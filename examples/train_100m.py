"""Train a ~100M-parameter dense LM with the full production substrate:
microbatched remat train step, async checkpointing, restart, straggler
bookkeeping, optional int8 gradient compression.

A few hundred steps is the full-scale intent; on this CPU container use
--steps 20 (default) for a quick demonstration — the code path is identical.

    PYTHONPATH=src python examples/train_100m.py --steps 20
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.fault import TrainSupervisor
from repro.models import model as M
from repro.train.data import make_batch
from repro.train.train_step import TrainConfig, make_train_step

CFG_100M = ArchConfig(
    name="dense_100m", family="dense",
    num_layers=10, d_model=640, num_heads=10, num_kv_heads=5,
    d_ff=2560, vocab_size=50304,
    stage_pattern=("attn",),
    mlp_act="silu", mlp_gated=True,
    dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=2)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"model: {cfg.param_count()/1e6:.1f}M params")
    tc = TrainConfig(lr=3e-4, grad_accum=args.grad_accum, remat=True,
                     compress_grads=args.compress_grads)
    opt, train_step = make_train_step(cfg, tc)

    def init_state():
        params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        return {"params": params, "opt": opt.init(params)}

    sup = TrainSupervisor(args.ckpt_dir, init_state, ckpt_every=10)
    state, start = sup.restore_or_init()
    if start:
        print(f"restored checkpoint; resuming from step {start}")
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, args.seq, args.batch, step).items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(state["params"], state["opt"], batch)
        state = {"params": params, "opt": opt_state}
        print(f"step {step}: loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.2f} "
              f"dt={time.perf_counter()-t0:.1f}s", flush=True)
        sup.after_step(step, state)
    sup.finalize(args.steps - 1, state)
    print("done; stragglers observed:", sup.straggler.slow_steps)


if __name__ == "__main__":
    main()
