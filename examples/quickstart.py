"""Quickstart: FP=xINT series expansion in 60 lines.

Expands a tensor and a linear layer into low-bit series, shows the
exponential convergence of Theorem 1, and the Abelian basis-model
decomposition of Theorem 2.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abelian as A
from repro.core import expansion as E
from repro.core.linear import expand_weight, expanded_apply
from repro.core.policy import W4A4
from repro.core.ptq import expand_params

rng = np.random.default_rng(0)

# --- Theorem 1: tensor series expansion -----------------------------------
M = jnp.array(rng.normal(size=(256, 256)).astype(np.float32))
et = E.expand(M, bits=4, terms=4, saturating=True, per_channel=True)
print("tensor expansion: INT4 x", et.num_terms, "terms")
for t in range(1, 5):
    res = float(jnp.max(jnp.abs(E.residual(M, et, t))))
    print(f"  terms={t}: max|M - reconstruction| = {res:.3e}")
print("  (each term shrinks the residual by 2^4 = 16x — exponential convergence)")

# --- Eq. 3/4: layer expansion ----------------------------------------------
x = jnp.array(rng.normal(size=(32, 256)).astype(np.float32))
w_et = expand_weight(M, W4A4)
y = expanded_apply(x, w_et, W4A4)          # sum of INT8-GEMM terms
rel = float(jnp.linalg.norm(y - x @ M) / jnp.linalg.norm(x @ M))
print(f"\nlayer expansion (W4A4, 2x3 terms): relative error = {rel:.4f}")

# --- Theorem 2: the model as an Abelian sum of low-bit basis models --------
params = {"fc1": {"kernel": M}, "fc2": {"kernel": jnp.array(
    rng.normal(size=(256, 64)).astype(np.float32))}}
q = expand_params(params, W4A4)
basis = A.basis_models(q)
print(f"\nmodel expansion: {len(basis)} isomorphic basis models")
total = A.abelian_sum(basis)               # AbelianAdd == AllReduce reduction
err = float(jnp.max(jnp.abs(total["fc1"]["kernel"] - E.reconstruct(q["fc1"]["kernel"]))))
print(f"abelian_sum(basis) == dequantized model (max err {err:.1e})")
print("the sum is order-independent — exactly the AllReduce contract")
