"""Quickstart: FP=xINT in three layers.

1. Theorem 1 — expand a tensor into a low-bit series (core layer);
2. Recipe -> Artifact -> Runtime — the unified API: quantize a model,
   save the artifact, load it back, run it bit-exactly;
3. Theorem 2 — the model as an Abelian sum of low-bit basis models.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import QuantArtifact, QuantRecipe, Runtime, quantize
from repro.core import abelian as A
from repro.core import expansion as E
from repro.core.policy import W4A4
from repro.models import model as M
from repro.configs.base import get_arch

rng = np.random.default_rng(0)

# --- Theorem 1: tensor series expansion -----------------------------------
M_t = jnp.array(rng.normal(size=(256, 256)).astype(np.float32))
et = E.expand(M_t, bits=4, terms=4, saturating=True, per_channel=True)
print("tensor expansion: INT4 x", et.num_terms, "terms")
for t in range(1, 5):
    res = float(jnp.max(jnp.abs(E.residual(M_t, et, t))))
    print(f"  terms={t}: max|M - reconstruction| = {res:.3e}")
print("  (each term shrinks the residual by 2^4 = 16x — exponential convergence)")

# --- The unified API: Recipe -> Artifact -> Runtime ------------------------
cfg = get_arch("qwen2_1_5b", smoke=True)
params = M.init_params(jax.random.PRNGKey(0), cfg)
recipe = QuantRecipe(method="fpxint", policy=W4A4, arch="qwen2_1_5b", smoke=True)
art = quantize(params, recipe)                       # calibration-free, seconds
st = art.meta["expansion_stats"]
print(f"\nquantize(): {int(st['expanded_leaves'])} GEMM weights expanded in "
      f"{art.quant_seconds:.2f}s, {st['compression']:.2f}x smaller")

path = os.path.join(tempfile.mkdtemp(), "qwen2_w4a4")
art.save(path)                                       # expand once ...
loaded = QuantArtifact.load(path)                    # ... serve forever
rt_mem = Runtime(art, backend="ref")
rt_disk = Runtime(loaded, backend="ref")
tokens = jnp.array(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
y_mem, y_disk = rt_mem.apply(tokens), rt_disk.apply(tokens)
assert bool(jnp.all(y_mem == y_disk)), "save/load must be bit-exact"
print(f"artifact round-trip: Runtime.apply bit-exact "
      f"(max|logit| = {float(jnp.max(jnp.abs(y_disk))):.3f})")

y_fp = jax.jit(lambda p, t: M.forward(p, {"tokens": t}, cfg))(params, tokens)
rel = float(jnp.linalg.norm(y_disk - y_fp) / jnp.linalg.norm(y_fp))
print(f"W4A4 vs FP logits: relative error = {rel:.4f}")

# every registered method produces the same artifact type
for method in ("rtn", "gptq_lite"):
    a = quantize(params, QuantRecipe(method=method, policy=W4A4, arch="qwen2_1_5b"))
    y = Runtime(a, backend="ref").apply(tokens)
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    print(f"{method:10s} through the same path: relative error = {rel:.4f}")

# --- Theorem 2: the model as an Abelian sum of low-bit basis models --------
toy = {"fc1": {"kernel": M_t}, "fc2": {"kernel": jnp.array(
    rng.normal(size=(256, 64)).astype(np.float32))}}
q = quantize(toy, QuantRecipe(method="fpxint", policy=W4A4)).params
basis = A.basis_models(q)
print(f"\nmodel expansion: {len(basis)} isomorphic basis models")
total = A.abelian_sum(basis)               # AbelianAdd == AllReduce reduction
err = float(jnp.max(jnp.abs(total["fc1"]["kernel"] - E.reconstruct(q["fc1"]["kernel"]))))
print(f"abelian_sum(basis) == dequantized model (max err {err:.1e})")
print("the sum is order-independent — exactly the AllReduce contract")
