"""Elastic quality from ONE artifact: QoS tiers + graceful degradation.

Theorem 1 makes every k-term prefix of an FP=xINT expansion a coherent
lower-bit model sharing weights/scales/KV layout with the full series —
so one resident artifact serves a whole quality ladder, per request, with
no weight reload (DESIGN.md §11):

1. quantize once (3 weight terms), record the tier ladder on the recipe;
2. serve a mixed full/k2/k1 workload and print per-tier metrics
   (nominal vs effective terms, deadline hit rate);
3. rerun under a seeded chaos HBM squeeze: the scheduler *degrades*
   degradable tiers to their floor budget instead of rejecting work,
   then restores them when the squeeze passes — zero slots leaked.

    python examples/elastic_rescale.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.api import QuantRecipe, Runtime, quantize
from repro.configs.base import get_arch
from repro.core.policy import ExpansionPolicy
from repro.infer.qos import ChaosConfig, Rejection
from repro.infer.serve import ServeConfig
from repro.launch.common import submit_with_backoff
from repro.models import model as M

# weight-only, THREE weight terms: k2/k1 are genuine truncations
POLICY = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=3, a_terms=0)
TIERS = (("k2", 2), ("k1", 1))


def submit_mixed(eng, cfg, n_requests, seed=0):
    """Round-robin the tier ladder over a mixed-length workload, through
    the typed-backpressure retry helper."""
    rng = np.random.default_rng(seed)
    names = list(eng.tiers)                 # ("full", "k2", "k1")
    ids = []
    for i in range(n_requests):
        toks = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 16))).tolist()
        res = submit_with_backoff(eng, toks, quality=names[i % len(names)],
                                  deadline_s=120.0)
        if isinstance(res, Rejection):
            print(f"  request {i} rejected: {res.reason.name}")
        else:
            ids.append(res)
    return ids


def report(eng):
    st = eng.last_run_stats
    for name, ts in sorted(st["tiers"].items()):
        print(f"  tier {name:>4}: {ts['requests']} reqs, "
              f"{ts['served_tokens']:3d} tokens, "
              f"terms {ts['mean_effective_terms']:.2f}"
              f"/{ts['nominal_terms']} "
              f"(degraded {ts['degraded_step_fraction']:.0%} of steps), "
              f"deadline hit rate {ts['deadline_hit_rate']:.2f}")
    q = st.get("qos", {})
    print(f"  degradation: {q.get('degraded_rounds', 0)} rounds, "
          f"reasons={q.get('degrade_reasons', {})}, "
          f"degraded_now={q.get('degraded_now', False)}")
    assert st["slots_leaked"] == 0 and st["queue_leftover"] == 0
    print(f"  invariants: slots_leaked=0 queue_leftover=0  OK")


def main():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    art = quantize(params, QuantRecipe(
        method="fpxint", policy=POLICY, arch="qwen2_1_5b", smoke=True,
        qos_tiers=TIERS))                    # ladder recorded on the recipe
    rt = Runtime(art, backend="ref", cfg=cfg)
    print(f"quantized once: {art.quant_seconds:.2f}s; serving tiers "
          f"full/k2/k1 from the SAME resident weights\n")

    # --- phase 1: mixed tiers, calm conditions --------------------------
    print("[calm] 6 requests, tiers round-robin full/k2/k1:")
    eng = rt.serve(ServeConfig(max_seq=64, max_slots=3))
    ids = submit_mixed(eng, cfg, 6)
    out = eng.run(max_new_tokens=8)
    assert set(out) == set(ids)
    report(eng)

    # --- phase 2: chaos HBM squeeze -> degrade, recover -----------------
    print("\n[chaos] same workload under a seeded HBM squeeze "
          "(rounds 2..5 at 40% budget) + latency spikes:")
    chaos = ChaosConfig(seed=0, latency_p=0.2, latency_s=0.002,
                        hbm_squeeze_start=2, hbm_squeeze_steps=4,
                        hbm_squeeze_frac=0.4)
    eng = rt.serve(ServeConfig(max_seq=64, max_slots=3, chaos=chaos))
    ids = submit_mixed(eng, cfg, 6)
    out = eng.run(max_new_tokens=8)
    assert set(out) == set(ids)              # degraded, not shed
    report(eng)
    st = eng.last_run_stats
    print(f"  chaos injected: {st['chaos']}")
    print("\nelastic quality complete: one artifact, three live qualities, "
          "graceful degradation under pressure.")


if __name__ == "__main__":
    main()
