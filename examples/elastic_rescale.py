"""Elastic scaling end-to-end: train on a 4-device mesh, checkpoint, lose
half the fleet, restore onto a 2-device mesh with new shardings, continue
training — parameters identical at the handoff, loss keeps falling.

    python examples/elastic_rescale.py      # sets its own XLA_FLAGS (8 dev)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.dist import checkpoint as CKPT
from repro.dist.sharding import ShardingRules
from repro.models import model as M
from repro.train.data import make_batch
from repro.train.train_step import TrainConfig, make_train_step


def make_mesh(d, m):
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh((d, m), ("data", "model"))


def main():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    tc = TrainConfig(lr=3e-3, remat=False)
    opt, step = make_train_step(cfg, tc)
    ckpt_dir = tempfile.mkdtemp(prefix="elastic_")

    def sharded_state(mesh, state=None):
        rules = ShardingRules(mesh, ("data",))
        template = state or {"params": M.init_params(jax.random.PRNGKey(0), cfg,
                                                     dtype=jnp.float32)}
        p_specs = rules.param_specs(template["params"])
        o_specs = rules.opt_state_specs("adamw", template["params"], p_specs)
        return {"params": p_specs, "opt": o_specs}

    # ---- phase 1: 4x2 mesh --------------------------------------------
    mesh_a = make_mesh(4, 2)
    params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    state = {"params": params, "opt": opt.init(params)}
    specs_a = sharded_state(mesh_a, state)
    state = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, jax.NamedSharding(mesh_a, s.spec)),
        state, specs_a)
    sstep = jax.jit(step)
    with mesh_a:
        for i in range(6):
            batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, i).items()}
            p, o, m = sstep(state["params"], state["opt"], batch)
            state = {"params": p, "opt": o}
            print(f"[mesh 4x2] step {i}: loss={float(m['loss']):.4f}")
    CKPT.save(ckpt_dir, 5, state)
    ref_leaf = np.asarray(jax.device_get(
        jax.tree_util.tree_leaves(state["params"])[0]))

    # ---- phase 2: "failure" -> restore on a 2x2 mesh -------------------
    print("\n... simulating loss of half the fleet; restoring on 2x2 ...\n")
    mesh_b = make_mesh(2, 2)
    rules_b = ShardingRules(mesh_b, ("data",))
    template = jax.eval_shape(lambda: {"params": M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)})
    p_specs = rules_b.param_specs(template["params"])
    o_specs = rules_b.opt_state_specs("adamw", template["params"], p_specs)
    full_template = jax.eval_shape(lambda: {"params": M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32),
                                            "opt": opt.init(M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32))})
    shardings = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh_b, s.spec), {"params": p_specs, "opt": o_specs})
    state2, step_restored = CKPT.restore(ckpt_dir, full_template, shardings=shardings)
    got = np.asarray(jax.device_get(jax.tree_util.tree_leaves(state2["params"])[0]))
    print(f"restored step {step_restored}; params bitwise equal: "
          f"{np.array_equal(ref_leaf, got)}")

    with mesh_b:
        for i in range(step_restored + 1, step_restored + 4):
            batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, i).items()}
            p, o, m = sstep(state2["params"], state2["opt"], batch)
            state2 = {"params": p, "opt": o}
            print(f"[mesh 2x2] step {i}: loss={float(m['loss']):.4f}")
    print("\nelastic rescale complete: same stream, half the devices.")


if __name__ == "__main__":
    main()
