"""End-to-end serving driver (the paper's deployment story):

1. train a small LM on the synthetic Markov task,
2. series-expand it W4A4 — calibration-free, seconds,
3. serve batched requests through the INT pipeline,
4. report quantization time, accuracy preservation, throughput.

    PYTHONPATH=src python examples/serve_expanded.py [--requests 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core.policy import W4A4
from repro.infer.serve import Engine, ServeConfig
from repro.models import model as M
from repro.train.data import make_batch
from repro.train.train_step import TrainConfig, loss_fn, make_train_step
from repro.models.layers import QuantContext


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch("qwen2_1_5b", smoke=True)
    print(f"training a {cfg.param_count()/1e3:.0f}k-param {cfg.family} LM "
          f"for {args.train_steps} steps...")
    params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt, step = make_train_step(cfg, TrainConfig(lr=3e-3, remat=False))
    opt_state = opt.init(params)
    step = jax.jit(step)
    for i in range(args.train_steps):
        b = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, i).items()}
        params, opt_state, m = step(params, opt_state, b)
    print(f"  final train loss {float(m['loss']):.3f}")

    def ev(p, qc=None):
        from repro.models.layers import FP
        b = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, 999).items()}
        l, met = loss_fn(p, b, cfg, qc or FP)
        return float(l), float(met["accuracy"])

    base_loss, base_acc = ev(params)
    eng = Engine(cfg, params, policy=W4A4,
                 serve_cfg=ServeConfig(max_seq=96, max_batch=8))
    q_loss, q_acc = ev(eng.params, QuantContext(policy=W4A4))
    print(f"\nFP=xINT W4A4 expansion: {eng.quant_seconds:.2f}s, zero calibration data")
    print(f"  loss {base_loss:.3f} -> {q_loss:.3f};  acc {base_acc:.3f} -> {q_acc:.3f}")

    rng = np.random.default_rng(1)
    for _ in range(args.requests):
        eng.add_request(rng.integers(0, cfg.vocab_size, 16).tolist())
    t0 = time.perf_counter()
    out = eng.run(max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in out.values())
    print(f"\nserved {len(out)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s batched on CPU)")
    print("sample generation:", out[0][:16])


if __name__ == "__main__":
    main()
