"""End-to-end serving driver (the paper's deployment story):

1. train a small LM on the synthetic Markov task,
2. quantize(params, recipe) — series-expand W4A4, calibration-free, seconds,
3. artifact.save(...) then QuantArtifact.load(...) — the expand-once product,
4. Runtime(artifact).serve(...) — continuous slot-batched requests through
   the INT pipeline with no re-expansion at admission (mixed-length
   prompts, per-request token budgets, slot recycling),
5. report quantization time, accuracy preservation, throughput, TTFT and
   slot occupancy.

    PYTHONPATH=src python examples/serve_expanded.py [--requests 16]
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import QuantArtifact, QuantRecipe, Runtime, quantize
from repro.configs.base import get_arch
from repro.core.policy import W4A4
from repro.launch.common import (add_serve_args, mesh_from_args,
                                 serve_config_from_args)
from repro.models import model as M
from repro.train.data import make_batch
from repro.train.train_step import TrainConfig, loss_fn, make_train_step

ARCH = "qwen2_1_5b"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--artifact-dir", default=None,
                    help="where to save the artifact (default: a temp dir)")
    # the shared serving flag set (launch/common.py, documented in
    # docs/api.md) — identical to `python -m repro.launch.serve`
    add_serve_args(ap, max_batch_default=8)
    ap.set_defaults(max_new=24, max_seq=96, max_slots=4)
    args = ap.parse_args()

    cfg = get_arch(ARCH, smoke=True)
    print(f"training a {cfg.param_count()/1e3:.0f}k-param {cfg.family} LM "
          f"for {args.train_steps} steps...")
    params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt, step = make_train_step(cfg, TrainConfig(lr=3e-3, remat=False))
    opt_state = opt.init(params)
    step = jax.jit(step)
    for i in range(args.train_steps):
        b = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, i).items()}
        params, opt_state, m = step(params, opt_state, b)
    print(f"  final train loss {float(m['loss']):.3f}")

    # quantize once; the artifact is the deployable product
    art = quantize(params, QuantRecipe(method="fpxint", policy=W4A4,
                                       arch=ARCH, smoke=True))
    path = os.path.join(args.artifact_dir or tempfile.mkdtemp(), "qwen2_w4a4")
    art.save(path)
    print(f"\nFP=xINT W4A4 expansion: {art.quant_seconds:.2f}s, zero "
          f"calibration data; artifact saved to {path}")

    # a fresh process would start exactly here; --placement term --mesh N
    # serves the artifact with its series terms scattered over N devices
    art = QuantArtifact.load(path)
    mesh, placement = mesh_from_args(args)
    rt = Runtime(art, backend="ref", cfg=cfg, mesh=mesh, placement=placement)

    b = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, 999).items()}
    base_loss, base_m = loss_fn(params, b, cfg)
    q_loss, q_m = rt.lm_loss(b)
    print(f"  loss {float(base_loss):.3f} -> {float(q_loss):.3f};  "
          f"acc {float(base_m['accuracy']):.3f} -> {float(q_m['accuracy']):.3f}")

    # continuous batching: a 4-slot pool serves mixed-length prompts, and
    # slots freed by per-request token budgets are recycled mid-stream
    eng = rt.serve(serve_config_from_args(args))
    assert eng.quant_seconds == art.quant_seconds  # admission did not re-expand
    rng = np.random.default_rng(1)
    for i in range(args.requests):
        length = int(rng.integers(6, 24))
        eng.add_request(rng.integers(0, cfg.vocab_size, length).tolist(),
                        max_new_tokens=int(rng.integers(4, args.max_new + 1)))
    t0 = time.perf_counter()
    out = eng.run(max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in out.values())
    st = eng.last_run_stats
    print(f"\nserved {len(out)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s batched on CPU)")
    print(f"continuous batching: {st['n_slots']} slots, "
          f"placement {st['placement']} x{st['mesh_devices']} devices, "
          f"occupancy {st['occupancy']:.2f}, "
          f"decode {st['decode_tokens_per_sec']:.1f} tok/s")
    ttfts = [m["ttft_s"] for m in eng.last_request_metrics.values()]
    print(f"ttft mean {np.mean(ttfts)*1e3:.0f}ms / max {np.max(ttfts)*1e3:.0f}ms")
    print("sample generation:", out[0][:16])


if __name__ == "__main__":
    main()
