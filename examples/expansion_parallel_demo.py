"""The paper's AllReduce execution model, live on 4 (fake) devices:

series terms shard over an 'expand' mesh axis, every device computes the
INT32 accumulators of its basis-model partial, and one *integer* psum
(= AbelianAdd, exact in Z) reconstructs the layer output — so the
distributed result matches the local fused GEMM exactly (DESIGN.md §9).
The production serving path wires the same executor through
Runtime(mesh=..., placement="term"); see README "Multi-device serving".

    python examples/expansion_parallel_demo.py     # sets its own XLA_FLAGS
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.linear import expand_weight, expanded_apply
from repro.core.policy import ExpansionPolicy
from repro.dist.expansion_parallel import make_expand_mesh, term_parallel_apply

pol = ExpansionPolicy(w_bits=4, a_bits=4, w_terms=4, a_terms=3)
rng = np.random.default_rng(0)
x = jnp.array(rng.normal(size=(64, 512)).astype(np.float32))
w = jnp.array(rng.normal(size=(512, 256)).astype(np.float32))

w_et = expand_weight(w, pol)
y_local = expanded_apply(x, w_et, pol)

mesh = make_expand_mesh(4)
print(f"devices: {jax.device_count()}; expand mesh: {mesh}")
y_par = term_parallel_apply(x, w_et, pol, mesh)

print("term-parallel == local fused:",
      bool(jnp.allclose(y_par, y_local, rtol=1e-5, atol=1e-5)))
rel = float(jnp.linalg.norm(y_par - x @ w) / jnp.linalg.norm(x @ w))
print(f"relative error vs FP matmul: {rel:.4f}")
print("each device computed 1 of 4 weight-plane groups; one psum per layer")
