"""The full PTQ lifecycle: train -> expand at several policies -> evaluate
-> pick the term count by the Fig. 4b rule -> compare against 1-term RTN.

    PYTHONPATH=src python examples/ptq_pipeline.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core import expansion as E
from repro.core.policy import NAMED_POLICIES, W4A4
from repro.core.ptq import expand_params, expand_params_timed, expansion_stats, max_weight_residual
from repro.models import model as M
from repro.models.layers import FP, QuantContext
from repro.train.data import make_batch
from repro.train.train_step import TrainConfig, loss_fn, make_train_step


def main():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt, step = make_train_step(cfg, TrainConfig(lr=3e-3, remat=False))
    opt_state = opt.init(params)
    step = jax.jit(step)
    print("training...")
    for i in range(60):
        b = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, i).items()}
        params, opt_state, _ = step(params, opt_state, b)

    def ev(p, qc=FP):
        b = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, 1234).items()}
        l, m = loss_fn(p, b, cfg, qc)
        return float(l), float(m["accuracy"])

    base = ev(params)
    print(f"FP: loss={base[0]:.3f} acc={base[1]:.3f}\n")
    print(f"{'policy':10s} {'loss':>7s} {'acc':>6s} {'size':>6s} {'quant_s':>8s} {'maxdiff':>9s}")
    for name in ("w8a8", "w4a4", "w2a4", "w3a3", "w2a2", "w4a16"):
        pol = NAMED_POLICIES[name]
        q, secs = expand_params_timed(params, pol)
        l, a = ev(q, QuantContext(policy=pol))
        st = expansion_stats(q)
        md = float(max_weight_residual(params, q))
        print(f"{name:10s} {l:7.3f} {a:6.3f} {1/st['compression']:5.2f}x {secs:8.2f} {md:9.2e}")

    # 1-term RTN comparison at W4A4
    rtn = dataclasses.replace(W4A4, w_terms=1, a_terms=1, w_saturating=False)
    q = expand_params(params, rtn)
    l, a = ev(q, QuantContext(policy=rtn))
    print(f"{'rtn_w4a4':10s} {l:7.3f} {a:6.3f}   (1-term truncation: the series terms are the win)")

    # Fig 4b stopping rule
    s1 = max(float(jnp.max(jnp.abs(leaf))) / 7.0
             for leaf in jax.tree_util.tree_leaves(params) if leaf.ndim >= 2)
    print(f"\nFig-4b rule: auto term count for threshold 1e-4 -> "
          f"{E.auto_num_terms(s1, 4, 1e-4)} terms (INT4)")


if __name__ == "__main__":
    main()
