"""The full PTQ lifecycle through the unified API: train -> quantize at
several recipes -> evaluate via Runtime -> pick the term count by the
Fig. 4b rule -> compare against the baseline methods (same artifact type,
same code path).

    PYTHONPATH=src python examples/ptq_pipeline.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.api import QuantRecipe, Runtime, quantize
from repro.configs.base import get_arch
from repro.core import expansion as E
from repro.core.policy import NAMED_POLICIES, W4A4
from repro.core.ptq import max_weight_residual
from repro.models import model as M
from repro.train.data import make_batch
from repro.train.train_step import TrainConfig, loss_fn, make_train_step

ARCH = "qwen2_1_5b"


def main():
    cfg = get_arch(ARCH, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt, step = make_train_step(cfg, TrainConfig(lr=3e-3, remat=False))
    opt_state = opt.init(params)
    step = jax.jit(step)
    print("training...")
    for i in range(60):
        b = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, i).items()}
        params, opt_state, _ = step(params, opt_state, b)

    eval_batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, 1234).items()}

    def ev_runtime(rt: Runtime):
        l, m = rt.lm_loss(eval_batch)
        return float(l), float(m["accuracy"])

    l, m = loss_fn(params, eval_batch, cfg)
    print(f"FP: loss={float(l):.3f} acc={float(m['accuracy']):.3f}\n")
    print(f"{'recipe':12s} {'loss':>7s} {'acc':>6s} {'size':>6s} {'quant_s':>8s} {'maxdiff':>9s}")
    for name in ("w8a8", "w4a4", "w2a4", "w3a3", "w2a2", "w4a16"):
        art = quantize(params, QuantRecipe(
            method="fpxint", policy=NAMED_POLICIES[name], arch=ARCH))
        loss, acc = ev_runtime(Runtime(art, backend="ref", cfg=cfg))
        st = art.meta["expansion_stats"]
        md = float(max_weight_residual(params, art.params))
        print(f"{name:12s} {loss:7.3f} {acc:6.3f} {1/st['compression']:5.2f}x "
              f"{art.quant_seconds:8.2f} {md:9.2e}")

    # baseline methods: same recipe surface, same artifact type, same eval path
    for method in ("rtn", "gptq_lite"):
        art = quantize(params, QuantRecipe(method=method, policy=W4A4, arch=ARCH))
        loss, acc = ev_runtime(Runtime(art, backend="ref", cfg=cfg))
        print(f"{method:12s} {loss:7.3f} {acc:6.3f}   (FP-reconstruction baseline)")

    # 1-term truncation of our own quantizer (the 'series terms are the win' row)
    rtn_pol = dataclasses.replace(W4A4, w_terms=1, a_terms=1, w_saturating=False)
    art = quantize(params, QuantRecipe(method="fpxint", policy=rtn_pol, arch=ARCH))
    loss, acc = ev_runtime(Runtime(art, backend="ref", cfg=cfg))
    print(f"{'1term_w4a4':12s} {loss:7.3f} {acc:6.3f}   (1-term truncation)")

    # Fig 4b stopping rule
    s1 = max(float(jnp.max(jnp.abs(leaf))) / 7.0
             for leaf in jax.tree_util.tree_leaves(params) if leaf.ndim >= 2)
    print(f"\nFig-4b rule: auto term count for threshold 1e-4 -> "
          f"{E.auto_num_terms(s1, 4, 1e-4)} terms (INT4)")


if __name__ == "__main__":
    main()
