from repro.quant.baselines import rtn_quantize_params, rtn_quantize_tensor, gptq_lite_quantize
from repro.quant.observers import MinMaxObserver, PercentileObserver, LaplaceObserver
