from repro.quant.baselines import (gptq_lite_quantize, gptq_lite_quantize_params,
                                   rtn_quantize_params, rtn_quantize_tensor)
from repro.quant.observers import MinMaxObserver, PercentileObserver, LaplaceObserver
