"""Baseline PTQ methods (rtn, gptq_lite) and calibration observers —
the non-series comparison rows of Tables 1/6, served through the same
Recipe -> Artifact -> Runtime path as fpxint (api/recipe.py registry)."""
from repro.quant.baselines import (gptq_lite_quantize, gptq_lite_quantize_params,
                                   rtn_quantize_params, rtn_quantize_tensor)
from repro.quant.observers import MinMaxObserver, PercentileObserver, LaplaceObserver
