"""Activation-range observers for the baseline PTQ methods.

FP=xINT itself is calibration-free (dynamic activation quantizers); these
observers exist for the *baselines* the paper compares against, which
calibrate static ranges on a small sample set.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass
class MinMaxObserver:
    lo: Optional[jnp.ndarray] = None
    hi: Optional[jnp.ndarray] = None

    def update(self, x: jnp.ndarray):
        lo, hi = jnp.min(x), jnp.max(x)
        self.lo = lo if self.lo is None else jnp.minimum(self.lo, lo)
        self.hi = hi if self.hi is None else jnp.maximum(self.hi, hi)
        return self

    def range(self):
        assert self.lo is not None, "observer saw no data"
        return self.lo, self.hi


@dataclasses.dataclass
class PercentileObserver:
    """Clip to the p/100 absolute-value percentile (outlier-robust).

    Streams a running *mean* of per-batch percentiles.  A running max (the
    previous behavior) converges to the global absmax as calibration batches
    accumulate — any single batch whose p-percentile lands near an outlier
    ratchets the estimate up permanently — which defeats exactly the
    outlier-robustness a percentile clip exists to provide.  The mean of
    per-batch percentiles is a consistent streaming estimator of the typical
    batch percentile and stays bounded away from the global absmax."""
    p: float = 99.9
    amax: Optional[jnp.ndarray] = None
    n: int = 0

    def update(self, x: jnp.ndarray):
        a = jnp.percentile(jnp.abs(x), self.p)
        self.amax = a if self.amax is None else \
            (self.amax * self.n + a) / (self.n + 1)
        self.n += 1
        return self

    def range(self):
        assert self.amax is not None
        return -self.amax, self.amax


@dataclasses.dataclass
class LaplaceObserver:
    """ACIQ-style Laplace-optimal clip (what FP=xINT's first plane uses)."""
    bits: int = 4
    b: Optional[jnp.ndarray] = None
    n: int = 0

    def update(self, x: jnp.ndarray):
        b = jnp.mean(jnp.abs(x - jnp.mean(x)))
        self.b = b if self.b is None else (self.b * self.n + b) / (self.n + 1)
        self.n += 1
        return self

    def range(self):
        from repro.core.expansion import laplace_clip_multiplier

        assert self.b is not None
        c = laplace_clip_multiplier(self.bits) * self.b
        return -c, c
