"""Baseline PTQ methods the paper compares against (Tables 1-3, 'Normal').

* ``rtn_quantize_*``  — round-to-nearest min-max PTQ (the 'Normal' row in
  Table 6): one scale per channel, no series, no correction terms.
* ``gptq_lite_quantize`` — a GPTQ-flavoured one-shot method: column-by-column
  rounding with error propagation into the not-yet-quantized columns,
  using a diagonal Hessian proxy (mean x^2 per input feature) from a tiny
  calibration batch.  This stands in for the calibrated-PTQ family
  (AdaQuant/BRECQ/GPTQ) that FP=xINT is benchmarked against.

Both produce *plain FP reconstructions* so they can be dropped into the same
model-apply path as the FP weights (the accuracy comparison isolates the
representation, exactly like the paper's tables).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def rtn_quantize_tensor(w: jnp.ndarray, bits: int, *, per_channel: bool = True,
                        symmetric: bool = True) -> jnp.ndarray:
    """Round-to-nearest quantize-dequantize (single term, min-max scales)."""
    w = w.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    axes = tuple(range(w.ndim - 1)) if per_channel else tuple(range(w.ndim))
    if symmetric:
        s = jnp.maximum(jnp.max(jnp.abs(w), axis=axes, keepdims=True), 1e-30) / qmax
        return s * jnp.clip(jnp.round(w / s), -qmax, qmax)
    lo = jnp.min(w, axis=axes, keepdims=True)
    hi = jnp.max(w, axis=axes, keepdims=True)
    s = jnp.maximum(hi - lo, 1e-30) / (2.0**bits - 1)
    z = jnp.round(-lo / s)
    return s * (jnp.clip(jnp.round(w / s) + z, 0, 2.0**bits - 1) - z)


def rtn_quantize_activation(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Dynamic per-tensor RTN for activations (the W_xA_y baselines)."""
    return rtn_quantize_tensor(x, bits, per_channel=False, symmetric=False)


def rtn_quantize_params(params: PyTree, bits: int) -> PyTree:
    """Quantize-dequantize every GEMM weight leaf (path ends in 'kernel')."""
    def visit(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if name.rsplit("/", 1)[-1] == "kernel" and hasattr(leaf, "ndim") and leaf.ndim >= 2:
            return rtn_quantize_tensor(leaf, bits)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


@partial(jax.jit, static_argnames=("bits",))
def gptq_lite_quantize(w: jnp.ndarray, x_cal: jnp.ndarray, bits: int) -> jnp.ndarray:
    """One-shot error-propagating quantization of a (K, N) weight.

    Processes input-dim rows in order; the rounding error of row k is pushed
    into the remaining rows weighted by the (diagonal-proxy) correlation of
    feature k with later features — a Hessian-diagonal GPTQ variant that
    needs only ``mean(x^2)`` statistics from ``x_cal`` (B, K).
    """
    w = w.astype(jnp.float32)
    k, n = w.shape
    qmax = float(2 ** (bits - 1) - 1)
    s = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-30) / qmax  # per out-channel
    h = jnp.mean(x_cal.astype(jnp.float32) ** 2, axis=0) + 1e-6  # (K,) diag Hessian proxy

    def body(carry, inputs):
        err_acc = carry                       # (N,) running error in output space
        w_row, h_k = inputs
        # compensate this row for the accumulated error of earlier rows
        w_eff = w_row - err_acc / jnp.maximum(h_k, 1e-6) * h_k / k
        q = jnp.clip(jnp.round(w_eff / s), -qmax, qmax) * s
        err_acc = err_acc + (w_eff - q) * h_k
        return err_acc, q

    _, wq = jax.lax.scan(body, jnp.zeros((n,), jnp.float32), (w, h))
    return wq


def gptq_lite_quantize_params(params: PyTree, bits: int, *, calib_batch: int = 32,
                              seed: int = 0) -> PyTree:
    """GPTQ-lite on every GEMM weight leaf (path ends in 'kernel').

    One synthetic calibration batch is drawn per leaf and shared across the
    slices of stacked (>2-dim expert/scanned) weights (the proxy benchmarks
    have no real calibration set in the container — the method still exercises
    the error-propagation machinery the calibrated-PTQ family relies on)."""
    import numpy as np
    r = np.random.default_rng(seed)

    def visit(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if name.rsplit("/", 1)[-1] == "kernel" and hasattr(leaf, "ndim") and leaf.ndim >= 2:
            k = leaf.shape[-2]
            x_cal = jnp.array(r.normal(size=(calib_batch, k)).astype("float32"))
            flat = leaf.reshape(-1, *leaf.shape[-2:])
            out = jnp.stack([gptq_lite_quantize(w, x_cal, bits) for w in flat])
            return out.reshape(leaf.shape)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)
