"""Model-level low-bit expansion driver (FP=xINT §3.3, Theorem 2).

Walks a parameter pytree and replaces every matmul weight with its
:class:`ExpandedTensor` series.  Calibration-free: every quantizer parameter
(clip, scales, bias, sat) is a pure function of the weight itself; activation
quantizers are dynamic (per batch) — no calibration set, no fine-tuning.

Weight-leaf identification is by path convention (the model zoo names every
GEMM weight ``kernel``); embedding gather tables (``embedding``) and norms/
biases stay FP.  First/last layers (paths matching ``first_last_patterns``)
are expanded at 8-bit per the paper's §5.1 protocol.
"""
from __future__ import annotations

import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import expansion as E
from repro.core.expansion import ExpandedTensor
from repro.core.policy import ExpansionPolicy

PyTree = Any

DEFAULT_FIRST_LAST = (r"lm_head", r"\bhead\b", r"embed_out", r"in_proj_first", r"patch_proj", r"frame_proj")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def is_gemm_weight(name: str, leaf) -> bool:
    if not isinstance(leaf, jnp.ndarray) and not hasattr(leaf, "shape"):
        return False
    if isinstance(leaf, ExpandedTensor):
        return False
    base = name.rsplit("/", 1)[-1]
    return base == "kernel" and leaf.ndim >= 2


def expand_params(
    params: PyTree,
    policy: ExpansionPolicy,
    *,
    first_last_patterns: Tuple[str, ...] = DEFAULT_FIRST_LAST,
    skip_patterns: Tuple[str, ...] = (),
) -> PyTree:
    """Replace every GEMM weight leaf with its series expansion.

    Weights with >2 dims (stacked experts / scanned layers) are expanded with
    independent per-slice quantizers over the leading axes (``expand_batched``).
    """
    fl_re = [re.compile(p) for p in first_last_patterns]
    skip_re = [re.compile(p) for p in skip_patterns]

    def visit(path, leaf):
        name = _path_str(path)
        if not is_gemm_weight(name, leaf):
            return leaf
        if any(r.search(name) for r in skip_re):
            return leaf
        is_fl = any(r.search(name) for r in fl_re)
        bits, _ = policy.layer_bits(name, is_fl)
        terms, _ = policy.layer_terms(is_fl)
        bd = leaf.ndim - 2
        if bd > 0:
            return E.expand_batched(
                leaf, bits, terms, batch_dims=bd,
                symmetric=policy.w_symmetric, saturating=policy.w_saturating,
                per_channel=policy.w_per_channel, keep_sat=policy.keep_w_sat,
                pack_safe=policy.pack_safe)
        return E.expand(
            leaf, bits, terms,
            symmetric=policy.w_symmetric, saturating=policy.w_saturating,
            per_channel=policy.w_per_channel, keep_sat=policy.keep_w_sat,
            pack_safe=policy.pack_safe)

    return jax.tree_util.tree_map_with_path(visit, params)


def expand_params_timed(params: PyTree, policy: ExpansionPolicy, **kw):
    """Returns (expanded_params, wall_seconds) — the paper's 'Quant-Time'."""
    t0 = time.perf_counter()
    out = jax.block_until_ready(jax.jit(lambda p: expand_params(p, policy, **kw))(params))
    return out, time.perf_counter() - t0


def expansion_stats(params: PyTree) -> Dict[str, float]:
    """Size accounting: FP bytes vs expanded bytes (planes int8 + scales +
    affine terms), plus counts.  Model-size numbers for Table 3."""
    fp_bytes = 0
    q_bytes = 0
    n_expanded = 0
    n_leaves = 0
    for leaf in jax.tree_util.tree_leaves(params, is_leaf=lambda l: isinstance(l, ExpandedTensor)):
        n_leaves += 1
        if isinstance(leaf, ExpandedTensor):
            n_expanded += 1
            orig = int(jnp.prod(jnp.array(leaf.orig_shape)))
            batch = int(jnp.prod(jnp.array(leaf.planes.shape[: leaf.batch_dims]))) if leaf.batch_dims else 1
            fp_bytes += 4 * orig * batch
            # logical low-bit storage: bits/8 bytes per element per term —
            # counted from orig_shape so packed (2 nibbles/byte) and
            # unpacked planes of the same series cost the same
            q_bytes += orig * batch * leaf.num_terms * leaf.bits // 8 \
                + leaf.scales.size * 4
            if leaf.bias is not None:
                q_bytes += leaf.bias.size * 4
            if leaf.sat is not None:
                nnz = int(jnp.sum(leaf.sat != 0))
                q_bytes += nnz * 8  # value + index
        else:
            b = leaf.size * leaf.dtype.itemsize
            fp_bytes += b
            q_bytes += b
    return {
        "fp_bytes": float(fp_bytes), "quant_bytes": float(q_bytes),
        "compression": float(fp_bytes) / max(float(q_bytes), 1.0),
        "expanded_leaves": float(n_expanded), "total_leaves": float(n_leaves),
    }


def max_weight_residual(params_fp: PyTree, params_q: PyTree) -> jnp.ndarray:
    """max over expanded leaves of |W - reconstruct(W_expanded)| (Fig. 4b x-axis)."""
    maxes = []

    def visit(q, fp):
        if isinstance(q, ExpandedTensor):
            maxes.append(jnp.max(jnp.abs(fp - E.reconstruct(q))))
        return q

    jax.tree_util.tree_map(visit, params_q, params_fp,
                           is_leaf=lambda l: isinstance(l, ExpandedTensor))
    return jnp.max(jnp.stack(maxes)) if maxes else jnp.float32(0.0)
