"""FP=xINT core: low-bit series expansion of tensors, layers, and models."""
from repro.core.expansion import (
    ExpandedTensor,
    expand,
    expand_batched,
    reconstruct,
    residual,
    theoretical_residual_bound,
    auto_num_terms,
    truncate,
    drop_sat,
)
from repro.core.abelian import (
    abelian_add,
    abelian_neg,
    abelian_zero_like,
    abelian_sum,
    abelian_mul,
    basis_model,
    basis_models,
    num_basis_terms,
    dequantize,
)
from repro.core.linear import expanded_apply, expand_weight, dense
from repro.core.policy import ExpansionPolicy, get_policy, NAMED_POLICIES
from repro.core.ptq import expand_params, expand_params_timed, expansion_stats, max_weight_residual
