"""AbelianAdd / AbelianMul over isomorphic models (FP=xINT §3.3).

The carrier set is "isomorphic models" — parameter pytrees with identical
treedef and leaf shapes.  The paper defines

    Model(W1, A, x) (+) Model(W2, A, x) = Model(W1 + W2, A, x)        (Eq. 5)
    U (*) model(W_i) = model(u_i * W_i)                               (Def. 2)

so AbelianAdd is leafwise addition of parameters and AbelianMul is a
per-layer scalar action.  ``(models, AbelianAdd)`` is an Abelian group
(identity = zero params, inverse = negated params), which is exactly the
contract AllReduce needs: the reduction used in
``dist/expansion_parallel.py`` is ``jax.lax.psum`` — commutative and
associative — applied to basis-model partial outputs.

These operations are what make the *model-level* expansion (Theorem 2)
executable: ``basis_models`` splits an expanded parameter pytree into the
isomorphic single-term models whose ⊎-sum reconstructs the FP model.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.core.expansion import ExpandedTensor, _expand_scale_dims

PyTree = Any


def _binary(f: Callable, a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(f, a, b)


def abelian_add(a: PyTree, b: PyTree) -> PyTree:
    """⊎ : leafwise parameter addition between isomorphic models (Eq. 5/6)."""
    return _binary(lambda x, y: x + y, a, b)


def abelian_neg(a: PyTree) -> PyTree:
    """Group inverse."""
    return jax.tree_util.tree_map(lambda x: -x, a)


def abelian_zero_like(a: PyTree) -> PyTree:
    """Group identity element."""
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def abelian_sum(models: Sequence[PyTree]) -> PyTree:
    """⊎-sum of many isomorphic models.  Order-independent (Abelian)."""
    if not models:
        raise ValueError("abelian_sum of empty sequence")
    out = models[0]
    for m in models[1:]:
        out = abelian_add(out, m)
    return out


def abelian_mul(u: Sequence[float] | jnp.ndarray, layers: Sequence[PyTree]) -> List[PyTree]:
    """U *̂ model: scale layer i's parameters by u_i (Definition 2)."""
    if len(u) != len(layers):
        raise ValueError(f"AbelianMul vector length {len(u)} != num layers {len(layers)}")
    return [jax.tree_util.tree_map(lambda x, s=s: s * x, layer) for s, layer in zip(u, layers)]


# ---------------------------------------------------------------------------
# basis models of an expanded parameter pytree (Theorem 2)
# ---------------------------------------------------------------------------
def is_expanded(leaf) -> bool:
    return isinstance(leaf, ExpandedTensor)


def dequant_term(et: ExpandedTensor, k: int) -> jnp.ndarray:
    """The FP weight contribution of series term k: scale_k * M~_k."""
    s_b = _expand_scale_dims(et.scales[k], et.planes.ndim - 1, et.per_channel)
    return s_b * et.planes[k].astype(jnp.float32)


def dequant_affine(et: ExpandedTensor) -> jnp.ndarray:
    """The non-series contribution: bias * M_nsy + M_sa (zero if symmetric/non-sat)."""
    out = jnp.zeros(et.orig_shape, jnp.float32)
    if et.bias is not None:
        out = out + _expand_scale_dims(et.bias, len(et.orig_shape), et.per_channel)
    if et.sat is not None:
        out = out + et.sat
    return out


def num_basis_terms(params: PyTree) -> int:
    """max term count across expanded leaves (+1 for the affine remainder)."""
    terms = [l.num_terms for l in jax.tree_util.tree_leaves(params, is_leaf=is_expanded) if is_expanded(l)]
    if not terms:
        return 1
    return max(terms) + 1


def basis_model(params: PyTree, k: int) -> PyTree:
    """Basis model k: every expanded weight contributes its k-th series term
    (or zero if it has fewer terms); the LAST index carries the affine part
    (bias*M_nsy + M_sa) plus every non-expanded FP leaf.

    ``abelian_sum(basis_model(p, k) for k in range(num_basis_terms(p)))``
    reconstructs the dequantized model exactly.
    """
    n = num_basis_terms(params)

    def pick(leaf):
        if is_expanded(leaf):
            if k < leaf.num_terms:
                return dequant_term(leaf, k)
            if k == n - 1:
                return dequant_affine(leaf)
            return jnp.zeros(leaf.orig_shape, jnp.float32)
        # non-expanded (FP) leaves ride along with the affine/base term
        return leaf if k == n - 1 else jnp.zeros_like(leaf)

    return jax.tree_util.tree_map(pick, params, is_leaf=is_expanded)


def basis_models(params: PyTree) -> List[PyTree]:
    return [basis_model(params, k) for k in range(num_basis_terms(params))]


def dequantize(params: PyTree) -> PyTree:
    """Full reconstruction: ⊎-sum of all basis models (== Theorem 2 RHS)."""
    from repro.core.expansion import reconstruct

    return jax.tree_util.tree_map(
        lambda l: reconstruct(l) if is_expanded(l) else l, params, is_leaf=is_expanded
    )
