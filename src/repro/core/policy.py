"""Expansion policies: which bits / how many series terms / where (FP=xINT §4, §5.1).

The paper's empirical rules, encoded:

* weights need only 2–3 terms (zero-gradient argument: ∂ℓ/∂W = 0 at a trained
  optimum, so W-error enters at second order) — ``w_terms`` defaults to 2;
* activations carry the accuracy — expand until ``max|residual| < 1e-4``
  (Fig. 4b) with a cap, ``a_terms`` defaults to policy-driven auto;
* first and last matmul layers stay at 8-bit (§5.1);
* weights per-channel, activations per-tensor & dynamic (calibration-free);
* saturating (Laplace clip) quantization for the first plane, with the sparse
  ``M_sa`` correction kept for weights and dropped for activations (§4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ExpansionPolicy:
    """Static (hashable) configuration for FP=xINT expansion."""

    w_bits: int = 4
    a_bits: int = 4
    w_terms: int = 2
    a_terms: int = 3
    # quantizer shape
    w_per_channel: bool = True
    w_symmetric: bool = True
    a_symmetric: bool = False          # activations are asymmetric (post-GELU etc.)
    w_saturating: bool = True          # Laplace clip on the weight's first plane
    a_saturating: bool = False         # activations keep full range: LLM-style
                                       # outliers make clipped-and-dropped A_sa
                                       # expensive (measured +0.31 loss on the
                                       # smoke LM) — beyond-paper default
    keep_w_sat: bool = True
    keep_a_sat: bool = False           # paper §4: A_sa influence is small
    pack_safe: bool = False            # keep every plane on the true X-bit
                                       # grid so INT4 planes pack 2/byte
                                       # (kernels/pack.py); costs a 3x slack
                                       # on the final-term residual bound
    # layer placement
    first_last_bits: int = 8           # §5.1: first & last layers at 8-bit
    first_last_terms: int = 1
    # per-layer mixed-precision overrides: name -> (bits_w, bits_a)
    mixed: Optional[Tuple[Tuple[str, Tuple[int, int]], ...]] = None
    # activation handling: dynamic per-batch scales (calibration-free)
    act_dynamic: bool = True
    # auto term selection threshold (Fig 4b: expand until maxdiff < 1e-4)
    auto_term_threshold: float = 1e-4
    max_terms: int = 6

    def layer_bits(self, name: str, is_first_or_last: bool) -> Tuple[int, int]:
        if self.mixed:
            for key, bits in self.mixed:
                if key in name:
                    return bits
        if is_first_or_last:
            return (self.first_last_bits, self.first_last_bits)
        return (self.w_bits, self.a_bits)

    def layer_terms(self, is_first_or_last: bool) -> Tuple[int, int]:
        if is_first_or_last:
            return (self.first_last_terms, self.first_last_terms)
        return (self.w_terms, self.a_terms)


# canonical settings used across benchmarks (paper Tables 1/2/6)
W4A4 = ExpansionPolicy(w_bits=4, a_bits=4, w_terms=2, a_terms=3)
W2A4 = ExpansionPolicy(w_bits=2, a_bits=4, w_terms=3, a_terms=3)
W4A2 = ExpansionPolicy(w_bits=4, a_bits=2, w_terms=2, a_terms=4)
W2A2 = ExpansionPolicy(w_bits=2, a_bits=2, w_terms=3, a_terms=5)
W3A3 = ExpansionPolicy(w_bits=3, a_bits=3, w_terms=2, a_terms=4)
W8A8 = ExpansionPolicy(w_bits=8, a_bits=8, w_terms=1, a_terms=1)
W4A16 = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=2, a_terms=0)  # weight-only (Table 6)

NAMED_POLICIES: Dict[str, ExpansionPolicy] = {
    "w4a4": W4A4, "w2a4": W2A4, "w4a2": W4A2, "w2a2": W2A2,
    "w3a3": W3A3, "w8a8": W8A8, "w4a16": W4A16,
}


def get_policy(name: str) -> ExpansionPolicy:
    try:
        return NAMED_POLICIES[name.lower()]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; known: {sorted(NAMED_POLICIES)}") from None
