"""Tensor-level low-bit series expansion (FP=xINT, Theorem 1).

Expands a dense FP tensor ``M`` into

    M  =  M_sa  +  bias * M_nsy  +  sum_i  scale_i * M~_i ,

where every ``M~_i`` is an INT-X plane (stored in an int8 container), the
scales follow the paper's dyadic schedule ``scale_i = 2^X * scale_{i+1}``,
``bias * M_nsy`` (all-ones, rank-1) absorbs an asymmetric zero-point, and
``M_sa`` is the sparse saturation correction produced by clipping.

Numerical conventions (see DESIGN.md §7):

* plane k=0 uses the symmetric grid ``[-(2^{X-1}-1), 2^{X-1}-1]`` so that
  ``scale_1 = absmax / (2^{X-1}-1)`` maps the extremes exactly;
* residual planes (k>=1) may use ``±2^{X-1}`` (the proof's bound) because a
  round-to-nearest residual lies in ``[-scale_{k-1}/2, scale_{k-1}/2]``;
  for X=8 the int8 container clamps +128 -> +127 and the clamp error is
  re-absorbed by the next residual (sequential extraction);
* extraction is *sequential* (numerically stable in f32); the paper's §4
  closed form ``M~_k = INTX(M/s_k) - 2^X * INTX(M/s_{k-1})`` is provided in
  :func:`extract_plane_closed_form` and is exactly equal to the sequential
  extraction whenever no clamping fires (tested property).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# the shared grid-constant table (repro/numerics.py, dependency-free): the
# kernels import the same functions, so every extraction site provably
# agrees; lint rule REPRO103 locks re-definitions outside repro/numerics.py.
# ``scale_ratio`` stays public here (E.scale_ratio) — it is part of the
# expansion API surface.
from repro.numerics import plane_limits as _plane_limits
from repro.numerics import scale_ratio

# ---------------------------------------------------------------------------
# ACIQ-style Laplace-optimal clipping multipliers: clip = kappa(X) * b where
# b is the Laplace scale estimated as mean |M - mu|.  (Banner et al., 2018.)
# ---------------------------------------------------------------------------
LAPLACE_CLIP_MULTIPLIER = {1: 1.86, 2: 2.83, 3: 3.89, 4: 5.03, 5: 6.20, 6: 7.41, 7: 8.64, 8: 9.89}


def laplace_clip_multiplier(bits: int) -> float:
    if bits in LAPLACE_CLIP_MULTIPLIER:
        return LAPLACE_CLIP_MULTIPLIER[bits]
    # asymptotic fit kappa ~= X*ln2 + 2.3 for larger X
    return bits * math.log(2.0) + 2.3


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["planes", "scales", "bias", "sat"],
    meta_fields=["bits", "per_channel", "batch_dims", "packed", "pack_pad"],
)
@dataclasses.dataclass
class ExpandedTensor:
    """A tensor represented as a low-bit series (Theorem 1).

    Attributes:
      planes:  int8, shape (*B, t, *orig_shape).  INT-X values in an int8
               container.  ``B`` are optional leading batch axes (e.g. the
               expert axis of stacked MoE weights), see ``batch_dims``.
               When ``packed``, the last axis holds 2 INT4 nibbles per byte
               (kernels/pack.py) and is ``ceil(orig_shape[-1] / 2)`` wide.
      scales:  f32, shape (*B, t) (per-tensor) or (*B, t, C) with
               C = orig_shape[-1] (per-channel over the last axis).
      bias:    f32 (*B,) or (*B, C), the asymmetric zero offset
               (``bias * M_nsy``), or None for symmetric expansions.
      sat:     f32 (*B, *orig_shape), dense storage of the sparse saturation
               correction ``M_sa``, or None for non-saturating expansions.
      bits:    logical bit-width X of each plane (static).
      per_channel: whether scales carry a channel dim (static).
      batch_dims: number of leading batch axes (static); generic ops vmap
               themselves over these (``expand_batched`` produces them).
      packed:  planes are INT4-packed 2/byte over the last axis (static).
      pack_pad: zero nibbles appended at pack time for an odd last axis
               (static; 0 or 1) — the artifact records it so unpacking can
               strip the pad exactly.
    """

    planes: jnp.ndarray
    scales: jnp.ndarray
    bias: Optional[jnp.ndarray]
    sat: Optional[jnp.ndarray]
    bits: int
    per_channel: bool
    batch_dims: int = 0
    packed: bool = False
    pack_pad: int = 0

    @property
    def num_terms(self) -> int:
        return self.planes.shape[self.batch_dims]

    @property
    def orig_shape(self):
        shape = self.planes.shape[self.batch_dims + 1:]
        if self.packed:
            shape = shape[:-1] + (shape[-1] * 2 - self.pack_pad,)
        return shape

    def unbatched_view(self) -> "ExpandedTensor":
        """Static view with one batch axis peeled (for use inside jax.vmap)."""
        assert self.batch_dims > 0
        return dataclasses.replace(self, batch_dims=self.batch_dims - 1)

    def truncate(self, terms: int) -> "ExpandedTensor":
        """Zero-copy prefix view over the term axis: the first ``terms``
        planes/scales (a ``lax.slice`` the compiler folds into consumers, no
        materialized copy).  Theorem 1's convergence guarantee makes this
        prefix a coherent lower-precision model in its own right — the free
        draft model of self-speculative decoding (DESIGN.md §10).  bias/sat
        are affine corrections, not series terms, and are kept."""
        return truncate(self, terms)

    def __repr__(self):  # keep pytree-printing short
        return (
            f"ExpandedTensor(bits={self.bits}, terms={self.num_terms}, "
            f"shape={tuple(self.orig_shape)}, per_channel={self.per_channel}, "
            f"asym={self.bias is not None}, sat={self.sat is not None}, "
            f"batch_dims={self.batch_dims}, packed={self.packed})"
        )


# ---------------------------------------------------------------------------
# scale / clip computation
# ---------------------------------------------------------------------------
def _reduce_all_but_last(x, fn):
    axes = tuple(range(x.ndim - 1))
    return fn(x, axis=axes)


def laplace_b(m: jnp.ndarray, per_channel: bool) -> jnp.ndarray:
    """Laplace scale estimate b = E|M - median| (we use mean as the center,
    which matches the symmetric-about-zero weight distributions in practice)."""
    if per_channel:
        mu = _reduce_all_but_last(m, jnp.mean)
        return _reduce_all_but_last(jnp.abs(m - mu), jnp.mean)
    return jnp.mean(jnp.abs(m - jnp.mean(m)))


def absmax(m: jnp.ndarray, per_channel: bool) -> jnp.ndarray:
    if per_channel:
        return _reduce_all_but_last(jnp.abs(m), jnp.max)
    return jnp.max(jnp.abs(m))


def clip_bound(m: jnp.ndarray, bits: int, saturating: bool, per_channel: bool) -> jnp.ndarray:
    """Clipping bound c: absmax (non-saturating) or the Laplace-optimal clip."""
    amax = absmax(m, per_channel)
    if not saturating:
        return amax
    c = laplace_clip_multiplier(bits) * laplace_b(m, per_channel)
    # never clip *outside* the data range, and guard against all-zero channels
    return jnp.minimum(jnp.maximum(c, 1e-30), amax)


def first_scale(c: jnp.ndarray, bits: int) -> jnp.ndarray:
    """scale_1 = clip / (2^{X-1}-1); guarded so all-zero tensors stay finite."""
    qmax = float(2 ** (bits - 1) - 1)
    return jnp.maximum(c, 1e-30) / qmax


# scale_ratio: imported from repro.numerics above (shared with the kernels).


def term_scale(scale1: jnp.ndarray, bits: int, k: int) -> jnp.ndarray:
    """scale_{k+1} = scale_k / ratio(X)  (dyadic schedule, Theorem 1)."""
    return scale1 / float(scale_ratio(bits) ** k)


# ---------------------------------------------------------------------------
# plane extraction (_plane_limits: imported from repro.numerics above)
# ---------------------------------------------------------------------------


def _expand_scale_dims(scale, target_ndim, per_channel):
    """Reshape a per-tensor () or per-channel (C,) scale for broadcasting."""
    if per_channel:
        return scale.reshape((1,) * (target_ndim - 1) + scale.shape[-1:])
    return scale


def extract_planes_sequential(m: jnp.ndarray, scale1: jnp.ndarray, bits: int, terms: int,
                              per_channel: bool, pack_safe: bool = False):
    """Sequential residual extraction (canonical semantics).

    Returns (planes int8 (t, *m.shape), residual f32)."""
    r = m.astype(jnp.float32)
    planes = []
    for k in range(terms):
        s = term_scale(scale1, bits, k)
        s_b = _expand_scale_dims(s, m.ndim, per_channel)
        lo, hi = _plane_limits(bits, k, pack_safe)
        q = jnp.clip(jnp.round(r / s_b), lo, hi)
        r = r - s_b * q
        planes.append(q.astype(jnp.int8))
    return jnp.stack(planes, axis=0), r


def extract_plane_closed_form(m: jnp.ndarray, scale1: jnp.ndarray, bits: int, k: int, per_channel: bool):
    """Paper §4 parallel closed form:
    M~_k = INTX(M / s_k) - 2^X * INTX(M / s_{k-1});  M~_0 = INTX(M / s_0).

    Exactly equals the sequential extraction whenever no clamping fires.
    Computed in f32; valid while |M/s_k| < 2^24 (document: bits*k <= ~20).
    """
    s_k = _expand_scale_dims(term_scale(scale1, bits, k), m.ndim, per_channel)
    cur = jnp.round(m.astype(jnp.float32) / s_k)
    if k == 0:
        lo, hi = _plane_limits(bits, 0)
        return jnp.clip(cur, lo, hi).astype(jnp.int8)
    s_prev = _expand_scale_dims(term_scale(scale1, bits, k - 1), m.ndim, per_channel)
    prev = jnp.round(m.astype(jnp.float32) / s_prev)
    lo, hi = _plane_limits(bits, k)
    return jnp.clip(cur - float(scale_ratio(bits)) * prev, lo, hi).astype(jnp.int8)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def expand(
    m: jnp.ndarray,
    bits: int,
    terms: int,
    *,
    symmetric: bool = True,
    saturating: bool = False,
    per_channel: bool = False,
    keep_sat: bool = True,
    pack_safe: bool = False,
) -> ExpandedTensor:
    """Expand tensor ``m`` into a ``terms``-term INT-``bits`` series (Theorem 1)."""
    if terms < 1:
        raise ValueError("terms must be >= 1")
    if not 1 <= bits <= 8:
        raise ValueError("bits must be in [1, 8] (int8 container)")
    m = m.astype(jnp.float32)

    bias = None
    if not symmetric:
        if per_channel:
            mx = _reduce_all_but_last(m, jnp.max)
            mn = _reduce_all_but_last(m, jnp.min)
        else:
            mx, mn = jnp.max(m), jnp.min(m)
        bias = (mx + mn) / 2.0  # paper: (vmax - vmin)/2 + vmin
        m = m - _expand_scale_dims(bias, m.ndim, per_channel)

    sat = None
    c = clip_bound(m, bits, saturating, per_channel)
    if saturating:
        c_b = _expand_scale_dims(c, m.ndim, per_channel)
        clipped = jnp.clip(m, -c_b, c_b)
        if keep_sat:
            sat = (m - clipped).astype(jnp.float32)
        m = clipped

    scale1 = first_scale(c, bits)
    planes, _ = extract_planes_sequential(m, scale1, bits, terms, per_channel, pack_safe)
    scales = jnp.stack([term_scale(scale1, bits, k) for k in range(terms)], axis=0).astype(jnp.float32)
    return ExpandedTensor(planes=planes, scales=scales, bias=bias, sat=sat, bits=bits, per_channel=per_channel)


def expand_batched(
    m: jnp.ndarray,
    bits: int,
    terms: int,
    *,
    batch_dims: int = 1,
    **kwargs,
) -> ExpandedTensor:
    """Expand a stack of tensors (e.g. per-expert MoE weights) independently.

    ``m``: (*B, ...) -> ExpandedTensor with ``batch_dims`` leading batch axes.
    Each slice gets its own scales/bias/sat (per-expert quantizers)."""
    fn = lambda x: expand(x, bits, terms, **kwargs)
    for _ in range(batch_dims):
        fn = jax.vmap(fn)
    et = fn(m)
    # vmap stacked the dataclass leaves but kept batch_dims=0 metadata
    return dataclasses.replace(et, batch_dims=batch_dims)


def reconstruct(et: ExpandedTensor, terms: Optional[int] = None) -> jnp.ndarray:
    """Sum the series back to FP: M_sa + bias*M_nsy + sum_i scale_i * M~_i."""
    if et.packed:
        et = unpack(et)
    if et.batch_dims > 0:
        return jax.vmap(lambda e: reconstruct(e, terms))(et.unbatched_view())
    t = et.num_terms if terms is None else min(terms, et.num_terms)
    ndim = et.planes.ndim - 1
    out = jnp.zeros(et.orig_shape, jnp.float32)
    for k in range(t):
        s_b = _expand_scale_dims(et.scales[k], ndim, et.per_channel)
        out = out + s_b * et.planes[k].astype(jnp.float32)
    if et.bias is not None:
        out = out + _expand_scale_dims(et.bias, ndim, et.per_channel)
    if et.sat is not None:
        out = out + et.sat
    return out


def residual(m: jnp.ndarray, et: ExpandedTensor, terms: Optional[int] = None) -> jnp.ndarray:
    return m.astype(jnp.float32) - reconstruct(et, terms)


def theoretical_residual_bound(et: ExpandedTensor) -> jnp.ndarray:
    """|residual| <= scale_n / 2: the ±2^{X-1} residual grid (ratio 2^X, X<8)
    or the halved ratio (X=8) make clamping impossible, so round-to-nearest's
    half-step bound is exact at every term."""
    last = jax.lax.index_in_dim(et.scales, et.num_terms - 1, axis=et.batch_dims, keepdims=False)
    return jnp.max(last) * 0.5


def auto_num_terms(scale1_max: float, bits: int, threshold: float = 1e-4, max_terms: int = 6) -> int:
    """Smallest n with scale_n/2 = scale_1/(2*ratio^{n-1}) < threshold (Fig 4b rule)."""
    n = 1
    while scale1_max / (2.0 * scale_ratio(bits) ** (n - 1)) >= threshold and n < max_terms:
        n += 1
    return n


def truncate(et: ExpandedTensor, terms: int) -> ExpandedTensor:
    """Drop trailing series terms (used by term-count ablations)."""
    t = min(terms, et.num_terms)
    bd = et.batch_dims
    return dataclasses.replace(
        et,
        planes=jax.lax.slice_in_dim(et.planes, 0, t, axis=bd),
        scales=jax.lax.slice_in_dim(et.scales, 0, t, axis=bd),
    )


def drop_sat(et: ExpandedTensor) -> ExpandedTensor:
    """Drop the saturation correction (paper §4: its loss influence is small)."""
    return dataclasses.replace(et, sat=None)


def pack(et: ExpandedTensor) -> ExpandedTensor:
    """INT4-pack the planes 2/byte over the last axis (kernels/pack.py).

    Requires bits <= 4 with values on the true X-bit grid [-8, 7] (expand
    with ``pack_safe=True``).  Odd last axes are padded by one zero nibble;
    the pad is recorded in ``pack_pad`` so ``unpack`` strips it exactly."""
    from repro.kernels.pack import pack_int4, pack_pad_nibbles

    if et.packed:
        return et
    if et.bits > 4:
        raise ValueError(f"cannot INT4-pack {et.bits}-bit planes")
    # default (non-pack-safe) extraction lets residual planes reach +2^{X-1}
    # (= +8 for X=4), which the nibble mask would silently wrap to -8 —
    # refuse rather than corrupt (the check is skipped under tracing; the
    # quantize-time callers pass concrete arrays)
    if not isinstance(et.planes, jax.core.Tracer):
        mx = int(jnp.max(et.planes)) if et.planes.size else 0
        if mx > 7:
            raise ValueError(
                f"planes reach +{mx}, outside the packable nibble grid "
                f"[-8, 7]; expand with pack_safe=True")
    cols = et.planes.shape[-1]
    return dataclasses.replace(
        et, planes=pack_int4(et.planes), packed=True,
        pack_pad=pack_pad_nibbles(cols))


def unpack(et: ExpandedTensor) -> ExpandedTensor:
    """Inverse of :func:`pack`: restore unpacked int8 planes (bit-exact)."""
    from repro.kernels.pack import unpack_int4

    if not et.packed:
        return et
    cols = et.planes.shape[-1] * 2 - et.pack_pad
    return dataclasses.replace(
        et, planes=unpack_int4(et.planes, orig_cols=cols), packed=False,
        pack_pad=0)
