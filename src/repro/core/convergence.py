"""Convergence accounting for the series expansion (Theorem 1/2 bounds).

The residual after n INT-X terms is bounded by ``scale_1 / (2 * 2^{X(n-1)})``
— exponential in ``n*X`` (total bits spent).  These helpers turn that bound
into term-count decisions (the paper's two stopping rules):

* activations: expand until ``max|residual| < 1e-4``  (Fig. 4b rule);
* weights:     stop once ``scale_n * 2^X < 1e-2``     (§4 total-differential
  rule — beyond that, W-error is invisible to the loss at first order).
"""
from __future__ import annotations

import math
from typing import Dict

import jax.numpy as jnp

from repro.core import expansion as E
from repro.core.expansion import ExpandedTensor


def residual_bound(scale1_max: float, bits: int, terms: int) -> float:
    """Upper bound on max|residual| after ``terms`` INT-``bits`` planes."""
    return scale1_max / (2.0 * E.scale_ratio(bits) ** (terms - 1))


def convergence_rate(bits: int) -> float:
    """Per-term geometric shrink factor: 1/ratio(X)."""
    return 1.0 / E.scale_ratio(bits)


def terms_for_threshold(scale1_max: float, bits: int, threshold: float = 1e-4,
                        max_terms: int = 6) -> int:
    """Fig. 4b rule: smallest n with residual bound < threshold."""
    return E.auto_num_terms(scale1_max, bits, threshold, max_terms)


def weight_terms_rule(scale1_max: float, bits: int, threshold: float = 1e-2,
                      max_terms: int = 3) -> int:
    """§4 rule: expand W while scale_n * 2^X >= threshold (then stop)."""
    n = 1
    ratio = E.scale_ratio(bits)
    while scale1_max * (2.0 ** bits) / (ratio ** (n - 1)) >= threshold and n < max_terms:
        n += 1
    return n


def measured_convergence(m: jnp.ndarray, bits: int, max_terms: int = 6,
                         **expand_kw) -> Dict[int, float]:
    """max|residual| per term count — empirical Fig. 4b curve for one tensor."""
    et = E.expand(m, bits, max_terms, **expand_kw)
    return {t: float(jnp.max(jnp.abs(E.residual(m, et, t)))) for t in range(1, max_terms + 1)}


def effective_bits(bits: int, terms: int) -> int:
    """Total information per element across the series (storage accounting)."""
    return bits * terms


def f32_noise_floor(absmax_val: float) -> float:
    """Expansion below the f32 ulp of the input is meaningless; used by tests
    to cap tolerance expectations (DESIGN.md §7)."""
    return absmax_val * 2.0 ** -22
