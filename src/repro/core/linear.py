"""Single-layer low-bit expansion (FP=xINT §3.2, Eq. 3/4).

Decompose  x = bias_a*1 + x~ + sigma_a   (center, clip)
           w = S_w + bias_w*M_nsy + W_sa (series + affine remainder)

with  S_w = sum_j sw_j * W_j  and  x~ the centered-clipped activation whose
series is Q(x~) = sum_i sa_i * A_i.  Then

  x @ w =  Q(x~) @ S_w                      <- SeriesGEMM  (INT8 MXU path)
         + rowsum(x~) (x) bias_w            <- rank-1 M_nsy fast path, O(n^2)
         + x~ @ W_sa                        <- sparse saturation correction
         + bias_a (x) colsum(w)             <- rank-1 (all-ones row), O(n^2)
         + sigma_a @ w                      <- activation clip overflow
         + [ (x~ - Q(x~)) @ S_w ]           <- DROPPED: the quantization error

Every kept term except SeriesGEMM is computed exactly from the FP activation
(available at runtime — activations are quantized dynamically), so the *only*
approximation is the exponentially-vanishing series residual — this is what
Theorem 1/2 convergence buys.  The rank-1 terms realize the paper's
"Computation Complexity of M_nsy Multiplication" O(n^2) analysis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import expansion as E
from repro.core.expansion import ExpandedTensor
from repro.core.policy import ExpansionPolicy
from repro.kernels import ops


def expand_weight(w: jnp.ndarray, policy: ExpansionPolicy, *, bits: Optional[int] = None,
                  terms: Optional[int] = None) -> ExpandedTensor:
    """Expand a (K, N) weight per policy (per-channel, symmetric, Laplace clip)."""
    return E.expand(
        w,
        bits if bits is not None else policy.w_bits,
        terms if terms is not None else policy.w_terms,
        symmetric=policy.w_symmetric,
        saturating=policy.w_saturating,
        per_channel=policy.w_per_channel,
        keep_sat=policy.keep_w_sat,
        pack_safe=policy.pack_safe,
    )


def series_colsum(w_et: ExpandedTensor) -> jnp.ndarray:
    """colsum over K of S_w = sum_j sw_j * W_j  ->  (N,)."""
    cs = jnp.sum(w_et.planes.astype(jnp.int32), axis=-2).astype(jnp.float32)  # (tw, N)
    scales = w_et.scales if w_et.per_channel else w_et.scales[:, None]
    return jnp.sum(scales * cs, axis=0)


def full_colsum(w_et: ExpandedTensor) -> jnp.ndarray:
    """colsum over K of the reconstructed w (series + bias*M_nsy + W_sa)."""
    k = w_et.orig_shape[-2]
    out = series_colsum(w_et)
    if w_et.bias is not None:
        out = out + float(k) * w_et.bias
    if w_et.sat is not None:
        out = out + jnp.sum(w_et.sat, axis=-2)
    return out


def _dynamic_act_params(x2d: jnp.ndarray, policy: ExpansionPolicy, a_bits: int):
    """Calibration-free per-batch activation quantizer: center, clip, scale1."""
    bias_a = None
    xc = x2d
    if not policy.a_symmetric:
        bias_a = (jnp.max(x2d) + jnp.min(x2d)) / 2.0
        xc = x2d - bias_a
    c = E.clip_bound(xc, a_bits, policy.a_saturating, per_channel=False)
    xt = jnp.clip(xc, -c, c)
    sigma = xc - xt if policy.keep_a_sat else None
    a_scale1 = E.first_scale(c, a_bits)
    return xt, bias_a, sigma, a_scale1


def expanded_apply(
    x: jnp.ndarray,
    w_et: ExpandedTensor,
    policy: ExpansionPolicy,
    *,
    a_bits: Optional[int] = None,
    a_terms: Optional[int] = None,
    use_kernel: bool = False,
    term_budget: Optional[int] = None,
) -> jnp.ndarray:
    """y = x @ w with w series-expanded and x dynamically expanded (Eq. 4).

    x: (..., K); w_et planes: (tw, K, N).  Returns (..., N) f32.
    ``a_terms == 0`` (or a_bits >= 16) selects the weight-only path (W4A16).
    ``term_budget`` serves the first k weight terms only — the Theorem-1
    prefix used as the self-speculative draft model (DESIGN.md §10); the
    affine corrections (bias/sat) are not series terms and always apply.
    """
    a_bits = a_bits if a_bits is not None else policy.a_bits
    a_terms = a_terms if a_terms is not None else policy.a_terms
    if term_budget is not None:
        w_et = E.truncate(w_et, term_budget)
    k, n = w_et.orig_shape[-2], w_et.orig_shape[-1]
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k).astype(jnp.float32)

    weight_only = a_terms <= 0 or a_bits >= 16
    # packed INT4 planes serve the weight-only Pallas GEMM directly (no
    # dequantized copy in HBM); every other path unpacks transparently
    if w_et.packed and not (weight_only and use_kernel and w_et.pack_pad == 0):
        w_et = E.unpack(w_et)

    if weight_only:
        # weight-only quantization: exact FP activation x reconstructed weight
        if w_et.packed:
            out = ops.packed_dequant_matmul(x2d, w_et.planes, w_et.scales)
        else:
            out = ops.dequant_matmul(
                x2d, w_et.planes, w_et.scales if w_et.per_channel else w_et.scales[:, None] * jnp.ones((1, n)))
        if w_et.bias is not None:
            out = out + jnp.sum(x2d, axis=-1, keepdims=True) * w_et.bias
        if w_et.sat is not None:
            out = out + x2d @ w_et.sat
        return out.reshape(*lead, n)

    xt, bias_a, sigma, a_scale1 = _dynamic_act_params(x2d, policy, a_bits)

    w_scales = w_et.scales if w_et.per_channel else jnp.broadcast_to(w_et.scales[:, None], (w_et.num_terms, n))
    out = ops.series_matmul(
        xt, a_scale1, w_et.planes, w_scales, a_bits=a_bits, a_terms=a_terms, use_kernel=use_kernel)

    # rank-1 M_nsy fast path:  x~ @ (bias_w * ones)  ==  rowsum(x~) (x) bias_w
    if w_et.bias is not None:
        out = out + jnp.sum(xt, axis=-1, keepdims=True) * w_et.bias
    # sparse saturation correction of the weight
    if w_et.sat is not None:
        out = out + xt @ w_et.sat
    # rank-1 all-ones row from the activation zero-point: bias_a (x) colsum(w)
    if bias_a is not None:
        out = out + bias_a * full_colsum(w_et)[None, :]
    # activation clip overflow (usually dropped per §4; kept only if configured)
    if sigma is not None:
        out = out + sigma @ E.reconstruct(w_et)
    return out.reshape(*lead, n)


def _grouped_epilogue(out: jnp.ndarray, xt: jnp.ndarray, bias_a, sigma,
                      w_et: ExpandedTensor) -> jnp.ndarray:
    """Eq. 4 affine corrections, batched over the leading expert axis —
    shared verbatim by the local grouped apply and the expert-parallel
    executor so the two stay bit-identical."""
    wv = w_et.unbatched_view()

    def _epi(out_e, xt_e, bias_a_e, sigma_e, we):
        if we.bias is not None:
            out_e = out_e + jnp.sum(xt_e, axis=-1, keepdims=True) * we.bias
        if we.sat is not None:
            out_e = out_e + xt_e @ we.sat
        if bias_a_e is not None:
            out_e = out_e + bias_a_e * full_colsum(we)[None, :]
        if sigma_e is not None:
            out_e = out_e + sigma_e @ E.reconstruct(we)
        return out_e

    return jax.vmap(_epi)(out, xt, bias_a, sigma, wv)


def grouped_expanded_apply(
    x: jnp.ndarray,
    w_et: ExpandedTensor,
    policy: ExpansionPolicy,
    *,
    a_bits: Optional[int] = None,
    a_terms: Optional[int] = None,
    use_kernel: bool = False,
    term_budget: Optional[int] = None,
) -> jnp.ndarray:
    """Batched (per-expert) twin of :func:`expanded_apply`.

    x: (E, M, K); ``w_et`` is a ``batch_dims == 1`` stacked expansion with
    planes (E, tw, K, N) — independent quantizers per expert
    (``expand_batched``).  Activation params are computed per expert
    (matching a Python loop of per-slice ``expanded_apply`` bit-for-bit),
    but the series GEMM runs as ONE grouped dispatch over the expert axis
    (``ops.grouped_series_matmul``), so the MXU dispatch count is O(terms),
    not O(E * terms).  Returns (E, M, N) f32."""
    if w_et.batch_dims != 1:
        raise ValueError(
            f"grouped_expanded_apply needs batch_dims=1, got {w_et}")
    a_bits = a_bits if a_bits is not None else policy.a_bits
    a_terms = a_terms if a_terms is not None else policy.a_terms
    if term_budget is not None:
        w_et = E.truncate(w_et, term_budget)
    if w_et.packed:
        w_et = E.unpack(w_et)
    e, m, k = x.shape
    n = w_et.orig_shape[-1]
    x32 = x.astype(jnp.float32)
    tw = w_et.num_terms

    if a_terms <= 0 or a_bits >= 16:
        # weight-only: exact FP activation x per-expert reconstructed weight
        wv = w_et.unbatched_view()

        def _one(xe, we):
            scales = we.scales if we.per_channel else \
                jnp.broadcast_to(we.scales[:, None], (we.num_terms, n))
            out_e = ops.dequant_matmul(xe, we.planes, scales)
            if we.bias is not None:
                out_e = out_e + jnp.sum(xe, axis=-1, keepdims=True) * we.bias
            if we.sat is not None:
                out_e = out_e + xe @ we.sat
            return out_e

        return jax.vmap(_one)(x32, wv)

    xt, bias_a, sigma, a_scale1 = jax.vmap(
        lambda xe: _dynamic_act_params(xe, policy, a_bits))(x32)

    w_scales = w_et.scales if w_et.per_channel else \
        jnp.broadcast_to(w_et.scales[..., None], (e, tw, n))
    out = ops.grouped_series_matmul(
        xt, a_scale1, w_et.planes, w_scales,
        a_bits=a_bits, a_terms=a_terms, use_kernel=use_kernel)
    return _grouped_epilogue(out, xt, bias_a, sigma, w_et)


def dense(x: jnp.ndarray, w, policy: Optional[ExpansionPolicy] = None, **kw) -> jnp.ndarray:
    """Dispatch: ExpandedTensor -> expanded_apply; plain array -> x @ w."""
    if isinstance(w, ExpandedTensor):
        assert policy is not None, "expanded weight needs an ExpansionPolicy"
        return expanded_apply(x, w, policy, **kw)
    return jnp.dot(x, w)
