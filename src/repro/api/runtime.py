"""Runtime: bind a QuantArtifact to an execution backend and run it.

The backend names replace the ad-hoc ``QuantContext(use_kernel=...)``
plumbing that previously leaked into every caller:

  * ``ref``            pure-jnp reference path (XLA-fused; CPU-friendly);
  * ``pallas``         the Pallas kernels (interpret on CPU, Mosaic on TPU);
  * ``pallas-packed``  Pallas with INT4-packed weight planes served in place
                       (requires an artifact built with ``pack=True``).

A Runtime resolves the model config from the artifact's recorded ``arch``
(or takes one explicitly), jits the forward once, and exposes

  * ``apply(batch)``   full-sequence logits,
  * ``lm_loss(batch)`` next-token loss + accuracy metrics,
  * ``serve(...)``     a serving :class:`~repro.infer.serve.Engine` admitted
                       by artifact — the model is expanded once per process
                       (at quantize time), never re-expanded per engine.
                       Serves with slot-based continuous batching by default
                       (``ServeConfig(scheduler="slots")``): variable-length
                       prompts prefill into free decode slots, EOS recycles
                       slots mid-stream; ``scheduler="grouped"`` keeps the
                       legacy group-drain path for bit-exactness baselines.

Multi-device serving (DESIGN.md §9): ``Runtime(artifact, mesh=...,
placement=...)`` binds the artifact *placed* over a 1-D device mesh —
``"term"`` scatters series terms (Theorem-2 expansion parallelism, one psum
per expanded GEMM), ``"tensor"`` shards output-feature columns, ``"expert"``
shards stacked MoE expert expansions over an ``"expert"`` axis (grouped
series GEMM + int32 psum, DESIGN.md §15), and ``"replicated"`` (the
default) keeps the single-device layout.  The
placement defaults from ``recipe.placement``; ``apply``/``lm_loss``/
``serve`` all run under it.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.artifact import QuantArtifact
from repro.configs.base import ArchConfig, get_arch
from repro.dist.placement import check_placement, make_serve_mesh, place_params

PyTree = Any

BACKENDS = ("ref", "pallas", "pallas-packed")


class Runtime:
    def __init__(self, artifact: QuantArtifact, backend: str = "ref",
                 cfg: Optional[ArchConfig] = None, *,
                 mesh: Optional[Any] = None,
                 placement: Optional[str] = None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
        placement = check_placement(
            placement if placement is not None else artifact.recipe.placement)
        if placement != "replicated":
            if backend != "ref":
                # Pallas interpret-mode callbacks cannot be partitioned (and
                # term scattering unpacks nibble planes anyway) — the sharded
                # placements serve the pure-jnp path; on real TPUs the Mosaic
                # kernels can lift this restriction per-shard
                raise ValueError(
                    f"placement={placement!r} serves backend='ref' only "
                    f"(got {backend!r}); see DESIGN.md §9")
            if placement == "term" and not artifact.expanded:
                raise ValueError(
                    f"placement='term' distributes series terms; method "
                    f"{artifact.method!r} has no term axis — use 'tensor'")
            if placement == "expert" and not artifact.expanded:
                raise ValueError(
                    f"placement='expert' shards stacked expert expansions; "
                    f"method {artifact.method!r} has no expansion to shard "
                    f"— use 'tensor'")
            if mesh is None:
                mesh = make_serve_mesh(0, placement)
        self.artifact = artifact
        self.backend = backend
        self.mesh = mesh
        self.placement = placement
        qc = artifact.quant_context(backend)
        if placement in ("term", "expert"):
            qc = dataclasses.replace(qc, mesh=mesh, placement=placement)
        self.qc = qc
        self.params = place_params(artifact.runtime_params(backend), mesh,
                                   placement)
        if cfg is None and artifact.arch is not None:
            cfg = get_arch(artifact.arch, smoke=artifact.recipe.smoke)
        self.cfg = cfg

    def _require_cfg(self) -> ArchConfig:
        if self.cfg is None:
            raise ValueError(
                "this artifact records no model arch; pass cfg=ArchConfig to "
                "Runtime (or set QuantRecipe(arch=...) at quantize time)")
        return self.cfg

    # -- execution ----------------------------------------------------------
    @cached_property
    def _forward(self):
        from repro.models import model as M
        cfg, qc = self._require_cfg(), self.qc
        return jax.jit(lambda p, batch: M.forward(p, batch, cfg, qc))

    @staticmethod
    def _as_batch(batch) -> Dict[str, jnp.ndarray]:
        if isinstance(batch, dict):
            return {k: jnp.asarray(v) for k, v in batch.items()}
        return {"tokens": jnp.asarray(batch)}

    def apply(self, batch) -> jnp.ndarray:
        """Full-sequence logits (B, S, V); ``batch`` is a dict or a raw
        (B, S) token array."""
        return self._forward(self.params, self._as_batch(batch))

    def lm_loss(self, batch, term_budget: Optional[int] = None
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Next-token loss + metrics on a batch with ``labels``.

        ``term_budget`` evaluates the loss under a truncated series context
        (Theorem 1: the first K terms are a coherent lower-bit model) — the
        quality axis of the QoS loss-vs-load tables (``benchmarks/
        qos_bench.py``).  ``None`` = the artifact's full context."""
        from repro.train.train_step import loss_fn
        qc = self.qc
        if term_budget is not None:
            if term_budget < 1:
                raise ValueError(
                    f"term_budget must be >= 1, got {term_budget}")
            if not self.artifact.expanded:
                raise ValueError(
                    f"term_budget truncates the series term axis; method "
                    f"{self.artifact.method!r} has no term axis")
            qc = dataclasses.replace(qc, term_budget=int(term_budget))
        return loss_fn(self.params, self._as_batch(batch),
                       self._require_cfg(), qc)

    def serve(self, serve_cfg=None, **engine_kw):
        """A serving Engine admitted by this artifact (no re-expansion),
        under this Runtime's mesh/placement.  ``serve_cfg`` selects the
        scheduler: ``"slots"`` (default, continuous batching with per-slot
        cache lengths) or ``"grouped"`` (legacy group-drain).

        ``recipe.spec_terms`` (recorded self-speculative intent, DESIGN.md
        §10) and ``recipe.qos_tiers`` (recorded QoS ladder, DESIGN.md §11)
        apply when the ``ServeConfig`` doesn't set its own ``spec_terms`` /
        ``tier_budgets`` — the same intent-then-override pattern as
        ``recipe.placement``."""
        from repro.infer.serve import Engine, ServeConfig
        sc = serve_cfg or ServeConfig()
        if sc.spec_terms == 0 and self.artifact.recipe.spec_terms > 0 \
                and sc.scheduler == "slots":
            sc = dataclasses.replace(
                sc, spec_terms=self.artifact.recipe.spec_terms)
        if sc.tier_budgets is None \
                and self.artifact.recipe.qos_tiers is not None \
                and sc.scheduler == "slots" and sc.spec_terms == 0:
            sc = dataclasses.replace(
                sc, tier_budgets=self.artifact.recipe.qos_tiers)
        return Engine(self._require_cfg(), artifact=self.artifact,
                      backend=self.backend, mesh=self.mesh,
                      placement=self.placement,
                      serve_cfg=sc,
                      _bound_params=self.params, **engine_kw)

    def __repr__(self):
        arch = self.cfg.name if self.cfg is not None else None
        return (f"Runtime(method={self.artifact.method!r}, "
                f"backend={self.backend!r}, arch={arch!r}, "
                f"placement={self.placement!r})")
