"""Runtime: bind a QuantArtifact to an execution backend and run it.

The backend names replace the ad-hoc ``QuantContext(use_kernel=...)``
plumbing that previously leaked into every caller:

  * ``ref``            pure-jnp reference path (XLA-fused; CPU-friendly);
  * ``pallas``         the Pallas kernels (interpret on CPU, Mosaic on TPU);
  * ``pallas-packed``  Pallas with INT4-packed weight planes served in place
                       (requires an artifact built with ``pack=True``).

A Runtime resolves the model config from the artifact's recorded ``arch``
(or takes one explicitly), jits the forward once, and exposes

  * ``apply(batch)``   full-sequence logits,
  * ``lm_loss(batch)`` next-token loss + accuracy metrics,
  * ``serve(...)``     a serving :class:`~repro.infer.serve.Engine` admitted
                       by artifact — the model is expanded once per process
                       (at quantize time), never re-expanded per engine.
                       Serves with slot-based continuous batching by default
                       (``ServeConfig(scheduler="slots")``): variable-length
                       prompts prefill into free decode slots, EOS recycles
                       slots mid-stream; ``scheduler="grouped"`` keeps the
                       legacy group-drain path for bit-exactness baselines.
"""
from __future__ import annotations

from functools import cached_property
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.artifact import QuantArtifact
from repro.configs.base import ArchConfig, get_arch

PyTree = Any

BACKENDS = ("ref", "pallas", "pallas-packed")


class Runtime:
    def __init__(self, artifact: QuantArtifact, backend: str = "ref",
                 cfg: Optional[ArchConfig] = None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
        self.artifact = artifact
        self.backend = backend
        self.qc = artifact.quant_context(backend)
        self.params = artifact.runtime_params(backend)
        if cfg is None and artifact.arch is not None:
            cfg = get_arch(artifact.arch, smoke=artifact.recipe.smoke)
        self.cfg = cfg

    def _require_cfg(self) -> ArchConfig:
        if self.cfg is None:
            raise ValueError(
                "this artifact records no model arch; pass cfg=ArchConfig to "
                "Runtime (or set QuantRecipe(arch=...) at quantize time)")
        return self.cfg

    # -- execution ----------------------------------------------------------
    @cached_property
    def _forward(self):
        from repro.models import model as M
        cfg, qc = self._require_cfg(), self.qc
        return jax.jit(lambda p, batch: M.forward(p, batch, cfg, qc))

    @staticmethod
    def _as_batch(batch) -> Dict[str, jnp.ndarray]:
        if isinstance(batch, dict):
            return {k: jnp.asarray(v) for k, v in batch.items()}
        return {"tokens": jnp.asarray(batch)}

    def apply(self, batch) -> jnp.ndarray:
        """Full-sequence logits (B, S, V); ``batch`` is a dict or a raw
        (B, S) token array."""
        return self._forward(self.params, self._as_batch(batch))

    def lm_loss(self, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Next-token loss + metrics on a batch with ``labels``."""
        from repro.train.train_step import loss_fn
        return loss_fn(self.params, self._as_batch(batch),
                       self._require_cfg(), self.qc)

    def serve(self, serve_cfg=None, **engine_kw):
        """A serving Engine admitted by this artifact (no re-expansion).
        ``serve_cfg`` selects the scheduler: ``"slots"`` (default,
        continuous batching with per-slot cache lengths) or ``"grouped"``
        (legacy group-drain)."""
        from repro.infer.serve import Engine, ServeConfig
        return Engine(self._require_cfg(), artifact=self.artifact,
                      backend=self.backend,
                      serve_cfg=serve_cfg or ServeConfig(), **engine_kw)

    def __repr__(self):
        arch = self.cfg.name if self.cfg is not None else None
        return (f"Runtime(method={self.artifact.method!r}, "
                f"backend={self.backend!r}, arch={arch!r})")
