"""QuantRecipe: the declarative *what* of quantization (method + policy).

The unified entry point is

    artifact = quantize(params, QuantRecipe(method="fpxint", policy=W4A4))

Every registered method — ``fpxint`` (the paper's series expansion), ``rtn``
(round-to-nearest min-max PTQ) and ``gptq_lite`` (error-propagating one-shot
PTQ) — consumes the same recipe and produces the same
:class:`~repro.api.artifact.QuantArtifact`, so the Tables 1–6 comparisons
all run through one code path.  Methods register via
:func:`register_quantizer`; a :class:`Quantizer` maps
``(params, recipe) -> (quantized params, provenance dict)``.

Recipes are frozen/hashable and JSON round-trip (``recipe_to_dict`` /
``recipe_from_dict``) so an artifact on disk records exactly how it was made.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Tuple

from repro.core.policy import ExpansionPolicy, get_policy

PyTree = Any


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """Declarative quantization request.

    Attributes:
      method: registry key (``fpxint`` | ``rtn`` | ``gptq_lite`` | plugins).
      policy: the :class:`ExpansionPolicy` — ``fpxint`` uses all of it;
              baseline methods read ``w_bits`` (their activation handling is
              dynamic/FP by construction, matching the paper's tables).
      pack:   INT4-pack the weight planes 2/byte (``fpxint`` with
              ``w_bits <= 4`` leaves; forces pack-safe extraction so planes
              stay on the packable grid).
      arch:   optional ArchConfig id recorded for :class:`Runtime` model ops
              (``apply`` / ``lm_loss`` / ``serve``); tensor-only use leaves
              it None.
      smoke:  whether ``arch`` refers to the smoke-scaled config.
      placement: default multi-device placement a Runtime binds this
              artifact under (``replicated`` | ``term`` | ``tensor`` |
              ``expert``, see DESIGN.md §9 and §15) — recorded intent;
              ``Runtime(placement=...)`` overrides it per deployment.
      spec_terms: default self-speculative draft budget (DESIGN.md §10):
              serve with the first K series terms as the draft model,
              verified by the full series.  Recorded intent like
              ``placement`` — ``Runtime.serve`` applies it when the
              ``ServeConfig`` doesn't choose; 0 = no speculation.  Only
              meaningful for ``fpxint`` (the baselines have no term axis).
      qos_tiers: default QoS tier ladder (DESIGN.md §11) as
              ``((name, term_budget), ...)``, e.g. ``(("k2", 2), ("k1", 1))``
              — the degraded qualities ``Engine.add_request(quality=...)``
              accepts next to the implicit ``"full"``.  Recorded intent like
              ``spec_terms``: ``Runtime.serve`` threads it into
              ``ServeConfig.tier_budgets`` when the config doesn't choose.
              ``None`` = serve the engine default ladder.  Only meaningful
              for ``fpxint`` (degraded tiers truncate the term axis).
      calib_batch / calib_seed: synthetic-calibration knobs for the
              calibrated-PTQ stand-in (``gptq_lite``).
    """

    method: str = "fpxint"
    policy: ExpansionPolicy = ExpansionPolicy()
    pack: bool = False
    arch: Optional[str] = None
    smoke: bool = True
    placement: str = "replicated"
    spec_terms: int = 0
    qos_tiers: Optional[Tuple[Tuple[str, int], ...]] = None
    calib_batch: int = 32
    calib_seed: int = 0

    def __post_init__(self):
        if self.method not in QUANTIZERS:
            raise KeyError(
                f"unknown quantization method {self.method!r}; "
                f"registered: {sorted(QUANTIZERS)}")
        from repro.dist.placement import check_placement
        check_placement(self.placement)
        if self.placement == "term" and self.method != "fpxint":
            raise ValueError(
                f"placement='term' distributes series terms; method "
                f"{self.method!r} produces plain FP reconstructions with no "
                f"term axis (use placement='tensor' or 'replicated')")
        if self.placement == "expert" and self.method != "fpxint":
            raise ValueError(
                f"placement='expert' shards stacked expert expansions over "
                f"the grouped series GEMM; method {self.method!r} produces "
                f"plain FP reconstructions with no expansion to shard "
                f"(use placement='tensor' or 'replicated')")
        if self.spec_terms < 0:
            raise ValueError(f"spec_terms must be >= 0, got {self.spec_terms}")
        if self.spec_terms > 0 and self.method != "fpxint":
            raise ValueError(
                f"spec_terms>0 drafts with a truncated series; method "
                f"{self.method!r} produces plain FP reconstructions with no "
                f"term axis to truncate")
        if self.qos_tiers is not None:
            # Normalize first (JSON round-trips tuples as lists): hashable
            # tuple-of-(str, int) regardless of how the ladder was spelled.
            object.__setattr__(self, "qos_tiers", tuple(
                (str(n), int(b)) for n, b in self.qos_tiers))
            if self.method != "fpxint":
                raise ValueError(
                    f"qos_tiers serves truncated-series qualities; method "
                    f"{self.method!r} produces plain FP reconstructions with "
                    f"no term axis to truncate")
            if self.spec_terms > 0:
                raise ValueError(
                    "qos_tiers and spec_terms>0 are mutually exclusive: "
                    "both spend the series term axis (pick one per recipe)")
            for entry in self.qos_tiers:
                name, budget = entry
                if name == "full" or int(budget) < 1:
                    raise ValueError(
                        f"qos_tiers entries must be (name, term_budget>=1) "
                        f"with name != 'full' (implicit); got {entry!r}")
        if self.pack:
            if self.method != "fpxint":
                raise ValueError(
                    f"pack=True applies to series expansions only; method "
                    f"{self.method!r} produces FP reconstructions")
            if self.policy.w_bits > 4:
                raise ValueError(
                    f"pack=True needs w_bits <= 4 (got {self.policy.w_bits})")


class Quantizer(Protocol):
    """A registered quantization method: params -> (quantized params, extra
    provenance merged into the artifact's ``meta``)."""

    def __call__(self, params: PyTree, recipe: QuantRecipe
                 ) -> Tuple[PyTree, Dict[str, Any]]: ...


QUANTIZERS: Dict[str, Quantizer] = {}


def register_quantizer(name: str) -> Callable[[Quantizer], Quantizer]:
    """Decorator: add a method to the registry (last registration wins)."""
    def deco(fn: Quantizer) -> Quantizer:
        QUANTIZERS[name] = fn
        return fn
    return deco


def get_quantizer(name: str) -> Quantizer:
    try:
        return QUANTIZERS[name]
    except KeyError:
        raise KeyError(f"unknown quantization method {name!r}; "
                       f"registered: {sorted(QUANTIZERS)}") from None


def list_methods() -> Tuple[str, ...]:
    return tuple(sorted(QUANTIZERS))


# ---------------------------------------------------------------------------
# JSON round-trip (artifact manifest)
# ---------------------------------------------------------------------------
def recipe_to_dict(recipe: QuantRecipe) -> Dict[str, Any]:
    d = dataclasses.asdict(recipe)
    d["policy"] = dataclasses.asdict(recipe.policy)
    return d


def recipe_from_dict(d: Dict[str, Any]) -> QuantRecipe:
    pd = dict(d["policy"])
    if pd.get("mixed") is not None:
        pd["mixed"] = tuple((str(k), tuple(int(b) for b in bits))
                            for k, bits in pd["mixed"])
    kw = {k: v for k, v in d.items() if k != "policy"}
    return QuantRecipe(policy=ExpansionPolicy(**pd), **kw)


def named_recipe(policy_name: str, method: str = "fpxint", **kw) -> QuantRecipe:
    """Convenience: recipe from a canonical policy name (``w4a4`` etc.)."""
    return QuantRecipe(method=method, policy=get_policy(policy_name), **kw)


# ---------------------------------------------------------------------------
# built-in methods
# ---------------------------------------------------------------------------
@register_quantizer("fpxint")
def _fpxint(params: PyTree, recipe: QuantRecipe) -> Tuple[PyTree, Dict[str, Any]]:
    """The paper's calibration-free series expansion (Theorems 1/2)."""
    import jax

    from repro.core import expansion as E
    from repro.core import ptq as PTQ
    from repro.core.expansion import ExpandedTensor

    policy = recipe.policy
    if recipe.pack and not policy.pack_safe:
        policy = dataclasses.replace(policy, pack_safe=True)
    q = jax.jit(lambda p: PTQ.expand_params(p, policy))(params)
    q = jax.block_until_ready(q)
    if recipe.pack:
        q = jax.tree_util.tree_map(
            lambda l: E.pack(l) if isinstance(l, ExpandedTensor) and l.bits <= 4 else l,
            q, is_leaf=lambda l: isinstance(l, ExpandedTensor))
    return q, {"expanded": True, "pack_safe": policy.pack_safe}


@register_quantizer("rtn")
def _rtn(params: PyTree, recipe: QuantRecipe) -> Tuple[PyTree, Dict[str, Any]]:
    """Round-to-nearest min-max PTQ — Table 6's 'Normal' row.  Produces plain
    FP reconstructions (weight-only), served through the FP apply path."""
    import jax

    from repro.quant.baselines import rtn_quantize_params

    q = jax.block_until_ready(rtn_quantize_params(params, recipe.policy.w_bits))
    return q, {"expanded": False, "weight_only": True}


@register_quantizer("gptq_lite")
def _gptq_lite(params: PyTree, recipe: QuantRecipe) -> Tuple[PyTree, Dict[str, Any]]:
    """One-shot error-propagating PTQ (the calibrated-PTQ family stand-in)."""
    import jax

    from repro.quant.baselines import gptq_lite_quantize_params

    q = jax.block_until_ready(gptq_lite_quantize_params(
        params, recipe.policy.w_bits, calib_batch=recipe.calib_batch,
        seed=recipe.calib_seed))
    return q, {"expanded": False, "weight_only": True,
               "calib_batch": recipe.calib_batch}
