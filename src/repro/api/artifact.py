"""QuantArtifact: the portable product of quantization (expand once, serve
forever).

An artifact bundles the quantized parameter pytree (``ExpandedTensor``
series leaves for ``fpxint``, plain FP reconstructions for the baselines),
the :class:`~repro.api.recipe.QuantRecipe` that produced it, and provenance
metadata (per-leaf bits/terms, quantization wall-time, size accounting).

On-disk format (§8 of DESIGN.md), built on the atomic extension-dtype-safe
npz machinery in ``dist/checkpoint.py``:

    <path>/
      artifact.npz     one entry per array, keyed "a<i>" (plain leaves) or
                       "a<i>/planes|scales|bias|sat" (expanded leaves), each
                       written through ``checkpoint.encode_array`` so bf16 &
                       fp8 leaves survive npz;
      manifest.json    format version, the recipe (JSON round-trip), meta,
                       and an ordered leaf table: tree path + leaf kind +
                       the ExpandedTensor statics (bits, per_channel,
                       batch_dims, packed, pack_pad, has_bias, has_sat);
      .DONE            commit marker, written last (a crash mid-save leaves
                       an ignorable uncommitted directory).

Saves stage into ``<path>.tmp`` and publish with a replace-rename
(``checkpoint.atomic_commit_dir``), so readers never observe a torn
artifact.  INT4-packed planes are stored packed — the disk artifact is the
same 2-nibbles-per-byte representation the ``pallas-packed`` runtime serves.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.api.recipe import QuantRecipe, recipe_from_dict, recipe_to_dict
from repro.core import expansion as E
from repro.core.expansion import ExpandedTensor
from repro.dist import checkpoint as CKPT

PyTree = Any

FORMAT_VERSION = 1
_NPZ = "artifact.npz"
_MANIFEST = "manifest.json"
_DONE = ".DONE"

_ET_FIELDS = ("planes", "scales", "bias", "sat")


# ---------------------------------------------------------------------------
# pytree <-> ordered leaf table (dict/list/tuple nesting, ET-aware)
# ---------------------------------------------------------------------------
def _flatten(tree: PyTree, path: Tuple = ()) -> List[Tuple[Tuple, Any]]:
    if isinstance(tree, ExpandedTensor) or not isinstance(tree, (dict, list, tuple)):
        return [(path, tree)]
    if not tree:  # empty container: keep as a structural leaf so the tree
        return [(path, tree)]  # round-trips with identical pytree structure
    out: List[Tuple[Tuple, Any]] = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flatten(tree[k], path + (("k", k),)))
    else:
        tag = "t" if isinstance(tree, tuple) else "i"
        for i, v in enumerate(tree):
            out.extend(_flatten(v, path + ((tag, i),)))
    return out


def _unflatten(entries: List[Tuple[Tuple, Any]]) -> PyTree:
    if len(entries) == 1 and entries[0][0] == ():
        return entries[0][1]
    root: Dict = {}
    for path, leaf in entries:
        node = root
        for step in path[:-1]:
            node = node.setdefault(tuple(step), {})
        node[tuple(path[-1])] = leaf

    def materialize(node):
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if not keys:       # an empty-dict structural leaf, not an inner node
            return {}      # (inner nodes always carry at least one child)
        tag = keys[0][0]
        if tag == "k":
            return {k[1]: materialize(v) for k, v in node.items()}
        seq = [materialize(node[(tag, i)]) for i in range(len(keys))]
        return tuple(seq) if tag == "t" else seq

    return materialize(root)


def _path_str(path: Tuple) -> str:
    return "/".join(str(p[1]) for p in path)


# ---------------------------------------------------------------------------
# the artifact
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class QuantArtifact:
    """Quantized params + recipe + provenance; save/load round-trips
    bit-exactly (tested contract)."""

    params: PyTree
    recipe: QuantRecipe
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- introspection ------------------------------------------------------
    @property
    def method(self) -> str:
        return self.recipe.method

    @property
    def policy(self):
        return self.recipe.policy

    @property
    def arch(self) -> Optional[str]:
        return self.recipe.arch

    @property
    def expanded(self) -> bool:
        """True when params carry ExpandedTensor series leaves (fpxint)."""
        return bool(self.meta.get("expanded", False))

    @property
    def packed(self) -> bool:
        return any(isinstance(l, ExpandedTensor) and l.packed
                   for l in jax.tree_util.tree_leaves(
                       self.params, is_leaf=lambda l: isinstance(l, ExpandedTensor)))

    @property
    def quant_seconds(self) -> float:
        return float(self.meta.get("quant_seconds", 0.0))

    def leaf_table(self) -> Dict[str, Dict[str, Any]]:
        """Per-leaf provenance: path -> {bits, terms, shape, packed} for every
        expanded leaf (empty for baseline FP-reconstruction artifacts)."""
        out: Dict[str, Dict[str, Any]] = {}
        for path, leaf in _flatten(self.params):
            if isinstance(leaf, ExpandedTensor):
                out[_path_str(path)] = {
                    "bits": leaf.bits, "terms": leaf.num_terms,
                    "shape": list(leaf.orig_shape), "packed": leaf.packed,
                    "batch_dims": leaf.batch_dims,
                }
        return out

    def reconstructed(self) -> PyTree:
        """FP view: every expanded leaf summed back to a dense tensor."""
        return jax.tree_util.tree_map(
            lambda l: E.reconstruct(l) if isinstance(l, ExpandedTensor) else l,
            self.params, is_leaf=lambda l: isinstance(l, ExpandedTensor))

    # -- runtime binding (used by Runtime and the serve engine) -------------
    def quant_context(self, backend: str = "ref"):
        """The QuantContext a backend serves this artifact under."""
        from repro.models.layers import FP, QuantContext

        if not self.expanded:
            if backend != "ref":
                raise ValueError(
                    f"method {self.method!r} produces FP reconstructions; "
                    f"only backend='ref' applies (got {backend!r})")
            return FP
        return QuantContext(policy=self.policy, use_kernel=backend != "ref")

    def runtime_params(self, backend: str = "ref") -> PyTree:
        """Params as the backend consumes them: ``pallas-packed`` serves the
        INT4-packed planes in place; other backends unpack once at bind."""
        if backend == "pallas-packed":
            if not self.packed:
                raise ValueError(
                    "backend='pallas-packed' needs a packed artifact "
                    "(quantize with QuantRecipe(pack=True))")
            if self.policy.a_terms > 0 and self.policy.a_bits < 16:
                # the series (activation-quantized) GEMM consumes unpacked
                # planes, so binding packed params would re-unpack every
                # weight inside the jitted forward on every call — use
                # 'pallas' (unpack once at bind) for W_xA_y policies;
                # pallas-packed is the weight-only (W4A16) serving backend
                raise ValueError(
                    "backend='pallas-packed' serves weight-only policies "
                    f"(a_terms == 0 or a_bits >= 16); this artifact is "
                    f"w{self.policy.w_bits}a{self.policy.a_bits} with "
                    f"a_terms={self.policy.a_terms} — use backend='pallas'")
            # odd-width (pad-nibble) leaves can't ride the packed GEMM;
            # unpack those once here rather than per call inside the jit
            return jax.tree_util.tree_map(
                lambda l: (E.unpack(l) if isinstance(l, ExpandedTensor)
                           and l.packed and l.pack_pad else l),
                self.params, is_leaf=lambda l: isinstance(l, ExpandedTensor))
        if not self.packed:
            return self.params
        return jax.tree_util.tree_map(
            lambda l: E.unpack(l) if isinstance(l, ExpandedTensor) else l,
            self.params, is_leaf=lambda l: isinstance(l, ExpandedTensor))

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> str:
        """Write the artifact directory atomically; returns ``path``."""
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        tmp = path.rstrip("/") + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        arrays: Dict[str, np.ndarray] = {}
        leaves: List[Dict[str, Any]] = []
        for idx, (path_t, leaf) in enumerate(_flatten(self.params)):
            key = f"a{idx}"
            entry: Dict[str, Any] = {"path": [list(p) for p in path_t]}
            if isinstance(leaf, ExpandedTensor):
                entry["kind"] = "expanded"
                entry.update(bits=leaf.bits, per_channel=leaf.per_channel,
                             batch_dims=leaf.batch_dims, packed=leaf.packed,
                             pack_pad=leaf.pack_pad,
                             has_bias=leaf.bias is not None,
                             has_sat=leaf.sat is not None)
                for f in _ET_FIELDS:
                    v = getattr(leaf, f)
                    if v is not None:
                        CKPT.encode_array(f"{key}/{f}",
                                          np.asarray(jax.device_get(v)), arrays)
            elif leaf is None:
                entry["kind"] = "none"
            elif isinstance(leaf, (dict, list, tuple)):
                if leaf:  # _flatten only leaves empty containers whole
                    raise ValueError(
                        f"unflattened non-empty container at {key!r}: {type(leaf).__name__}")
                entry["kind"] = "empty"
                entry["container"] = ("dict" if isinstance(leaf, dict)
                                      else "tuple" if isinstance(leaf, tuple)
                                      else "list")
            else:
                entry["kind"] = "array"
                CKPT.encode_array(key, np.asarray(jax.device_get(leaf)), arrays)
            leaves.append(entry)

        manifest = {
            "format_version": FORMAT_VERSION,
            "recipe": recipe_to_dict(self.recipe),
            "meta": self.meta,
            "leaves": leaves,
        }
        CKPT.write_npz(os.path.join(tmp, _NPZ), arrays)
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        CKPT.atomic_commit_dir(tmp, path, _DONE)
        return path

    @classmethod
    def load(cls, path: str) -> "QuantArtifact":
        """Load a committed artifact; bit-exact inverse of :meth:`save`."""
        if not os.path.exists(os.path.join(path, _DONE)):
            raise FileNotFoundError(f"no committed artifact at {path}")
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
        version = manifest.get("format_version")
        if version != FORMAT_VERSION:
            raise ValueError(f"artifact format {version} != {FORMAT_VERSION}")
        recipe = recipe_from_dict(manifest["recipe"])
        entries: List[Tuple[Tuple, Any]] = []
        with np.load(os.path.join(path, _NPZ)) as data:
            for idx, entry in enumerate(manifest["leaves"]):
                key = f"a{idx}"
                path_t = tuple((p[0], p[1]) for p in entry["path"])
                kind = entry["kind"]
                if kind == "none":
                    leaf = None
                elif kind == "empty":
                    leaf = {"dict": {}, "list": [], "tuple": ()}[entry["container"]]
                elif kind == "array":
                    leaf = jax.numpy.asarray(CKPT.decode_array(key, data))
                else:
                    fields = {f: (jax.numpy.asarray(CKPT.decode_array(f"{key}/{f}", data))
                                  if f"{key}/{f}" in data.files else None)
                              for f in _ET_FIELDS}
                    leaf = ExpandedTensor(
                        planes=fields["planes"], scales=fields["scales"],
                        bias=fields["bias"], sat=fields["sat"],
                        bits=int(entry["bits"]),
                        per_channel=bool(entry["per_channel"]),
                        batch_dims=int(entry["batch_dims"]),
                        packed=bool(entry["packed"]),
                        pack_pad=int(entry["pack_pad"]))
                entries.append((path_t, leaf))
        return cls(params=_unflatten(entries), recipe=recipe,
                   meta=manifest["meta"])


# ---------------------------------------------------------------------------
# Recipe -> Artifact
# ---------------------------------------------------------------------------
def quantize(params: PyTree, recipe: QuantRecipe) -> QuantArtifact:
    """The single quantization entry point: run the recipe's registered
    method over ``params`` and package the result with provenance.

    Wall-time of the method call is the paper's 'Quant-Time' (Tables 2/3);
    size accounting comes from ``ptq.expansion_stats`` (Table 3)."""
    import time

    from repro.api.recipe import get_quantizer
    from repro.core.ptq import expansion_stats

    fn = get_quantizer(recipe.method)
    t0 = time.perf_counter()
    qparams, extra = fn(params, recipe)
    seconds = time.perf_counter() - t0
    # format_version and the per-leaf statics live in the manifest (save()
    # writes them; leaf_table() derives them on demand) — meta holds only
    # what the manifest does not already record
    meta = {
        "method": recipe.method,
        "quant_seconds": seconds,
        "expansion_stats": expansion_stats(qparams),
        **extra,
    }
    return QuantArtifact(params=qparams, recipe=recipe, meta=meta)
