"""The unified quantization API: Recipe -> Artifact -> Runtime.

    from repro.api import QuantRecipe, Runtime, quantize
    from repro.core.policy import W4A4

    art = quantize(params, QuantRecipe(method="fpxint", policy=W4A4,
                                       arch="qwen2_1_5b"))
    art.save("artifacts/qwen2_w4a4")           # expand once ...
    art = QuantArtifact.load("artifacts/qwen2_w4a4")
    rt = Runtime(art, backend="ref")           # ... serve the INT series forever
    logits = rt.apply(tokens)
    engine = rt.serve()

All registered methods (``fpxint`` series expansion, ``rtn``, ``gptq_lite``)
produce the same artifact type; ``repro.core.*`` stays the stable low-level
layer this package composes.

Multi-device serving: ``Runtime(art, mesh=make_serve_mesh(n, placement),
placement="term"|"tensor")`` binds the artifact scattered over a 1-D
device mesh (DESIGN.md §9; ``repro.dist.placement``).
"""
from repro.api.artifact import QuantArtifact, quantize
from repro.api.recipe import (QuantRecipe, Quantizer, get_quantizer,
                              list_methods, named_recipe, recipe_from_dict,
                              recipe_to_dict, register_quantizer)
from repro.api.runtime import BACKENDS, Runtime

__all__ = [
    "QuantRecipe", "QuantArtifact", "Runtime", "Quantizer", "BACKENDS",
    "quantize", "register_quantizer", "get_quantizer", "list_methods",
    "named_recipe", "recipe_to_dict", "recipe_from_dict",
]
