"""Gradient compression via the paper's residual-series codec (beyond-paper).

Theorem 1 reused as a comms compressor: each gradient leaf is expanded into
``terms`` INT-``bits`` planes (error bounded by scale_n/2, Theorem 1) before
the all-reduce, with *error feedback* — the quantization residual is carried
to the next step so the time-average of decoded gradients converges to the
true gradient (the EF-SGD argument).  Small leaves (< ``min_size`` elements)
are sent uncompressed: their wire cost is dominated by latency anyway and
biases/norm gains are precision-critical.

Functional contract (jit/donation-safe, used inside make_train_step):

    init_err, compress = make_compressor(params_like, cc)
    err = init_err()                      # zeros, one buffer per large leaf
    decoded, err = compress(grads, err)   # decode(encode(g + err)), new err
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import expansion as E

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    bits: int = 8
    terms: int = 1
    min_size: int = 2048     # leaves below this many elements stay FP


def compress_decompress(g: jnp.ndarray, cc: CompressionConfig) -> jnp.ndarray:
    """Encode + decode one leaf (what the receiver of the all-reduce sees)."""
    size = 1
    for d in g.shape:
        size *= d
    if size < cc.min_size:
        return g
    et = E.expand(g.astype(jnp.float32), cc.bits, cc.terms)
    return E.reconstruct(et)


def make_compressor(params_like: PyTree, cc: CompressionConfig,
                    ) -> Tuple[Callable[[], PyTree], Callable[[PyTree, PyTree], Tuple[PyTree, PyTree]]]:
    """Error-feedback compressor over a param-shaped pytree.

    ``params_like`` may be concrete arrays or eval_shape structs; only
    shapes are read.  Returns (init_err, compress)."""
    def _size(leaf) -> int:
        n = 1
        for d in leaf.shape:
            n *= d
        return n

    def init_err() -> PyTree:
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32) if _size(p) >= cc.min_size
            else jnp.zeros((), jnp.float32),
            params_like)

    def compress(grads: PyTree, err: PyTree) -> Tuple[PyTree, PyTree]:
        def one(g, e):
            if _size(g) < cc.min_size:
                return g, e                       # uncompressed, no feedback
            h = g.astype(jnp.float32) + e
            dec = compress_decompress(h, cc)
            return dec.astype(g.dtype), h - dec
        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        e_leaves = treedef.flatten_up_to(err)
        pairs = [one(g, e) for g, e in zip(g_leaves, e_leaves)]
        return (jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs]),
                jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs]))

    return init_err, compress


def wire_bytes(params: PyTree, cc: CompressionConfig) -> Tuple[int, int]:
    """(fp32 all-reduce bytes, compressed bytes) for one gradient exchange.

    Compressed leaves cost ``terms * bits/8`` bytes per element plus a f32
    scale per term; small leaves ship as fp32 either way."""
    fp = 0
    comp = 0
    for leaf in jax.tree_util.tree_leaves(params):
        n = 1
        for d in leaf.shape:
            n *= d
        fp += 4 * n
        if n >= cc.min_size:
            comp += (cc.terms * cc.bits * n + 7) // 8 + 4 * cc.terms
        else:
            comp += 4 * n
    return fp, comp
