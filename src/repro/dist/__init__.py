"""Distributed-systems substrate: checkpointing, fault handling, sharding
rules, gradient compression, and the Theorem-2 term-parallel executors."""
