"""Distributed-systems substrate: checkpointing, fault handling, sharding
rules, gradient compression, the Theorem-2 term-parallel executors, and the
serving placement layer (``placement.py``) that wires them into the
Runtime/Engine path (DESIGN.md §9)."""
from repro.dist.placement import (  # noqa: F401
    PLACEMENTS,
    make_serve_mesh,
    place_params,
)
