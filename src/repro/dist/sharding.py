"""Sharding rules: pytree -> NamedSharding specs for the production meshes.

One rule object per (mesh, data-parallel axes) pair.  The policy is
shape-driven and conservative — a leaf is sharded only along axes that
divide evenly, anything else stays replicated — so the same rules serve
smoke models on 8 fake hosts and the 256-chip dry-run cells:

* params: replicated in plain data-parallel mode — compute is then bitwise
  identical to the unsharded run (the exactness contract the multidevice
  tests assert).  With ``fsdp`` the last axis divisible by the "model" size
  is tensor-sharded (column-parallel) and the largest remaining axis is
  sharded across the data axes (ZeRO-3-style) — the memory/collective
  regime of the dry-run cells;
* optimizer state: same rules (moments mirror their parameter's layout;
  scalars like the step counter replicate);
* batches / caches: leading-dim (batch) sharding across the data axes when
  ``shard_batch`` (global batch divisible by the dp size).
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.train.optimizer import get_optimizer

PyTree = Any


class ShardingRules:
    def __init__(self, mesh: Mesh, dp_axes: Sequence[str], *,
                 fsdp: bool = False, shard_batch: bool = True):
        self.mesh = mesh
        self.dp = tuple(a for a in dp_axes if a in mesh.shape)
        self.fsdp = fsdp
        self.shard_batch = shard_batch
        self.model_axis = "model" if "model" in mesh.shape else None

    # ------------------------------------------------------------------
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def _dp_size(self) -> int:
        size = 1
        for a in self.dp:
            size *= self.mesh.shape[a]
        return size

    def _dp_entry(self):
        return self.dp if len(self.dp) > 1 else self.dp[0]

    def _param_spec(self, leaf) -> NamedSharding:
        dims = tuple(leaf.shape)
        if not self.fsdp:
            # plain DP keeps params replicated: every device runs the exact
            # unsharded computation (no contraction reassociation)
            return self.replicated()
        spec = [None] * len(dims)
        if self.model_axis:
            # column-parallel preference: shard the LAST divisible axis (the
            # output-feature dim of (K, N) kernels)
            msize = self.mesh.shape[self.model_axis]
            for i in reversed(range(len(dims))):
                if dims[i] % msize == 0 and dims[i] >= msize:
                    spec[i] = self.model_axis
                    break
        if self.dp:
            dsize = self._dp_size()
            for i in sorted(range(len(dims)), key=lambda i: -dims[i]):
                if spec[i] is None and dims[i] % dsize == 0 and dims[i] >= dsize:
                    spec[i] = self._dp_entry()
                    break
        return NamedSharding(self.mesh, P(*spec))

    def _batch_spec(self, leaf) -> NamedSharding:
        dims = tuple(leaf.shape)
        if not (self.shard_batch and self.dp and dims):
            return self.replicated()
        dsize = self._dp_size()
        if dims[0] % dsize == 0 and dims[0] >= dsize:
            return NamedSharding(
                self.mesh, P(*([self._dp_entry()] + [None] * (len(dims) - 1))))
        return self.replicated()

    def _cache_spec(self, leaf) -> NamedSharding:
        """Caches carry batch on different axes per block kind (stage-vmapped
        blocks prepend a stage axis): shard the largest dp-divisible axis."""
        dims = tuple(leaf.shape)
        if not (self.shard_batch and self.dp):
            return self.replicated()
        dsize = self._dp_size()
        spec = [None] * len(dims)
        for i in sorted(range(len(dims)), key=lambda i: -dims[i]):
            if dims[i] % dsize == 0 and dims[i] >= dsize:
                spec[i] = self._dp_entry()
                break
        return NamedSharding(self.mesh, P(*spec))

    # ------------------------------------------------------------------
    def param_specs(self, params_struct: PyTree) -> PyTree:
        return jax.tree_util.tree_map(self._param_spec, params_struct)

    def opt_state_specs(self, optimizer: str, params_struct: PyTree,
                        p_specs: PyTree) -> PyTree:
        """Specs for ``opt.init(params)``: moments follow the same shape
        rules as params (identical layout for mirrored moments)."""
        del p_specs  # layout is re-derived shape-wise; kept for API parity
        opt = get_optimizer(optimizer)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        return jax.tree_util.tree_map(self._param_spec, opt_struct)

    def batch_specs(self, batch_struct: PyTree) -> PyTree:
        return jax.tree_util.tree_map(self._batch_spec, batch_struct)

    def cache_specs(self, cache_struct: PyTree) -> PyTree:
        return jax.tree_util.tree_map(self._cache_spec, cache_struct)
