"""Sharding rules: pytree -> NamedSharding specs for the production meshes.

One rule object per (mesh, data-parallel axes) pair.  The policy is
shape-driven and conservative — a leaf is sharded only along axes that
divide evenly, anything else stays replicated — so the same rules serve
smoke models on 8 fake hosts and the 256-chip dry-run cells:

* params: replicated in plain data-parallel mode — compute is then bitwise
  identical to the unsharded run (the exactness contract the multidevice
  tests assert).  With ``fsdp`` the last axis divisible by the "model" size
  is tensor-sharded (column-parallel) and the largest remaining axis is
  sharded across the data axes (ZeRO-3-style) — the memory/collective
  regime of the dry-run cells;
* optimizer state: same rules (moments mirror their parameter's layout;
  scalars like the step counter replicate);
* batches / caches: leading-dim (batch) sharding across the data axes when
  ``shard_batch`` (global batch divisible by the dp size).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.expansion import ExpandedTensor
from repro.train.optimizer import get_optimizer

PyTree = Any


class ShardingRules:
    def __init__(self, mesh: Mesh, dp_axes: Sequence[str], *,
                 fsdp: bool = False, shard_batch: bool = True):
        self.mesh = mesh
        self.dp = tuple(a for a in dp_axes if a in mesh.shape)
        self.fsdp = fsdp
        self.shard_batch = shard_batch
        self.model_axis = "model" if "model" in mesh.shape else None

    # ------------------------------------------------------------------
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def _dp_size(self) -> int:
        size = 1
        for a in self.dp:
            size *= self.mesh.shape[a]
        return size

    def _dp_entry(self):
        return self.dp if len(self.dp) > 1 else self.dp[0]

    def _param_spec(self, leaf) -> NamedSharding:
        dims = tuple(leaf.shape)
        if not self.fsdp:
            # plain DP keeps params replicated: every device runs the exact
            # unsharded computation (no contraction reassociation)
            return self.replicated()
        spec = [None] * len(dims)
        if self.model_axis:
            # column-parallel preference: shard the LAST divisible axis (the
            # output-feature dim of (K, N) kernels)
            msize = self.mesh.shape[self.model_axis]
            for i in reversed(range(len(dims))):
                if dims[i] % msize == 0 and dims[i] >= msize:
                    spec[i] = self.model_axis
                    break
        if self.dp:
            dsize = self._dp_size()
            for i in sorted(range(len(dims)), key=lambda i: -dims[i]):
                if spec[i] is None and dims[i] % dsize == 0 and dims[i] >= dsize:
                    spec[i] = self._dp_entry()
                    break
        return NamedSharding(self.mesh, P(*spec))

    def _batch_spec(self, leaf) -> NamedSharding:
        dims = tuple(leaf.shape)
        if not (self.shard_batch and self.dp and dims):
            return self.replicated()
        dsize = self._dp_size()
        if dims[0] % dsize == 0 and dims[0] >= dsize:
            return NamedSharding(
                self.mesh, P(*([self._dp_entry()] + [None] * (len(dims) - 1))))
        return self.replicated()

    def _cache_spec(self, leaf) -> NamedSharding:
        """Caches carry batch on different axes per block kind (stage-vmapped
        blocks prepend a stage axis): shard the largest dp-divisible axis."""
        dims = tuple(leaf.shape)
        if not (self.shard_batch and self.dp):
            return self.replicated()
        dsize = self._dp_size()
        spec = [None] * len(dims)
        for i in sorted(range(len(dims)), key=lambda i: -dims[i]):
            if dims[i] % dsize == 0 and dims[i] >= dsize:
                spec[i] = self._dp_entry()
                break
        return NamedSharding(self.mesh, P(*spec))

    # ------------------------------------------------------------------
    def param_specs(self, params_struct: PyTree) -> PyTree:
        return jax.tree_util.tree_map(self._param_spec, params_struct)

    def opt_state_specs(self, optimizer: str, params_struct: PyTree,
                        p_specs: PyTree) -> PyTree:
        """Specs for ``opt.init(params)``: moments follow the same shape
        rules as params (identical layout for mirrored moments)."""
        del p_specs  # layout is re-derived shape-wise; kept for API parity
        opt = get_optimizer(optimizer)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        return jax.tree_util.tree_map(self._param_spec, opt_struct)

    def batch_specs(self, batch_struct: PyTree) -> PyTree:
        return jax.tree_util.tree_map(self._batch_spec, batch_struct)

    def cache_specs(self, cache_struct: PyTree) -> PyTree:
        return jax.tree_util.tree_map(self._cache_spec, cache_struct)


# ---------------------------------------------------------------------------
# serving column-parallel placement (``placement="tensor"``, DESIGN.md §9)
# ---------------------------------------------------------------------------
def _column_spec(leaf, mesh: Mesh, axis: str) -> NamedSharding:
    """Shard the last (output-feature) axis when it divides the mesh axis;
    1-D leaves (norm scales, biases) and non-dividing shapes replicate.
    Column-parallel keeps each output feature's full-K contraction on one
    device, so no dot product is reassociated — logits stay exact."""
    dims = tuple(getattr(leaf, "shape", ()))
    msize = mesh.shape[axis]
    if len(dims) >= 2 and dims[-1] % msize == 0 and dims[-1] >= msize:
        return NamedSharding(mesh, P(*([None] * (len(dims) - 1) + [axis])))
    return NamedSharding(mesh, P())


def column_parallel_specs(params: PyTree, mesh: Mesh, *,
                          axis: str = "model") -> PyTree:
    """NamedShardings for serving a parameter pytree column-parallel.

    ``ExpandedTensor`` leaves shard every per-output-channel component along
    its last axis — planes (…, t, K, N), per-channel scales (…, t, N), bias
    (…, N) and sat (…, K, N) all split on N, so one device owns every series
    component of its output columns; per-tensor (scalar-scale) components
    replicate.  The returned tree nests shardings *inside* ExpandedTensor
    spec leaves, matching the params pytree for ``jax.device_put``."""
    rep = NamedSharding(mesh, P())

    def et_spec(et: ExpandedTensor) -> ExpandedTensor:
        n = et.planes.shape[-1]  # packed width when packed — still the unit
        msize = mesh.shape[axis]
        ok = n % msize == 0 and n >= msize
        col = lambda v: NamedSharding(
            mesh, P(*([None] * (v.ndim - 1) + [axis]))) if ok else rep
        return dataclasses.replace(
            et, planes=col(et.planes),
            scales=col(et.scales) if et.per_channel else rep,
            bias=None if et.bias is None else (col(et.bias) if et.per_channel
                                               else rep),
            sat=None if et.sat is None else col(et.sat))

    is_et = lambda l: isinstance(l, ExpandedTensor)
    return jax.tree_util.tree_map(
        lambda l: et_spec(l) if is_et(l) else _column_spec(l, mesh, axis),
        params, is_leaf=is_et)


def shard_params_column_parallel(params: PyTree, mesh: Mesh, *,
                                 axis: str = "model") -> PyTree:
    """Place serving params column-parallel over ``mesh`` (GSPMD consumes
    the shardings inside jit; no manual collectives)."""
    return jax.device_put(params, column_parallel_specs(params, mesh, axis=axis))
