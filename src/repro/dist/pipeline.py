"""GPipe-style pipeline parallelism over a "stage" mesh axis (shard_map).

Each device holds one stage's weights; microbatches flow through the ring
via collective-permute.  With S stages and M microbatches the schedule runs
``M + S - 1`` ticks; the bubble fraction is ``(S-1)/(M+S-1)`` — the usual
GPipe accounting.  Idle ticks process zero tensors (cheap, masked out of
the result), so the loop body is uniform across devices — SPMD-safe.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

AXIS = "stage"


def make_stage_mesh(n_stages: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:n_stages]), (AXIS,))


def pipeline_forward(stage_fn: Callable, stage_params: jnp.ndarray,
                     x: jnp.ndarray, mesh: Mesh) -> jnp.ndarray:
    """stage_fn(w, h): one stage; stage_params (S, ...) sharded per stage;
    x (n_micro, mb, d) microbatches.  Returns (n_micro, mb, d) = the
    sequential composition of all stages, computed pipelined."""
    n_stages = mesh.shape[AXIS]
    n_micro = x.shape[0]

    @partial(shard_map, mesh=mesh, in_specs=(P(AXIS), P()), out_specs=P())
    def _run(w_local, x_all):
        idx = jax.lax.axis_index(AXIS)
        w = jax.tree_util.tree_map(lambda a: a[0], w_local)
        buf = jnp.zeros_like(x_all[0])
        outs = jnp.zeros_like(x_all)
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        for t in range(n_micro + n_stages - 1):
            if t < n_micro:                   # stage 0 ingests microbatch t
                buf = jnp.where(idx == 0, x_all[t], buf)
            h = stage_fn(w, buf)
            if t >= n_stages - 1:             # last stage emits t-(S-1)
                outs = outs.at[t - (n_stages - 1)].set(
                    jnp.where(idx == n_stages - 1, h, outs[t - (n_stages - 1)]))
            buf = jax.lax.ppermute(h, AXIS, fwd)
        # only the last stage holds real outputs; psum replicates them
        return jax.lax.psum(jnp.where(idx == n_stages - 1, outs, 0.0), AXIS)

    return _run(stage_params, x)
