"""Expert parallelism: stacked MoE expansions sharded over an "expert" axis.

The third serving placement (DESIGN.md §15).  A stacked per-expert
expansion (``expand_batched``: planes ``(E, tw, K, N)``, independent
quantizers per expert) scatters its *expert* axis over a 1-D ``"expert"``
mesh axis; every device runs the grouped series GEMM for its local experts
and ONE ``psum`` combines the per-expert INT32 accumulators — the Abelian
contract of DESIGN.md §9 on a second mesh axis.  Each global accumulator
slot is written by exactly one device (zeros — the group identity —
elsewhere), so the integer psum is exact for ANY device count: the f32
epilogue (dyadic scale folds, Eq. 4 affine corrections, router
dispatch/combine einsums) runs replicated, bit-identically on every
device, which is what makes expert-parallel serving token-identical to the
replicated oracle.

Composition with term parallelism: :func:`make_moe_mesh` builds a 2-D
``("expert", "expand")`` mesh.  Expert kernels shard their expert axis over
``"expert"`` (their term axis stays replicated — the expert axis is the
distribution unit); dense/attention expansions term-shard over ``"expand"``
exactly as under ``placement="term"`` (``QuantContext.term_parallel`` is
true on such a mesh), so the two integer-psum contracts coexist, one per
axis.

Two entry layers, mirroring ``dist/expansion_parallel.py``:

* :func:`grouped_parallel_apply` — the distributed twin of
  ``core.linear.grouped_expanded_apply`` (used by ``models.moe._expert_mm``
  when a ``QuantContext`` carries ``placement="expert"``);
* :func:`shard_moe_params` — the artifact-bind step: MoE expert kernels
  scatter their expert axis; router/attention/norm/dense leaves replicate
  (or term-shard when the mesh carries a non-trivial ``"expand"`` axis).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.contracts import annotate as _contract
from repro.core import expansion as E
from repro.core import linear as LIN
from repro.core.expansion import ExpandedTensor
from repro.core.policy import ExpansionPolicy
from repro.kernels import ref

AXIS = "expert"

#: subtree key whose GEMM kernels are stacked per-expert (models/moe.py)
_MOE_KEY = "moe"
_EXPERT_KERNELS = ("wi", "wg", "wo")

PyTree = Any


def make_moe_mesh(n_expert: int, n_term: int = 1) -> Mesh:
    """Mesh for expert-parallel serving: 1-D ``("expert",)`` when
    ``n_term == 1``, else the 2-D ``("expert", "expand")`` composition
    (expert kernels shard experts; dense kernels shard series terms)."""
    import numpy as np

    n = n_expert * n_term
    if n > jax.device_count():
        raise ValueError(
            f"mesh wants {n} devices; only {jax.device_count()} visible "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"for a fake-device mesh)")
    devs = np.array(jax.devices()[:n])
    if n_term == 1:
        return Mesh(devs, (AXIS,))
    return Mesh(devs.reshape(n_expert, n_term), (AXIS, "expand"))


# ---------------------------------------------------------------------------
# artifact-bind placement
# ---------------------------------------------------------------------------
def _is_expert_leaf(path) -> bool:
    """Is this tree path a stacked per-expert GEMM kernel?  MoE expert
    kernels live under a ``"moe"`` subtree at keys ``wi``/``wg``/``wo``
    (``models/moe.py``); the router and the shared expert (``moe/shared/
    wi...`` — a dense always-on MLP, llama4 flavor) stay dense."""
    keys = [k.key for k in path if hasattr(k, "key")]
    if _MOE_KEY not in keys:
        return False
    i = keys.index(_MOE_KEY)
    if "shared" in keys[i:]:
        return False
    return any(k in _EXPERT_KERNELS for k in keys[i:])


def expert_sharding_spec(et: ExpandedTensor, mesh: Mesh) -> ExpandedTensor:
    """Per-component NamedShardings for one stacked expert leaf: every data
    field scatters its expert axis — the LAST batch axis (stage-stacked
    ``(L, E, ...)`` leaves carry ``batch_dims == 2``, tail leaves
    ``(E, ...)`` carry 1) — over ``AXIS``; everything else replicates."""
    ax = et.batch_dims - 1
    if ax < 0:
        raise ValueError(f"expert leaf must be batched, got {et}")

    def spec(arr):
        if arr is None:
            return None
        return NamedSharding(
            mesh, P(*([None] * ax + [AXIS] + [None] * (arr.ndim - ax - 1))))

    return dataclasses.replace(
        et, planes=spec(et.planes), scales=spec(et.scales),
        bias=spec(et.bias), sat=spec(et.sat))


def shard_moe_params(params: PyTree, mesh: Mesh) -> PyTree:
    """Artifact-bind placement for ``placement="expert"`` serving: stacked
    expert kernels scatter their expert axis over ``"expert"``; every other
    leaf replicates — unless the mesh carries a non-trivial ``"expand"``
    axis, in which case non-expert ``ExpandedTensor`` leaves term-shard
    over it (the 2-D expert x term composition).  Packed expert leaves are
    unpacked first (the expert axis, not the byte axis, distributes)."""
    from jax.tree_util import tree_flatten_with_path, tree_unflatten

    is_et = lambda l: isinstance(l, ExpandedTensor)
    term_too = mesh.shape.get("expand", 1) > 1
    if term_too:
        from repro.dist.expansion_parallel import pad_terms, term_sharding_spec

    leaves, treedef = tree_flatten_with_path(params, is_leaf=is_et)
    placed = []
    for path, leaf in leaves:
        if is_et(leaf) and _is_expert_leaf(path):
            if leaf.packed:
                leaf = E.unpack(leaf)
            n = mesh.shape[AXIS]
            e_ax = leaf.batch_dims - 1
            if leaf.planes.shape[e_ax] % n:
                raise ValueError(
                    f"expert count {leaf.planes.shape[e_ax]} does not divide "
                    f"the {AXIS!r} mesh axis ({n}); pick a mesh whose expert "
                    f"axis divides num_experts")
            placed.append(jax.device_put(leaf, expert_sharding_spec(leaf, mesh)))
        elif is_et(leaf) and term_too:
            leaf = pad_terms(leaf, mesh.shape["expand"])
            placed.append(jax.device_put(leaf, term_sharding_spec(leaf, mesh)))
        else:
            placed.append(jax.device_put(leaf, NamedSharding(mesh, P())))
    return tree_unflatten(treedef, placed)


def replicated_einsum(spec: str, a: jnp.ndarray, b: jnp.ndarray,
                      mesh: Mesh) -> jnp.ndarray:
    """An einsum pinned to single-device reduction order on every device.

    The MoE combine (``te,etd->td`` / ``gsec,gecd->gsd``) contracts over
    the expert axis.  Outside a manual region GSPMD is free to partition
    that contraction over the mesh (it sees the producer was
    expert-sharded), which splits the f32 sum into per-device partials and
    reassociates it — an ulp wobble that the next layer's activation
    requantization amplifies into token flips (observed: bisect showed
    every grouped GEMM bit-exact while this one einsum differed by 1 ulp).
    Inside shard_map with fully-replicated specs each device computes the
    complete contraction locally in the canonical single-device order, so
    the expert engine's combine is bit-identical to the replicated
    oracle's."""
    @partial(shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=P())
    def _run(a_r, b_r):
        return jnp.einsum(spec, a_r, b_r)

    return _run(a, b)


# ---------------------------------------------------------------------------
# the distributed grouped apply
# ---------------------------------------------------------------------------
def grouped_parallel_apply(x: jnp.ndarray, w_et: ExpandedTensor,
                           policy: ExpansionPolicy, mesh: Mesh,
                           term_budget: int = None) -> jnp.ndarray:
    """Distributed twin of ``core.linear.grouped_expanded_apply`` (expert
    sharding): each device computes the INT8xINT8->INT32 series accumulators
    of its local experts, one ``psum`` over the ``"expert"`` axis combines
    them in the integer domain, and the f32 epilogue (dyadic scale folds in
    the canonical oracle order + the shared Eq. 4 batched corrections) runs
    replicated — so the result is bit-identical to the replicated grouped
    apply for any device count.

    ``term_budget`` truncates the weight series exactly like the local
    grouped apply — the term axis is NOT the sharded axis here (experts
    are), so slicing is shard-safe and keeps the epilogue's ``reconstruct``/
    ``full_colsum`` corrections bit-identical to the replicated engine's
    truncated view.  x: (E, M, K) -> (E, M, N) f32."""
    if w_et.batch_dims != 1:
        raise ValueError(
            f"grouped_parallel_apply needs batch_dims=1, got {w_et}")
    if term_budget is not None:
        w_et = E.truncate(w_et, term_budget)
    if w_et.packed:
        w_et = E.unpack(w_et)
    a_bits, a_terms = policy.a_bits, policy.a_terms
    e, m, k = x.shape
    n = w_et.orig_shape[-1]
    tw = w_et.num_terms
    n_shards = mesh.shape[AXIS]
    if e % n_shards:
        raise ValueError(
            f"expert count {e} does not divide the {AXIS!r} mesh axis "
            f"({n_shards})")
    loc = e // n_shards
    x32 = x.astype(jnp.float32)

    # Everything floating-point below runs INSIDE one shard_map manual
    # region.  Outside a manual region GSPMD owns the partitioning of every
    # op that touches the expert-sharded weight components — it may split
    # an f32 reduction (epilogue matmuls, colsums, the scale fold) into
    # per-device partials and reassociate the sum, and whether it does
    # depends on the surrounding compiled program (observed: bit-exact
    # standalone, 1-ulp wobble inside a full decode step).  Inside the
    # region each device all-gathers the weight shards (pure data movement,
    # exact) and executes the canonical full-shape single-device
    # arithmetic, so the result is bit-identical to the replicated oracle
    # in ANY surrounding program.
    comps = {"planes": w_et.planes, "scales": w_et.scales}
    if w_et.bias is not None:
        comps["bias"] = w_et.bias
    if w_et.sat is not None:
        comps["sat"] = w_et.sat
    in_specs = (P(), {key: P(AXIS) for key in comps})

    def _gather_w(comp_l):
        full = {key: jax.lax.all_gather(v, AXIS, axis=0, tiled=True)
                for key, v in comp_l.items()}
        return dataclasses.replace(
            w_et, planes=full["planes"], scales=full["scales"],
            bias=full.get("bias"), sat=full.get("sat"))

    if a_terms <= 0 or a_bits >= 16:
        # weight-only: per-expert FP GEMMs are wholly local to one device;
        # the psum only gathers disjoint expert rows (f32, but each slot is
        # written once over zeros, so no sum is reassociated — the waiver
        # below documents the domain, not a deviation)
        @partial(shard_map, mesh=mesh, in_specs=in_specs, out_specs=P(),
                 check_rep=False)
        def _dequant(x_full, comp_l):
            start = jax.lax.axis_index(AXIS) * loc
            x_l = jax.lax.dynamic_slice_in_dim(x_full, start, loc, 0)
            scales_l = comp_l["scales"] if w_et.per_channel else \
                jnp.broadcast_to(comp_l["scales"][..., None], (loc, tw, n))
            part = jax.vmap(ref.dequant_matmul_ref)(
                x_l, comp_l["planes"], scales_l.astype(jnp.float32))
            buf = jnp.zeros((e, m, n), jnp.float32)
            buf = jax.lax.dynamic_update_slice(buf, part, (start, 0, 0))
            out = jax.lax.psum(buf, AXIS)
            return LIN._grouped_epilogue(out, x_full, None, None,
                                         _gather_w(comp_l))

        return _dequant(x32, comps)

    @partial(shard_map, mesh=mesh, in_specs=in_specs, out_specs=P(),
             check_rep=False)
    def _series(x_full, comp_l):
        # per-expert dynamic activation params + residual planes, computed
        # at full shape on every device — identical f32 arithmetic to the
        # replicated grouped apply
        xt, bias_a, sigma, a_scale1 = jax.vmap(
            lambda xe: LIN._dynamic_act_params(xe, policy, a_bits))(x_full)
        a_planes = jax.vmap(
            lambda xe, s: ref.residual_quantize_ref(xe, s, a_bits, a_terms)
        )(xt, a_scale1)                               # (E, ta, M, K) int8

        # int32 series accumulators for the LOCAL experts only — the
        # per-expert GEMMs never split, only their int32 results travel
        start = jax.lax.axis_index(AXIS) * loc
        ap_l = jax.lax.dynamic_slice_in_dim(a_planes, start, loc, 0)

        def _one(ap_e, pl_e):
            acc = jnp.stack([
                jax.lax.dot_general(ap_e[i], pl_e[j],
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.int32)
                for i in range(a_terms) for j in range(tw)])
            return acc.reshape(a_terms, tw, m, n)

        acc_l = jax.vmap(_one)(ap_l, comp_l["planes"])  # (loc, ta, tw, M, N)
        buf = jnp.zeros((e, a_terms, tw, m, n), jnp.int32)
        buf = jax.lax.dynamic_update_slice(buf, acc_l, (start, 0, 0, 0, 0))
        # exact: integer AbelianAdd — each expert's slots are written by
        # exactly one device (zeros, the group identity, elsewhere)
        accs = jax.lax.psum(buf, AXIS)                # (E, ta, tw, M, N)

        w_full = _gather_w(comp_l)
        scales = w_full.scales if w_et.per_channel else \
            jnp.broadcast_to(w_full.scales[..., None], (e, tw, n))
        scales = scales.astype(jnp.float32)

        # f32 scale-fold in the canonical oracle order (i-outer, j-inner —
        # matches ref.series_matmul_ref / the grouped ref fallback)
        out = jnp.zeros((e, m, n), jnp.float32)
        for i in range(a_terms):
            sa_i = a_scale1 / float(ref._scale_ratio(a_bits) ** i)   # (E,)
            for j in range(tw):
                out = out + (sa_i[:, None, None] * scales[:, j, None, :]) \
                    * accs[:, i, j].astype(jnp.float32)

        return LIN._grouped_epilogue(out, xt, bias_a, sigma, w_full)

    return _series(x32, comps)


# the integer-domain psum contract (DESIGN.md §9/§15), checked by
# repro.analysis.check_integer_psum on axes=("expert",): the series path
# psums int32 accumulators; the weight-only path psums disjoint FP expert
# rows and carries the waiver (reported, never failed).
_contract(grouped_parallel_apply, name="grouped_parallel_apply",
          int_psum_axes=(AXIS,),
          float_psum_waiver=(
              "weight-only path (a_terms == 0 or a_bits >= 16) psums FP "
              "per-expert partials: each expert row is written by exactly "
              "one device over zeros, so no floating sum is reassociated"))
