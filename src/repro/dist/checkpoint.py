"""Atomic, resumable checkpointing (single-host; npz per step).

Layout: ``<dir>/step_%09d/state.npz`` plus a ``.DONE`` commit marker written
last — a crash mid-save leaves an uncommitted directory that readers ignore
and ``gc_old`` removes.  Leaves are keyed by their pytree key-path, so
restore can validate structure (missing leaf -> KeyError) and shapes
(mismatch -> ValueError) against an ``eval_shape`` template before touching
the model.  ``AsyncCheckpointer`` overlaps the write with training (each
save waits for the previous one — at most one outstanding write).
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_STEP_PREFIX = "step_"
_DONE = ".DONE"
_FILE = "state.npz"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"{_STEP_PREFIX}{step:09d}")


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves], treedef


# npz silently degrades extension dtypes (bfloat16, float8_*) to void — store
# those as flat raw bytes plus "<key>::dtype" / "<key>::shape" sidecar
# entries so the exact dtype round-trips.  ``encode_array``/``decode_array``
# and ``write_npz``/``atomic_commit_dir`` are the reusable substrate the
# quantization-artifact format (repro.api.artifact) is built on.
_DTYPE_KEY = "::dtype"
_SHAPE_KEY = "::shape"


def encode_array(key: str, arr: np.ndarray, out: dict) -> None:
    """Add ``arr`` to the npz dict, extension-dtype-safe (bf16/fp8 survive)."""
    if arr.dtype.kind in "biufc":
        out[key] = arr
        return
    out[key] = arr.reshape(-1).view(np.uint8)
    out[key + _DTYPE_KEY] = np.array(arr.dtype.name)
    out[key + _SHAPE_KEY] = np.array(arr.shape, np.int64)


def decode_array(key: str, data) -> np.ndarray:
    """Inverse of :func:`encode_array` against an open ``np.load`` handle."""
    arr = data[key]
    if key + _DTYPE_KEY not in data.files:
        return arr
    import ml_dtypes
    dtype = np.dtype(getattr(ml_dtypes, str(data[key + _DTYPE_KEY])))
    shape = tuple(int(d) for d in data[key + _SHAPE_KEY])
    return arr.view(dtype).reshape(shape)


def is_sidecar_key(key: str) -> bool:
    """True for the ``::dtype``/``::shape`` entries decode_array consumes."""
    return key.endswith(_DTYPE_KEY) or key.endswith(_SHAPE_KEY)


def write_npz(path: str, arrays: dict) -> None:
    """np.savez + flush + fsync (durable before any commit marker)."""
    with open(path, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())


def atomic_commit_dir(tmp: str, final: str, done_marker: str = _DONE) -> None:
    """Atomically publish a fully-written ``tmp`` directory at ``final``:
    rename into place, then write the commit marker readers key on LAST.

    A pre-existing ``final`` is moved aside (rename, not delete) before the
    swap and removed only after the new marker is durably written, so a
    crash mid-commit never destroys the previously committed copy — it
    survives at ``<final>.old`` (with its marker) for manual recovery."""
    old = final + ".old"
    if os.path.exists(final):
        if os.path.exists(old):
            shutil.rmtree(old)
        os.replace(final, old)
    os.replace(tmp, final)
    with open(os.path.join(final, done_marker), "w") as f:
        f.write("ok\n")
        f.flush()
        os.fsync(f.fileno())
    shutil.rmtree(old, ignore_errors=True)


# backwards-compatible private aliases (internal callers predate the api layer)
_encode_leaf = encode_array
_decode_leaf = decode_array


def committed_steps(directory: str) -> List[int]:
    """Sorted steps with a commit marker (crashed saves are invisible)."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if not name.startswith(_STEP_PREFIX):
            continue
        try:  # skip step_*.tmp / step_*.old leftovers of interrupted saves
            step = int(name[len(_STEP_PREFIX):])
        except ValueError:
            continue
        if os.path.exists(os.path.join(directory, name, _DONE)):
            out.append(step)
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def gc_old(directory: str, keep: int) -> None:
    """Remove all but the newest ``keep`` committed steps AND any
    uncommitted (crashed) step directories — including ``step_*.tmp``
    leftovers from a save killed mid-write."""
    if not os.path.isdir(directory):
        return
    committed = committed_steps(directory)
    drop = set(committed[:-keep] if keep else committed)
    for name in os.listdir(directory):
        if not name.startswith(_STEP_PREFIX):
            continue
        path = os.path.join(directory, name)
        try:
            step = int(name[len(_STEP_PREFIX):])
        except ValueError:
            # a committed *.old copy is the survivor of a crashed re-commit
            # (atomic_commit_dir) — preserve it for manual recovery; only
            # markerless leftovers (step_*.tmp, torn moves) are garbage
            if name.endswith(".old") and \
                    os.path.exists(os.path.join(path, _DONE)):
                continue
            shutil.rmtree(path, ignore_errors=True)
            continue
        if step in drop or not os.path.exists(os.path.join(path, _DONE)):
            shutil.rmtree(path, ignore_errors=True)


def save(directory: str, step: int, state: PyTree, keep: Optional[int] = None) -> None:
    """Atomic commit: write into a temp dir, fsync, rename, mark .DONE."""
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(state)
    arrays: dict = {}
    for key, leaf in flat:
        encode_array(key, np.asarray(jax.device_get(leaf)), arrays)
    write_npz(os.path.join(tmp, _FILE), arrays)
    atomic_commit_dir(tmp, final)
    if keep:
        gc_old(directory, keep)


def restore(directory: str, template: PyTree,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, int]:
    """Load the latest committed step into the ``template`` structure.

    ``template`` comes from ``jax.eval_shape`` — every leaf is validated by
    key-path (KeyError if absent in the checkpoint) and shape (ValueError).
    ``shardings``: optional pytree of Shardings (same structure) applied via
    device_put — the elastic-rescale path."""
    step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    with np.load(os.path.join(_step_dir(directory, step), _FILE)) as data:
        flat, treedef = _flatten(template)
        sh_leaves = None
        if shardings is not None:
            sh_leaves = [s for _, s in _flatten(shardings)[0]]
        leaves = []
        for idx, (key, tmpl) in enumerate(flat):
            if key not in data.files:
                raise KeyError(f"checkpoint at step {step} has no leaf {key}")
            arr = decode_array(key, data)
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"shape mismatch at {key}: checkpoint {arr.shape} vs "
                    f"template {tmpl.shape}")
            if sh_leaves is not None:
                leaves.append(jax.device_put(arr, sh_leaves[idx]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Background-thread writer: ``save`` returns immediately; each save
    waits for the previous write (at most one in flight); ``wait`` joins."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state: PyTree) -> None:
        self.wait()
        # materialize on host in the caller (device buffers may be donated)
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state)

        def _worker():
            try:
                save(self.directory, step, host_state, self.keep)
            except BaseException as e:  # surfaced on the next wait()/save()
                self._error = e

        self._thread = threading.Thread(target=_worker, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Join the in-flight write; re-raises any exception it hit (a
        silently-failed checkpoint is worse than a crashed trainer)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
