"""Serving placement: how one model instance spreads over a device mesh.

The deployment axis the Runtime/Engine expose (DESIGN.md §9):

* ``"replicated"`` — every device holds the full model; the single-device
  behavior (and the bit-exactness baseline the sharded placements are
  measured against);
* ``"term"``       — Theorem-2 expansion parallelism: ``ExpandedTensor``
  weight terms scatter over a 1-D ``"expand"`` mesh axis at artifact-bind
  time and every expanded GEMM runs as ``shard_map`` + one ``psum``
  (``dist/expansion_parallel.py``).  Per-device weight memory shrinks by
  ~the device count; activations and KV caches replicate;
* ``"tensor"``     — column-parallel over a ``"model"`` axis
  (``dist/sharding.py``): each device owns a slice of every GEMM's output
  features.  Works for expanded *and* plain-FP params; contractions are
  never reassociated, so logits are exact;
* ``"expert"``     — MoE expert parallelism: stacked per-expert expansions
  scatter their expert axis over a 1-D ``"expert"`` mesh axis and the
  grouped series GEMM psums INT32 accumulators
  (``dist/expert_parallel.py``).  Composes with term parallelism on a 2-D
  ``("expert", "expand")`` mesh (``make_moe_mesh``): dense expansions then
  term-shard exactly as under ``"term"``.

This module is the small dispatcher the serving stack wires through:
:func:`make_serve_mesh` builds the 1-D mesh with the axis name the
placement's collectives expect, and :func:`place_params` applies the
placement to a parameter pytree.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

PLACEMENTS = ("replicated", "term", "tensor", "expert")

#: mesh axis name each placement's collectives are written against
PLACEMENT_AXIS = {"term": "expand", "tensor": "model", "expert": "expert"}

#: mesh axes whose psums must reduce in the INTEGER domain (the Abelian
#: exactness contract, DESIGN.md §9).  "term" contracts series partials —
#: f32 psums there reassociate per device count and diverge through
#: requantization; "expert" combines per-expert series accumulators the
#: same way on its own axis (DESIGN.md §15); "tensor" shards output
#: columns (no contraction is reassociated), so it carries no
#: integer-domain requirement.  ``repro.analysis.check_integer_psum``
#: reads this to know which axes to police when tracing a placed
#: computation.
INT_PSUM_AXES = ("expand", "expert")


def int_psum_axes(placement: str) -> tuple:
    """The mesh axes the integer-domain psum rule applies to under a
    placement (empty for placements with no reassociated contraction).
    ``"expert"`` polices both its own axis and ``"expand"`` — a 2-D
    expert x term mesh runs both contracts, and policing an absent axis
    is harmless."""
    check_placement(placement)
    if placement == "expert":
        return ("expert", "expand")
    axis = PLACEMENT_AXIS.get(placement)
    return (axis,) if axis in INT_PSUM_AXES else ()


def check_placement(placement: str) -> str:
    if placement not in PLACEMENTS:
        raise ValueError(
            f"unknown placement {placement!r}; one of {PLACEMENTS}")
    return placement


def make_serve_mesh(n_devices: int = 0, placement: str = "term") -> Mesh:
    """1-D serving mesh over the first ``n_devices`` local devices (0 = all),
    named for the placement: ``"expand"`` for term parallelism, ``"model"``
    for column-parallel."""
    import numpy as np

    check_placement(placement)
    axis = PLACEMENT_AXIS.get(placement, "expand")
    n = n_devices or jax.device_count()
    if n > jax.device_count():
        raise ValueError(
            f"mesh wants {n} devices; only {jax.device_count()} visible "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"for a fake-device mesh)")
    return Mesh(np.array(jax.devices()[:n]), (axis,))


def place_params(params: PyTree, mesh: Optional[Mesh],
                 placement: str = "replicated") -> PyTree:
    """Apply a serving placement to a parameter pytree (artifact-bind step).

    ``"term"`` pads every expanded leaf's term axis to a mesh-axis multiple
    (zero planes — the Abelian identity) and scatters planes/scales;
    ``"tensor"`` shards output-feature columns; ``"replicated"`` (or no
    mesh) broadcasts everything so sharded and unsharded engines see the
    same committed-device layout."""
    check_placement(placement)
    if mesh is None:
        if placement != "replicated":
            raise ValueError(f"placement={placement!r} needs a mesh "
                             f"(make_serve_mesh)")
        return params
    if placement == "term":
        from repro.dist.expansion_parallel import AXIS, shard_expanded_params
        if AXIS not in mesh.shape:
            raise ValueError(
                f"placement='term' needs a mesh with an {AXIS!r} axis; got "
                f"{tuple(mesh.shape)} (use make_serve_mesh(n, 'term'))")
        return shard_expanded_params(params, mesh)
    if placement == "tensor":
        from repro.dist.sharding import shard_params_column_parallel
        if "model" not in mesh.shape:
            raise ValueError(
                f"placement='tensor' needs a mesh with a 'model' axis; got "
                f"{tuple(mesh.shape)} (use make_serve_mesh(n, 'tensor'))")
        return shard_params_column_parallel(params, mesh)
    if placement == "expert":
        from repro.dist.expert_parallel import AXIS, shard_moe_params
        if AXIS not in mesh.shape:
            raise ValueError(
                f"placement='expert' needs a mesh with an {AXIS!r} axis; got "
                f"{tuple(mesh.shape)} (use make_serve_mesh(n, 'expert') or "
                f"dist.expert_parallel.make_moe_mesh)")
        return shard_moe_params(params, mesh)
    return jax.device_put(params, NamedSharding(mesh, P()))
