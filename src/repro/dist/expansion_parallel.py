"""Expansion (term) parallelism: Theorem 2 executed across devices.

The series GEMM is a sum of independent per-term GEMMs —
``out = sum_j Q(x~) @ (sw_j * W_j)`` — an Abelian reduction, so the weight
terms can be scattered over a mesh axis and combined with a single psum
(the paper's AllReduce execution model).  The affine corrections of
Eq. 4 (rank-1 M_nsy terms, saturation, clip overflow) are cheap O(n^2)
adds computed replicated, outside the parallel region.

Term counts that do not divide the axis are zero-plane padded: a plane of
zeros with zero scale contributes nothing to the psum.

Two entry layers (DESIGN.md §9):

* :func:`term_parallel_apply` — the distributed twin of
  ``core.linear.expanded_apply`` for one GEMM (used directly by demos, and
  by ``models/layers.dense`` when a ``QuantContext`` carries
  ``placement="term"``);
* :func:`shard_expanded_params` — the artifact-bind step: pad every
  ``ExpandedTensor``'s term axis to a mesh-axis multiple and ``device_put``
  the planes/scales scattered over the ``"expand"`` axis, so serving jits
  see pre-placed weights and insert no resharding collectives.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, List

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.contracts import annotate as _contract
from repro.core import expansion as E
from repro.core import linear as LIN
from repro.core.expansion import ExpandedTensor
from repro.core.policy import ExpansionPolicy
from repro.kernels import ref

AXIS = "expand"

PyTree = Any


def make_expand_mesh(n_devices: int) -> Mesh:
    """1-D mesh over the first ``n_devices`` devices, axis name "expand"
    (the serving entry is ``dist.placement.make_serve_mesh``, which this
    delegates to so device-count validation lives in one place)."""
    from repro.dist.placement import make_serve_mesh
    return make_serve_mesh(n_devices, "term")


# ---------------------------------------------------------------------------
# artifact-bind placement: zero-pad the term axis and scatter it over AXIS
# ---------------------------------------------------------------------------
def pad_terms(et: ExpandedTensor, multiple: int) -> ExpandedTensor:
    """Zero-plane-pad the term axis up to a ``multiple`` (Theorem 2 padding:
    a zero plane with zero scale is the Abelian identity, so padded terms
    contribute exactly +0.0 to every partial sum and to the psum)."""
    if et.packed:
        et = E.unpack(et)  # nibble-packed planes cannot be term-scattered
    bd = et.batch_dims
    pad = (-et.num_terms) % max(1, multiple)
    if not pad:
        return et
    p_pads = [(0, 0)] * et.planes.ndim
    p_pads[bd] = (0, pad)
    s_pads = [(0, 0)] * et.scales.ndim
    s_pads[bd] = (0, pad)
    return dataclasses.replace(
        et, planes=jnp.pad(et.planes, p_pads), scales=jnp.pad(et.scales, s_pads))


def term_sharding_spec(et: ExpandedTensor, mesh: Mesh) -> ExpandedTensor:
    """Per-component NamedShardings for one expanded leaf, shaped like the
    leaf itself (an ``ExpandedTensor`` whose data fields hold shardings, so
    it can be handed to ``jax.device_put`` as a matching pytree): planes and
    scales scatter their term axis over ``AXIS``; bias/sat replicate."""
    bd = et.batch_dims
    rep = NamedSharding(mesh, P())
    planes_sh = NamedSharding(
        mesh, P(*([None] * bd + [AXIS] + [None] * (et.planes.ndim - bd - 1))))
    scales_sh = NamedSharding(
        mesh, P(*([None] * bd + [AXIS] + [None] * (et.scales.ndim - bd - 1))))
    return dataclasses.replace(
        et, planes=planes_sh, scales=scales_sh,
        bias=None if et.bias is None else rep,
        sat=None if et.sat is None else rep)


def shard_expanded_params(params: PyTree, mesh: Mesh) -> PyTree:
    """Artifact-bind placement for ``placement="term"`` serving: every
    ``ExpandedTensor`` leaf is zero-plane padded so its term count divides
    ``mesh.shape[AXIS]`` and its planes/scales are scattered over the mesh
    axis; plain leaves (embeddings, norms, biases) replicate.  Packed
    (INT4-nibble) leaves are unpacked first — the term axis, not the byte
    axis, is the distribution unit."""
    n = mesh.shape[AXIS]
    is_et = lambda l: isinstance(l, ExpandedTensor)
    padded = jax.tree_util.tree_map(
        lambda l: pad_terms(l, n) if is_et(l) else l, params, is_leaf=is_et)
    specs = jax.tree_util.tree_map(
        lambda l: (term_sharding_spec(l, mesh) if is_et(l)
                   else NamedSharding(mesh, P())), padded, is_leaf=is_et)
    return jax.device_put(padded, specs)


def _padded_terms(w_et: ExpandedTensor, n_shards: int):
    """(planes (t_pad, K, N), per-channel scales (t_pad, N)) zero-padded so
    the term axis divides the mesh axis."""
    tw = w_et.num_terms
    n = w_et.orig_shape[-1]
    planes = w_et.planes
    scales = w_et.scales if w_et.per_channel else \
        jnp.broadcast_to(w_et.scales[:, None], (tw, n))
    pad = (-tw) % n_shards
    if pad:
        planes = jnp.pad(planes, ((0, pad), (0, 0), (0, 0)))
        scales = jnp.pad(scales, ((0, pad), (0, 0)))
    return planes, scales.astype(jnp.float32)


def term_parallel_apply(x: jnp.ndarray, w_et: ExpandedTensor,
                        policy: ExpansionPolicy, mesh: Mesh,
                        term_budget: int = None) -> jnp.ndarray:
    """Distributed twin of ``core.linear.expanded_apply`` (weight-term
    sharding): each device computes the series GEMM over its local weight
    terms, one ``psum`` (the Abelian reduction of Theorem 2) combines them,
    and the Eq. 4 affine epilogue is added replicated.

    ``term_budget`` (the truncated-series draft of DESIGN.md §10) zeroes the
    scales of terms >= k instead of slicing: the term axis is scattered over
    the mesh, and a zero scale is the Abelian identity — masked terms
    contribute exactly +0.0 to the psum, so the result is bit-identical to
    the replicated engine's sliced ``ExpandedTensor.truncate(k)``.  (The
    masked devices still run their GEMMs; slicing across shards would need a
    resharding collective that costs more than it saves at serving batch
    sizes.)

    x: (..., K); returns (..., N) f32 — matches the local fused result up to
    psum reassociation (greedy served *tokens* are identical; logits agree
    to f32 reduction order, see DESIGN.md §9).  Weight-only policies
    (``a_terms == 0`` or ``a_bits >= 16``) take a per-term dequant-GEMM with
    the same single-psum contract.  Batched (e.g. per-expert MoE) leaves are
    not routed here — they keep the replicated apply."""
    if w_et.batch_dims > 0:
        raise NotImplementedError(
            "term_parallel_apply serves unbatched weights; peel batch axes "
            "(stage scan / expert vmap) before routing")
    if w_et.packed:
        w_et = E.unpack(w_et)
    a_bits, a_terms = policy.a_bits, policy.a_terms
    k, n = w_et.orig_shape[-2], w_et.orig_shape[-1]
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k).astype(jnp.float32)

    n_shards = mesh.shape[AXIS]
    planes, scales = _padded_terms(w_et, n_shards)
    tw_pad = planes.shape[0]
    loc = tw_pad // n_shards
    m = x2d.shape[0]
    if term_budget is not None:
        scales = scales * (jnp.arange(tw_pad) < term_budget)[:, None]

    if a_terms <= 0 or a_bits >= 16:
        # weight-only (e.g. W4A16): exact FP activation against each local
        # partial reconstruction, psum over term shards.  The activation is
        # FP here, so the partials are FP and the psum may reassociate their
        # sum — without the activation-requantization amplifier of the
        # series path the deviation stays at ulp level (DESIGN.md §9).
        @partial(shard_map, mesh=mesh,
                 in_specs=(P(), P(AXIS), P(AXIS)), out_specs=P())
        def _dequant(x_r, planes_l, scales_l):
            part = ref.dequant_matmul_ref(x_r, planes_l, scales_l)
            return jax.lax.psum(part, AXIS)

        out = _dequant(x2d, planes, scales)
        if w_et.bias is not None:
            out = out + jnp.sum(x2d, axis=-1, keepdims=True) * w_et.bias
        if w_et.sat is not None:
            out = out + x2d @ w_et.sat
        return out.reshape(*lead, n)

    xt, bias_a, sigma, a_scale1 = LIN._dynamic_act_params(x2d, policy, a_bits)

    # The distributed portion is kept EXACT: each device computes the
    # INT8xINT8->INT32 accumulators of its local weight terms and the one
    # psum reduces *integers* — the Abelian group of Theorem 2 realized in
    # Z, where the reduction truly is order-independent (f32 partial sums
    # would make the psum association device-count-dependent).  All f32
    # arithmetic — the activation quantization before, the dyadic
    # scale-and-accumulate epilogue after (same i-outer/j-inner order as
    # the local oracle) — runs replicated, identically on every device.
    a_planes = ref.residual_quantize_ref(xt, a_scale1, a_bits, a_terms)

    @partial(shard_map, mesh=mesh, in_specs=(P(), P(AXIS)), out_specs=P())
    def _int_accs(aplanes_r, planes_l):
        acc_l = jnp.stack([
            jax.lax.dot_general(aplanes_r[i], planes_l[j],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
            for j in range(loc) for i in range(a_terms)])
        acc_l = acc_l.reshape(loc, a_terms, m, n)
        buf = jnp.zeros((tw_pad, a_terms, m, n), jnp.int32)
        start = jax.lax.axis_index(AXIS) * loc
        buf = jax.lax.dynamic_update_slice(buf, acc_l, (start, 0, 0, 0))
        # exact: integer AbelianAdd.  Each global slot is written by exactly
        # one device (zeros — the group identity — elsewhere), so a tiled
        # all_gather of acc_l is bit-identical and moves 1/n_shards of the
        # bytes; the psum form is kept as the paper's AllReduce contract —
        # swap to all_gather when chasing interconnect bandwidth on real
        # meshes.
        return jax.lax.psum(buf, AXIS)

    accs = _int_accs(a_planes, planes)      # (tw_pad, ta, M, N), replicated
    ratio = float(ref._scale_ratio(a_bits))
    out = jnp.zeros((m, n), jnp.float32)
    for i in range(a_terms):                # canonical oracle order
        sa_i = a_scale1 / (ratio ** i)
        for j in range(tw_pad):
            out = out + (sa_i * scales[j]) * accs[j, i].astype(jnp.float32)

    # affine corrections — identical to expanded_apply's epilogue
    if w_et.bias is not None:
        out = out + jnp.sum(xt, axis=-1, keepdims=True) * w_et.bias
    if w_et.sat is not None:
        out = out + xt @ w_et.sat
    if bias_a is not None:
        out = out + bias_a * LIN.full_colsum(w_et)[None, :]
    if sigma is not None:
        out = out + sigma @ E.reconstruct(w_et)
    return out.reshape(*lead, n)


# the integer-domain psum contract (DESIGN.md §9), checked by
# repro.analysis.check_integer_psum: the series path psums int32
# accumulators; the weight-only path deliberately psums FP partials and
# carries the waiver below (reported, never failed).
_contract(term_parallel_apply, name="term_parallel_apply",
          int_psum_axes=(AXIS,),
          float_psum_waiver=(
              "weight-only path (a_terms == 0 or a_bits >= 16) psums FP "
              "partials: without the activation-requantization amplifier "
              "the reassociation deviation stays at ulp level"))


def term_parallel_mlp_forward(x: jnp.ndarray, ets: List[ExpandedTensor],
                              policy: ExpansionPolicy, mesh: Mesh) -> jnp.ndarray:
    """Theorem 2 over a whole MLP stack: per-layer psum (AbelianAdd) with the
    nonlinearity duplicated on every shard (it is cheap and data-parallel)."""
    h = x
    for i, et in enumerate(ets):
        h = term_parallel_apply(h, et, policy, mesh)
        if i < len(ets) - 1:
            h = jax.nn.gelu(h)
    return h
