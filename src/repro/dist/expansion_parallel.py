"""Expansion (term) parallelism: Theorem 2 executed across devices.

The series GEMM is a sum of independent per-term GEMMs —
``out = sum_j Q(x~) @ (sw_j * W_j)`` — an Abelian reduction, so the weight
terms can be scattered over a mesh axis and combined with a single psum
(the paper's AllReduce execution model).  The affine corrections of
Eq. 4 (rank-1 M_nsy terms, saturation, clip overflow) are cheap O(n^2)
adds computed replicated, outside the parallel region.

Term counts that do not divide the axis are zero-plane padded: a plane of
zeros with zero scale contributes nothing to the psum.
"""
from __future__ import annotations

from functools import partial
from typing import List

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import expansion as E
from repro.core import linear as LIN
from repro.core.expansion import ExpandedTensor
from repro.core.policy import ExpansionPolicy
from repro.kernels import ref

AXIS = "expand"


def make_expand_mesh(n_devices: int) -> Mesh:
    """1-D mesh over the first ``n_devices`` devices, axis name "expand"."""
    import numpy as np
    devs = np.array(jax.devices()[:n_devices])
    return Mesh(devs, (AXIS,))


def _padded_terms(w_et: ExpandedTensor, n_shards: int):
    """(planes (t_pad, K, N), per-channel scales (t_pad, N)) zero-padded so
    the term axis divides the mesh axis."""
    tw = w_et.num_terms
    n = w_et.orig_shape[-1]
    planes = w_et.planes
    scales = w_et.scales if w_et.per_channel else \
        jnp.broadcast_to(w_et.scales[:, None], (tw, n))
    pad = (-tw) % n_shards
    if pad:
        planes = jnp.pad(planes, ((0, pad), (0, 0), (0, 0)))
        scales = jnp.pad(scales, ((0, pad), (0, 0)))
    return planes, scales.astype(jnp.float32)


def term_parallel_apply(x: jnp.ndarray, w_et: ExpandedTensor,
                        policy: ExpansionPolicy, mesh: Mesh) -> jnp.ndarray:
    """Distributed twin of core.linear.expanded_apply (weight-term sharding).

    x: (..., K); returns (..., N) f32 — matches the local fused result up to
    psum reassociation."""
    a_bits, a_terms = policy.a_bits, policy.a_terms
    k, n = w_et.orig_shape[-2], w_et.orig_shape[-1]
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k).astype(jnp.float32)
    xt, bias_a, sigma, a_scale1 = LIN._dynamic_act_params(x2d, policy, a_bits)

    n_shards = mesh.shape[AXIS]
    planes, scales = _padded_terms(w_et, n_shards)

    @partial(shard_map, mesh=mesh,
             in_specs=(P(), P(), P(AXIS), P(AXIS)), out_specs=P())
    def _series(xt_r, s1_r, planes_l, scales_l):
        part = ref.series_matmul_ref(xt_r, s1_r, planes_l, scales_l,
                                     a_bits=a_bits, a_terms=a_terms)
        return jax.lax.psum(part, AXIS)

    out = _series(xt, a_scale1, planes, scales)

    # affine corrections — identical to expanded_apply's epilogue
    if w_et.bias is not None:
        out = out + jnp.sum(xt, axis=-1, keepdims=True) * w_et.bias
    if w_et.sat is not None:
        out = out + xt @ w_et.sat
    if bias_a is not None:
        out = out + bias_a * LIN.full_colsum(w_et)[None, :]
    if sigma is not None:
        out = out + sigma @ E.reconstruct(w_et)
    return out.reshape(*lead, n)


def term_parallel_mlp_forward(x: jnp.ndarray, ets: List[ExpandedTensor],
                              policy: ExpansionPolicy, mesh: Mesh) -> jnp.ndarray:
    """Theorem 2 over a whole MLP stack: per-layer psum (AbelianAdd) with the
    nonlinearity duplicated on every shard (it is cheap and data-parallel)."""
    h = x
    for i, et in enumerate(ets):
        h = term_parallel_apply(h, et, policy, mesh)
        if i < len(ets) - 1:
            h = jax.nn.gelu(h)
    return h
