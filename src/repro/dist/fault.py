"""Fault tolerance: preemption-safe training supervision + stragglers.

``TrainSupervisor`` wraps the train loop's lifecycle: restore-or-init from
the newest committed checkpoint (bitwise-identical resume — the data loader
is step-keyed, so a crashed run replays exactly), periodic checkpointing
every ``ckpt_every`` steps, and a final synchronous save.  It also feeds
per-step wall times to a ``StragglerDetector`` so slow steps (preempted
neighbors, thermal throttling) are logged without poisoning the EMA.

``DispatchWatchdog`` generalizes the same detector to *serving*: the slot
scheduler feeds it per-round dispatch wall times, stalled rounds (chaos
latency spikes, noisy neighbors, allocator hiccups) are flagged against
the healthy EMA or an absolute ``stall_s`` ceiling, and the EMA doubles as
the round-time estimate behind the deadline-miss estimator
(``repro.infer.qos.estimate_miss_rate``).
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Tuple

import jax

from repro.dist import checkpoint as CKPT

PyTree = Any


class StragglerDetector:
    """Flag steps slower than ``factor`` x the EMA of healthy step times.

    The first ``warmup`` observations seed the EMA and are never flagged;
    flagged steps do NOT update the EMA (a straggler must not raise the bar
    for detecting the next one)."""

    def __init__(self, factor: float = 2.0, warmup: int = 2, decay: float = 0.9):
        self.factor = factor
        self.warmup = warmup
        self.decay = decay
        self.ema: Optional[float] = None
        self.count = 0
        self.slow_steps: List[Tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.count += 1
        if self.ema is None:
            self.ema = dt
            return False
        if self.count > self.warmup and dt > self.factor * self.ema:
            self.slow_steps.append((step, dt))
            return True
        self.ema = self.decay * self.ema + (1.0 - self.decay) * dt
        return False


class DispatchWatchdog(StragglerDetector):
    """Serving-side straggler detection over scheduler dispatch rounds.

    Same EMA-relative flagging as :class:`StragglerDetector`, plus an
    absolute ``stall_s`` ceiling: a round slower than ``stall_s`` is always
    flagged (even during warmup, when the EMA has no evidence yet) —
    ``stall_s=0`` disables the ceiling.  ``ema`` is exposed as the healthy
    round-time estimate for deadline projections."""

    def __init__(self, factor: float = 4.0, warmup: int = 2,
                 decay: float = 0.9, stall_s: float = 0.0):
        super().__init__(factor=factor, warmup=warmup, decay=decay)
        self.stall_s = stall_s

    def observe(self, step: int, dt: float) -> bool:
        if self.stall_s > 0.0 and dt > self.stall_s:
            # absolute ceiling: flag without feeding the EMA (a stall must
            # not raise the bar for detecting the next one)
            self.count += 1
            self.slow_steps.append((step, dt))
            return True
        return super().observe(step, dt)

    @property
    def stalled_rounds(self) -> int:
        return len(self.slow_steps)

    def stats(self):
        return {"stalled_rounds": self.stalled_rounds,
                "round_ema_s": self.ema if self.ema is not None else 0.0}


class TrainSupervisor:
    """Checkpoint-driven lifecycle for one training 'life'.

    init_state: zero-arg callable building the fresh {params, opt, ...}
    state pytree; its ``jax.eval_shape`` is the restore template."""

    def __init__(self, ckpt_dir: str, init_state: Callable[[], PyTree], *,
                 ckpt_every: int = 50, keep: int = 3,
                 shardings: Optional[PyTree] = None):
        self.ckpt_dir = ckpt_dir
        self.init_state = init_state
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.shardings = shardings
        self.straggler = StragglerDetector()
        self._last_t: Optional[float] = None
        self._last_saved: Optional[int] = None

    def restore_or_init(self) -> Tuple[PyTree, int]:
        """(state, first step to run): latest committed step + 1, or 0."""
        step = CKPT.latest_step(self.ckpt_dir)
        if step is None:
            return self.init_state(), 0
        template = jax.eval_shape(self.init_state)
        state, step = CKPT.restore(self.ckpt_dir, template,
                                   shardings=self.shardings)
        return state, step + 1

    def after_step(self, step: int, state: PyTree) -> None:
        now = time.perf_counter()
        if self._last_t is not None:
            self.straggler.observe(step, now - self._last_t)
        self._last_t = now
        if (step + 1) % self.ckpt_every == 0:
            CKPT.save(self.ckpt_dir, step, state, keep=self.keep)
            self._last_saved = step

    def finalize(self, step: int, state: PyTree) -> None:
        if step >= 0 and self._last_saved != step:
            CKPT.save(self.ckpt_dir, step, state, keep=self.keep)
            self._last_saved = step
