"""Serving layer: the slot-scheduler Engine (continuous batching,
device-fused sampling, artifact admission, mesh placements) and the
KV/state-cache size model behind per-device HBM admission control."""
from repro.infer.scheduler import Request, SlotScheduler
from repro.infer.serve import Engine, ServeConfig, make_decode_sample_step, make_serve_step
