from repro.infer.scheduler import Request, SlotScheduler
from repro.infer.serve import Engine, ServeConfig, make_decode_sample_step, make_serve_step
