from repro.infer.serve import Engine, ServeConfig, make_serve_step
