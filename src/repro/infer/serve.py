"""Batched serving engine for FP=xINT-expanded models.

The PTQ paper's deployment story: expand a trained FP model once (seconds,
calibration-free), then serve the INT series.  The engine:

* expands params at admission (``policy`` given) — the quantization step
  the paper times in Table 2/3;
* groups equal-length requests into batches (exactness over padding
  heuristics: attention math is identical to the unbatched run);
* runs jit'd prefill + donated-cache decode steps (in-place cache update);
* continuous-batching-lite: a request queue is drained group by group, new
  groups admitted as slots free up.

``make_serve_step`` is the function the multi-pod dry-run lowers for the
``decode_*`` cells.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import ptq as PTQ
from repro.core.policy import ExpansionPolicy
from repro.models import model as M
from repro.models.layers import FP, QuantContext

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 512            # decode capacity (cache size)
    max_batch: int = 8
    temperature: float = 0.0      # 0 = greedy
    eos_id: int = -1              # -1 = never stop early
    seed: int = 0


def make_serve_step(cfg: ArchConfig, qc: QuantContext = FP):
    """serve_step(params, tokens (B,1), caches, cache_len) ->
    (logits (B,V), caches') — the unit the decode dry-run cells lower."""
    def serve_step(params, tokens, caches, cache_len):
        return M.decode_step(params, tokens, caches, cache_len, cfg, qc)
    return serve_step


class Engine:
    def __init__(self, cfg: ArchConfig, params: PyTree, *,
                 policy: Optional[ExpansionPolicy] = None,
                 serve_cfg: ServeConfig = ServeConfig(),
                 use_kernel: bool = False):
        self.cfg = cfg
        self.sc = serve_cfg
        self.qc = QuantContext(policy=policy, use_kernel=use_kernel) if policy else FP
        t0 = time.perf_counter()
        if policy is not None:
            params = jax.jit(lambda p: PTQ.expand_params(p, policy))(params)
            params = jax.block_until_ready(params)
        self.quant_seconds = time.perf_counter() - t0
        self.params = params
        self._queue: List[Tuple[int, List[int]]] = []
        self._next_id = 0

        self._prefill = jax.jit(
            lambda p, batch: M.prefill(p, batch, cfg, self.qc, s_max=self.sc.max_seq))
        self._decode = jax.jit(
            lambda p, tok, caches, clen: M.decode_step(p, tok, caches, clen, cfg, self.qc),
            donate_argnums=(2,))

    # ------------------------------------------------------------------
    def add_request(self, tokens: Sequence[int]) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, list(tokens)))
        return rid

    def _form_groups(self) -> List[List[Tuple[int, List[int]]]]:
        by_len: Dict[int, List] = defaultdict(list)
        for rid, toks in self._queue:
            by_len[len(toks)].append((rid, toks))
        groups = []
        for _, reqs in sorted(by_len.items()):
            for i in range(0, len(reqs), self.sc.max_batch):
                groups.append(reqs[i:i + self.sc.max_batch])
        return groups

    def run(self, max_new_tokens: int = 16) -> Dict[int, List[int]]:
        """Drain the queue; returns request id -> generated tokens."""
        out: Dict[int, List[int]] = {}
        key = jax.random.PRNGKey(self.sc.seed)
        for group in self._form_groups():
            rids = [rid for rid, _ in group]
            prompts = np.array([t for _, t in group], np.int32)
            b, s = prompts.shape
            assert s + max_new_tokens <= self.sc.max_seq, "over decode capacity"
            logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
            gen = [[] for _ in rids]
            alive = np.ones(b, bool)
            clen = jnp.int32(s)
            tok = self._sample(logits, key)
            for t in range(max_new_tokens):
                for i in range(b):
                    if alive[i]:
                        gen[i].append(int(tok[i, 0]))
                        if int(tok[i, 0]) == self.sc.eos_id:
                            alive[i] = False
                if not alive.any() or t == max_new_tokens - 1:
                    break
                logits, caches = self._decode(self.params, tok, caches, clen)
                clen = clen + 1
                key, sub = jax.random.split(key)
                tok = self._sample(logits, sub)
            for rid, g in zip(rids, gen):
                out[rid] = g
        self._queue.clear()
        return out

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        tok = jax.random.categorical(key, logits / self.sc.temperature, axis=-1)
        return tok[:, None].astype(jnp.int32)
