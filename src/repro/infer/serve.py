"""Batched serving engine for FP=xINT-expanded models.

The PTQ paper's deployment story: expand a trained FP model once (seconds,
calibration-free), then serve the INT series.  The engine:

* expands params at admission (``policy`` given) — the quantization step
  the paper times in Table 2/3;
* groups equal-length requests into batches (exactness over padding
  heuristics: attention math is identical to the unbatched run);
* runs jit'd prefill + donated-cache decode steps (in-place cache update);
* fuses sampling and EOS tracking into the decode step ON DEVICE: the host
  pulls exactly one (tokens, alive) pair per decode step — the seed engine
  instead called ``int(tok[i, 0])`` twice per request per step, i.e.
  ``2 * batch`` blocking host syncs per generated token;
* continuous-batching-lite: a request queue is drained group by group, new
  groups admitted as slots free up.

``make_serve_step`` is the function the multi-pod dry-run lowers for the
``decode_*`` cells; ``make_decode_sample_step`` is the fused
decode+sample+EOS unit the engine actually steps.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import ptq as PTQ
from repro.core.policy import ExpansionPolicy
from repro.models import model as M
from repro.models.layers import FP, QuantContext

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 512            # decode capacity (cache size)
    max_batch: int = 8
    temperature: float = 0.0      # 0 = greedy
    eos_id: int = -1              # -1 = never stop early
    seed: int = 0


def _sample_logits(logits: jnp.ndarray, key, temperature: float) -> jnp.ndarray:
    """(B, V) logits -> (B, 1) int32 tokens; greedy when temperature <= 0."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    tok = jax.random.categorical(key, logits / temperature, axis=-1)
    return tok[:, None].astype(jnp.int32)


def make_serve_step(cfg: ArchConfig, qc: QuantContext = FP):
    """serve_step(params, tokens (B,1), caches, cache_len) ->
    (logits (B,V), caches') — the unit the decode dry-run cells lower."""
    def serve_step(params, tokens, caches, cache_len):
        return M.decode_step(params, tokens, caches, cache_len, cfg, qc)
    return serve_step


def make_decode_sample_step(cfg: ArchConfig, qc: QuantContext = FP):
    """Fused decode + sample + EOS-mask step (all on device).

    step(params, tok (B,1), caches, cache_len, key, alive (B,), eos_id ();
         temperature static) -> (next_tok, caches', key', alive').

    ``alive`` accumulates ``tok != eos`` so the engine's host loop needs a
    single device transfer per step; ``eos_id`` is a dynamic operand so
    reconfiguring it does not retrace."""
    def step(params, tok, caches, cache_len, key, alive, eos_id, *, temperature):
        logits, caches = M.decode_step(params, tok, caches, cache_len, cfg, qc)
        key, sub = jax.random.split(key)
        nxt = _sample_logits(logits, sub, temperature)
        alive = jnp.logical_and(alive, nxt[:, 0] != eos_id)
        return nxt, caches, key, alive
    return step


class Engine:
    def __init__(self, cfg: ArchConfig, params: Optional[PyTree] = None, *,
                 policy: Optional[ExpansionPolicy] = None,
                 artifact: Optional[Any] = None,
                 backend: Optional[str] = None,
                 serve_cfg: ServeConfig = ServeConfig(),
                 use_kernel: bool = False):
        """Admit a model either as raw FP ``params`` (optionally expanded
        here when ``policy`` is given — the legacy per-engine path) or as a
        pre-built ``artifact`` (:class:`repro.api.QuantArtifact`): the
        quantized params are bound as-is, so a model is expanded once per
        process (at ``quantize`` time), not once per engine.  ``backend``
        picks the artifact execution path (``ref`` | ``pallas`` |
        ``pallas-packed``; see :class:`repro.api.Runtime`)."""
        self.cfg = cfg
        self.sc = serve_cfg
        if artifact is not None:
            if params is not None or policy is not None:
                raise ValueError(
                    "pass either artifact= or (params, policy), not both")
            backend = backend or ("pallas" if use_kernel else "ref")
            self.qc = artifact.quant_context(backend)
            params = artifact.runtime_params(backend)
            self.quant_seconds = artifact.quant_seconds  # paid once, upstream
        else:
            if params is None:
                raise ValueError("Engine needs params or an artifact")
            self.qc = QuantContext(policy=policy, use_kernel=use_kernel) if policy else FP
            t0 = time.perf_counter()
            if policy is not None:
                params = jax.jit(lambda p: PTQ.expand_params(p, policy))(params)
                params = jax.block_until_ready(params)
            self.quant_seconds = time.perf_counter() - t0
        self.params = params
        self._queue: List[Tuple[int, List[int]]] = []
        self._next_id = 0

        self._prefill = jax.jit(
            lambda p, batch: M.prefill(p, batch, cfg, self.qc, s_max=self.sc.max_seq))
        self._decode = jax.jit(
            make_decode_sample_step(cfg, self.qc),
            donate_argnums=(2,), static_argnames=("temperature",))

    # ------------------------------------------------------------------
    def add_request(self, tokens: Sequence[int]) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, list(tokens)))
        return rid

    def _form_groups(self) -> List[List[Tuple[int, List[int]]]]:
        by_len: Dict[int, List] = defaultdict(list)
        for rid, toks in self._queue:
            by_len[len(toks)].append((rid, toks))
        groups = []
        for _, reqs in sorted(by_len.items()):
            for i in range(0, len(reqs), self.sc.max_batch):
                groups.append(reqs[i:i + self.sc.max_batch])
        return groups

    def run(self, max_new_tokens: int = 16) -> Dict[int, List[int]]:
        """Drain the queue; returns request id -> generated tokens."""
        out: Dict[int, List[int]] = {}
        key = jax.random.PRNGKey(self.sc.seed)
        temperature = float(self.sc.temperature)
        eos = jnp.int32(self.sc.eos_id)
        for group in self._form_groups():
            rids = [rid for rid, _ in group]
            prompts = np.array([t for _, t in group], np.int32)
            b, s = prompts.shape
            assert s + max_new_tokens <= self.sc.max_seq, "over decode capacity"
            logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
            tok = self._sample(logits, key)
            alive = tok[:, 0] != eos                       # on-device EOS mask
            gen = [[] for _ in rids]
            alive_host = np.ones(b, bool)                  # aliveness BEFORE tok
            clen = jnp.int32(s)
            for t in range(max_new_tokens):
                # the ONE host transfer of this decode step
                tok_host, alive_after = jax.device_get((tok, alive))
                for i in range(b):
                    if alive_host[i]:
                        gen[i].append(int(tok_host[i, 0]))
                alive_host = np.asarray(alive_after)
                if not alive_host.any() or t == max_new_tokens - 1:
                    break
                tok, caches, key, alive = self._decode(
                    self.params, tok, caches, clen, key, alive, eos,
                    temperature=temperature)
                clen = clen + 1
            for rid, g in zip(rids, gen):
                out[rid] = g
        self._queue.clear()
        return out

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        return _sample_logits(logits, key, self.sc.temperature)
