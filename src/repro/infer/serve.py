"""Batched serving engine for FP=xINT-expanded models.

The PTQ paper's deployment story: expand a trained FP model once (seconds,
calibration-free), then serve the INT series.  The engine:

* expands params at admission (``policy`` given) — the quantization step
  the paper times in Table 2/3 — or binds a pre-built artifact as-is;
* serves with **slot-based continuous batching** by default
  (``ServeConfig(scheduler="slots")``, :mod:`repro.infer.scheduler`):
  variable-length prompts are padded-prefilled into free slots of a live
  decode cache, one fused decode step serves every slot at its own
  sequence position (vector ``cache_len``), and slots freed by EOS or
  token budgets are recycled for queued requests mid-stream;
* keeps the legacy **group-drain** path behind
  ``ServeConfig(scheduler="grouped")``: equal-length requests batched and
  drained to completion — the bit-exactness baseline the slots path is
  compared against;
* fuses sampling and EOS tracking into the decode step ON DEVICE: the host
  pulls exactly one (tokens, alive) pair per decode step;
* treats ``eos_id`` AND ``temperature`` as dynamic operands of the fused
  step, so reconfiguring either never retraces the decode kernel;
* serves **self-speculatively** when ``ServeConfig(spec_terms=k)`` is set
  (DESIGN.md §10): the first ``k`` series terms of the expanded weights —
  a coherent model by Theorem 1 — draft ``spec_lookahead`` tokens per slot,
  one chunked full-series pass verifies them all, and the slot scheduler
  commits the longest matching greedy prefix; emitted tokens are always
  full-model argmaxes, so greedy output is token-identical to the
  non-speculative engine;
* serves **multi-device placements** (DESIGN.md §9): with ``mesh`` +
  ``placement="term"`` the expanded weights live scattered over the mesh's
  ``"expand"`` axis and every expanded GEMM of prefill-into-slot and the
  fused decode step runs as shard_map + one psum; ``placement="tensor"``
  is column-parallel via parameter shardings (GSPMD).  Caches, tokens and
  the scheduler state replicate, so the slot scheduler drives a sharded
  engine identically to a replicated one.

``make_serve_step`` is the function the multi-pod dry-run lowers for the
``decode_*`` cells; ``make_decode_sample_step`` is the fused
decode+sample+EOS unit the engine actually steps.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import annotate as _contract
from repro.configs.base import ArchConfig
from repro.core import ptq as PTQ
from repro.core.policy import ExpansionPolicy
from repro.infer import qos as Q
from repro.infer.scheduler import Request, SlotScheduler
from repro.models import model as M
from repro.models.layers import FP, QuantContext

PyTree = Any

SCHEDULERS = ("slots", "grouped")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 512            # decode capacity (cache size)
    max_batch: int = 8            # grouped batch size / default slot count
    temperature: float = 0.0      # 0 = greedy (dynamic: no retrace on change)
    eos_id: int = -1              # -1 = never stop early (dynamic operand)
    seed: int = 0
    scheduler: str = "slots"      # "slots" (continuous) | "grouped" (legacy)
    max_slots: int = 0            # 0 -> max_batch decode slots
    hbm_budget_bytes: float = 0.0  # >0: cap slots via kvcache.max_batch_for_hbm
    prefill_bucket: int = 16      # pad prompts to a multiple (bounds retraces)
    # self-speculative decoding (DESIGN.md §10): draft with the first
    # spec_terms series terms of the SAME expanded weights, verify with the
    # full series — greedy output stays token-identical to non-speculative
    spec_terms: int = 0           # 0 = off; k >= 1 = k-term draft model
    spec_lookahead: int = 4       # draft tokens per round (gamma)
    # -- QoS / robustness (DESIGN.md §11) --------------------------------
    # statically truncate the WHOLE engine to the first k series terms
    # (Theorem 1 prefix = a coherent lower-bit deployment of one artifact);
    # None = full series.  Per-request tiers are relative to this context.
    term_budget: Optional[int] = None
    # quality-tier ladder served by add_request(quality=...): ((name,
    # term_budget), ...); None = the default (("k2", 2), ("k1", 1)) ladder
    # when the model is series-expanded.  "full" is always available.
    tier_budgets: Optional[Any] = None
    max_queue: int = 0            # >0: add_request backpressure bound
    degrade: Q.DegradeConfig = Q.DegradeConfig()  # load-adaptive degradation
    chaos: Optional[Q.ChaosConfig] = None         # fault injection (CI/chaos)
    # -- paged KV cache (DESIGN.md §13) ----------------------------------
    # attention KV lives in fixed-size page pools addressed through
    # per-slot block tables; admission reserves ceil(len/page) pages, so a
    # short sequence stops charging max_seq HBM.  Requires the slots
    # scheduler; chaos injection is not supported on the paged engine.
    paged: bool = False
    page_size: int = 16           # tokens per KV page
    num_pages: int = 0            # 0 -> derived (hbm budget or slots*max_seq)
    # -- chunked prefill + shared-prefix caching (DESIGN.md §14) ---------
    # prefill_chunk > 0: prompts prefill in fixed-size chunks fused into
    # decode rounds (one dispatch serves live decode rows plus one chunk),
    # so a long prompt no longer monopolizes a round and queued TTFT stops
    # scaling with the longest in-flight prompt.  Output stays
    # token-identical to monolithic prefill.
    prefill_chunk: int = 0        # 0 = monolithic prefill-into-slot
    # prefix_cache: radix trie over prompt pages (paged engines only) —
    # admission increfs matched pages into the block table and prefills
    # only the uncached suffix; series expansion is deterministic in the
    # prompt, so shared pages are bit-identical to a cold prefill's.
    prefix_cache: bool = False


def _sample_logits(logits: jnp.ndarray, key, temperature: float) -> jnp.ndarray:
    """(B, V) logits -> (B, 1) int32 tokens; greedy when temperature <= 0.
    Host-side helper (``temperature`` is a python float)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    tok = jax.random.categorical(key, logits / temperature, axis=-1)
    return tok[:, None].astype(jnp.int32)


def sample_logits_dynamic(logits: jnp.ndarray, key,
                          temperature: jnp.ndarray) -> jnp.ndarray:
    """Trace-safe sampling with ``temperature`` as a dynamic operand: the
    greedy/categorical choice is a ``where``, not a python branch, so
    changing temperature does not retrace/recompile the fused decode step."""
    greedy = jnp.argmax(logits, axis=-1)
    t = jnp.maximum(jnp.asarray(temperature, logits.dtype), 1e-6)
    sampled = jax.random.categorical(key, logits / t, axis=-1)
    tok = jnp.where(jnp.asarray(temperature) > 0, sampled, greedy)
    return tok[:, None].astype(jnp.int32)


def make_serve_step(cfg: ArchConfig, qc: QuantContext = FP):
    """serve_step(params, tokens (B,1), caches, cache_len) ->
    (logits (B,V), caches') — the unit the decode dry-run cells lower.
    ``cache_len`` may be () or (B,) (per-slot positions)."""
    def serve_step(params, tokens, caches, cache_len):
        return M.decode_step(params, tokens, caches, cache_len, cfg, qc)
    return serve_step


def _select_rows(new, old, mask, axis):
    """Row-wise merge: keep ``new`` where ``mask`` (over batch ``axis``)."""
    shape = [1] * new.ndim
    shape[axis] = mask.shape[0]
    return jnp.where(mask.reshape(shape), new, old)


def make_decode_sample_step(cfg: ArchConfig, qc: QuantContext = FP,
                            masked: bool = False, moe_stats: bool = False):
    """Fused decode + sample + EOS-mask step (all on device).

    step(params, tok (B,1), caches, cache_len () or (B,), key, alive (B,),
         eos_id (), temperature ()[, row_mask (B,)])
        -> (next_tok, caches', key', alive').

    ``alive`` accumulates ``tok != eos`` so the engine's host loop needs a
    single device transfer per step; ``eos_id`` and ``temperature`` are
    dynamic operands so reconfiguring either does not retrace.

    ``masked=True`` adds a ``row_mask`` operand (also dynamic — membership
    changes never retrace): only masked rows commit their new token / alive
    bit / cache writes, unmasked rows keep their inputs bit-for-bit.  This
    is how QoS tiers share one slot pool: each scheduler iteration issues
    one masked dispatch per distinct term budget, and every slot's state
    advances under exactly its own tier's ``QuantContext.term_budget``
    (the ``jnp.where`` merges fuse into the cache scatter — no extra cache
    materialization).  Stage cache leaves are stacked ``(L, B, ...)``
    (batch axis 1), tail leaves ``(B, ...)`` (axis 0).

    ``moe_stats=True`` (static) appends the round's MoE routing telemetry
    (summed over every ``moe_attn`` block — :func:`moe.zero_stats`
    structure) as a FIFTH output, which the scheduler folds into its
    expert-imbalance stats.  It rides the same fused dispatch and the same
    single host transfer; under the masked variant it is NOT row-merged
    (token routing counts every batch row — the signal measures the
    compute each expert performs per dispatch, DESIGN.md §15)."""
    def step(params, tok, caches, cache_len, key, alive, eos_id, temperature):
        if moe_stats:
            logits, caches, mst = M.decode_step(params, tok, caches,
                                                cache_len, cfg, qc,
                                                moe_stats=True)
        else:
            logits, caches = M.decode_step(params, tok, caches, cache_len,
                                           cfg, qc)
        key, sub = jax.random.split(key)
        nxt = sample_logits_dynamic(logits, sub, temperature)
        alive = jnp.logical_and(alive, nxt[:, 0] != eos_id)
        if moe_stats:
            return nxt, caches, key, alive, mst
        return nxt, caches, key, alive

    _contract(step, name="fused_decode", transfers_per_round=1,
              int_psum_axes=("expand", "expert"),
              dynamic_operands=("eos_id", "temperature"),
              donate_argnums=(2,), budget_key="decode")
    if not masked:
        return step

    def masked_step(params, tok, caches, cache_len, key, alive, eos_id,
                    temperature, row_mask):
        res = step(params, tok, caches, cache_len, key, alive, eos_id,
                   temperature)
        nxt, new_caches, key, alive_new = res[:4]
        nxt = jnp.where(row_mask[:, None], nxt, tok)
        alive_out = jnp.where(row_mask, alive_new, alive)
        merged = {
            "stages": jax.tree_util.tree_map(
                lambda nw, old: _select_rows(nw, old, row_mask, 1),
                new_caches["stages"], caches["stages"]),
            "tail": jax.tree_util.tree_map(
                lambda nw, old: _select_rows(nw, old, row_mask, 0),
                new_caches["tail"], caches["tail"]),
        }
        return (nxt, merged, key, alive_out) + tuple(res[4:])

    _contract(masked_step, name="fused_decode_masked", transfers_per_round=1,
              int_psum_axes=("expand", "expert"),
              dynamic_operands=("eos_id", "temperature", "row_mask"),
              donate_argnums=(2,), budget_key="decode_masked")
    return masked_step


def _pool_sentinel(caches) -> Optional[int]:
    """Sentinel page id of a paged cache tree (None when the arch has no
    full-attention blocks — nothing is paged, tables are inert)."""
    for part, ax in (("stages", 1), ("tail", 0)):
        leaves, _ = jax.tree_util.tree_flatten_with_path(caches.get(part, {}))
        for path, leaf in leaves:
            if M._is_pool_leaf(path):
                return leaf.shape[ax] - 1
    return None


def make_paged_decode_step(cfg: ArchConfig, qc: QuantContext, page_size: int,
                           masked: bool = False):
    """Paged twin of :func:`make_decode_sample_step`: same fused
    decode+sample+EOS contract with a ``block_tables`` (B, MP) operand after
    ``cache_len``.

    ``masked=True`` keeps the QoS-tier contract on the paged layout with a
    two-part merge: rows outside ``row_mask`` run under an all-sentinel
    block table (their pool writes land on the sentinel page — garbage that
    is never read unmasked — so pool leaves, which have no batch axis, are
    taken wholesale), while per-slot leaves (local rings, recurrent state)
    merge row-wise exactly as the dense step."""
    def step(params, tok, caches, cache_len, block_tables, key, alive,
             eos_id, temperature):
        logits, caches = M.paged_decode_step(params, tok, caches, cache_len,
                                             block_tables, cfg, qc,
                                             page_size=page_size)
        key, sub = jax.random.split(key)
        nxt = sample_logits_dynamic(logits, sub, temperature)
        alive = jnp.logical_and(alive, nxt[:, 0] != eos_id)
        return nxt, caches, key, alive

    _contract(step, name="fused_decode_paged", transfers_per_round=1,
              int_psum_axes=("expand", "expert"),
              dynamic_operands=("block_tables", "eos_id", "temperature"),
              donate_argnums=(2,), budget_key="decode_paged")
    if not masked:
        return step

    def masked_step(params, tok, caches, cache_len, block_tables, key, alive,
                    eos_id, temperature, row_mask):
        sentinel = _pool_sentinel(caches)
        bt_eff = block_tables
        if sentinel is not None:
            bt_eff = jnp.where(row_mask[:, None], block_tables, sentinel)
        nxt, new_caches, key, alive_new = step(
            params, tok, caches, cache_len, bt_eff, key, alive, eos_id,
            temperature)
        nxt = jnp.where(row_mask[:, None], nxt, tok)
        alive_out = jnp.where(row_mask, alive_new, alive)

        def merge(axis):
            def f(path, nw, old):
                if M._is_pool_leaf(path):
                    return nw          # unmasked writes went to the sentinel
                return _select_rows(nw, old, row_mask, axis)
            return f

        merged = {
            "stages": jax.tree_util.tree_map_with_path(
                merge(1), new_caches["stages"], caches["stages"]),
            "tail": jax.tree_util.tree_map_with_path(
                merge(0), new_caches["tail"], caches["tail"]),
        }
        return nxt, merged, key, alive_out

    _contract(masked_step, name="fused_decode_paged_masked",
              transfers_per_round=1, int_psum_axes=("expand", "expert"),
              dynamic_operands=("block_tables", "eos_id", "temperature",
                                "row_mask"),
              donate_argnums=(2,), budget_key="decode_paged")
    return masked_step


def _has_expanded(params) -> bool:
    """True when the tree carries ExpandedTensor leaves (a series term axis
    exists to truncate — the precondition for QoS tiers / term budgets)."""
    from repro.core.expansion import ExpandedTensor
    return any(isinstance(l, ExpandedTensor)
               for l in jax.tree_util.tree_leaves(
                   params, is_leaf=lambda l: isinstance(l, ExpandedTensor)))


def make_spec_decode_step(cfg: ArchConfig, qc: QuantContext,
                          qc_draft: QuantContext, lookahead: int,
                          masked: bool = False):
    """Fused draft-γ + verify speculative round (one dispatch, DESIGN.md §10).

    step(params, tok (B,1), caches, cache_len (B,)) ->
        (next_tok (B,1), caches', full_tok (B, γ+1), accept (B,)).

    Drafting runs ``lookahead`` greedy decode steps under the truncated
    ``qc_draft`` on a *functional* copy of the caches (its writes never
    reach the committed state — XLA materializes copies of only the buffers
    the draft touches).  Verification scores the chunk
    ``[tok, d_1..d_γ]`` in ONE full-series pass (:func:`model.verify_step`),
    accepts the longest prefix where draft and verify tokens agree, commits
    KV/state for exactly the accepted positions
    (:func:`model.commit_verify`), and returns the full-model token at the
    first mismatch (the "free" correction) as the next pending token.  The
    slot's new cache length is ``cache_len + accept + 1``.

    Greedy only: acceptance compares argmaxes, which is what makes the
    emitted stream token-identical to the non-speculative engine."""
    def step(params, tok, caches, cache_len):
        b = tok.shape[0]
        clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
        d_caches, d_tok = caches, tok
        drafts = []
        for j in range(lookahead):
            logits, d_caches = M.decode_step(params, d_tok, d_caches,
                                              clen + j, cfg, qc_draft)
            d_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            drafts.append(d_tok)
        drafts = jnp.concatenate(drafts, axis=1)               # (B, γ)
        chunk = jnp.concatenate([tok, drafts], axis=1)         # (B, γ+1)
        logits, deltas = M.verify_step(params, chunk, caches, clen, cfg, qc)
        full = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, γ+1)
        match = (drafts == full[:, :-1]).astype(jnp.int32)
        accept = jnp.sum(jnp.cumprod(match, axis=1), axis=1)   # (B,) in [0,γ]
        caches = M.commit_verify(caches, deltas, clen, accept, cfg)
        next_tok = jnp.take_along_axis(full, accept[:, None], axis=1)
        return next_tok, caches, full, accept

    _contract(step, name="spec_decode", transfers_per_round=1,
              int_psum_axes=("expand", "expert"), donate_argnums=(2,),
              budget_key="spec_decode")
    if not masked:
        return step

    # row-masked variant (``masked=True``): required whenever a chunked
    # prefill can be in flight — an unmasked speculative commit would write
    # draft garbage into the filling slot's ring/recurrent state.
    def masked_step(params, tok, caches, cache_len, row_mask):
        nxt, new_caches, full, accept = step(params, tok, caches, cache_len)
        nxt = jnp.where(row_mask[:, None], nxt, tok)
        full = jnp.where(row_mask[:, None], full, 0)
        accept = jnp.where(row_mask, accept, 0)
        merged = {
            "stages": jax.tree_util.tree_map(
                lambda nw, old: _select_rows(nw, old, row_mask, 1),
                new_caches["stages"], caches["stages"]),
            "tail": jax.tree_util.tree_map(
                lambda nw, old: _select_rows(nw, old, row_mask, 0),
                new_caches["tail"], caches["tail"]),
        }
        return nxt, merged, full, accept

    _contract(masked_step, name="spec_decode_masked", transfers_per_round=1,
              int_psum_axes=("expand", "expert"), dynamic_operands=("row_mask",),
              donate_argnums=(2,), budget_key="spec_decode_masked")
    return masked_step


def make_paged_spec_decode_step(cfg: ArchConfig, qc: QuantContext,
                                qc_draft: QuantContext, lookahead: int,
                                page_size: int, masked: bool = False):
    """Paged twin of :func:`make_spec_decode_step`: draft steps, the verify
    pass, and the commit all go through the slot block tables.  Admission
    reserves ``lookahead + 1`` extra positions' worth of pages per slot so
    the chunk writes never overflow the table (scheduler._admit)."""
    def step(params, tok, caches, cache_len, block_tables):
        b = tok.shape[0]
        clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
        d_caches, d_tok = caches, tok
        drafts = []
        for j in range(lookahead):
            logits, d_caches = M.paged_decode_step(
                params, d_tok, d_caches, clen + j, block_tables, cfg,
                qc_draft, page_size=page_size)
            d_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            drafts.append(d_tok)
        drafts = jnp.concatenate(drafts, axis=1)               # (B, γ)
        chunk = jnp.concatenate([tok, drafts], axis=1)         # (B, γ+1)
        logits, deltas = M.paged_verify_step(params, chunk, caches, clen,
                                             block_tables, cfg, qc,
                                             page_size=page_size)
        full = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, γ+1)
        match = (drafts == full[:, :-1]).astype(jnp.int32)
        accept = jnp.sum(jnp.cumprod(match, axis=1), axis=1)   # (B,) in [0,γ]
        caches = M.commit_verify_paged(caches, deltas, clen, accept,
                                       block_tables, cfg, page_size=page_size)
        next_tok = jnp.take_along_axis(full, accept[:, None], axis=1)
        return next_tok, caches, full, accept

    _contract(step, name="spec_decode_paged", transfers_per_round=1,
              int_psum_axes=("expand", "expert"),
              dynamic_operands=("block_tables",), donate_argnums=(2,),
              budget_key="spec_decode_paged")
    if not masked:
        return step

    # row-masked paged variant: unmasked rows draft/verify/commit through
    # an all-sentinel block table (pool writes become no-reads garbage) and
    # their per-slot leaves merge row-wise — the same two-part merge as the
    # masked paged decode step.  Required with chunked prefill / prefix
    # caching: a filling slot's table can hold shared (increfed) pages an
    # unmasked speculative write would corrupt for every sharer.
    def masked_step(params, tok, caches, cache_len, block_tables, row_mask):
        sentinel = _pool_sentinel(caches)
        bt_eff = block_tables
        if sentinel is not None:
            bt_eff = jnp.where(row_mask[:, None], block_tables, sentinel)
        nxt, new_caches, full, accept = step(
            params, tok, caches, cache_len, bt_eff)
        nxt = jnp.where(row_mask[:, None], nxt, tok)
        full = jnp.where(row_mask[:, None], full, 0)
        accept = jnp.where(row_mask, accept, 0)

        def merge(axis):
            def f(path, nw, old):
                if M._is_pool_leaf(path):
                    return nw          # unmasked writes went to the sentinel
                return _select_rows(nw, old, row_mask, axis)
            return f

        merged = {
            "stages": jax.tree_util.tree_map_with_path(
                merge(1), new_caches["stages"], caches["stages"]),
            "tail": jax.tree_util.tree_map_with_path(
                merge(0), new_caches["tail"], caches["tail"]),
        }
        return nxt, merged, full, accept

    _contract(masked_step, name="spec_decode_paged_masked",
              transfers_per_round=1, int_psum_axes=("expand", "expert"),
              dynamic_operands=("block_tables", "row_mask"),
              donate_argnums=(2,), budget_key="spec_decode_paged_masked")
    return masked_step


def make_prefill_chunk_step(cfg: ArchConfig, qc: QuantContext, *,
                            paged: bool, page_size: int = 0, s_max: int = 0):
    """Chunk-fused serving step (DESIGN.md §14): ONE dispatch advances the
    live decode rows by one token AND prefills one chunk of the filling
    prompt.

    step(params, tokens (B,C), caches, cache_len (B,)[, block_tables],
         key, alive (B,), eos_id (), temperature (), valid (B,),
         write_from (B,), commit_rows (B,), decode_rows (B,),
         seed_rows (B,), tok (B,1))
        -> (next_tok (B,1), caches', key', alive')

    Row roles (all dynamic bool masks — membership changes never retrace):

    * ``decode_rows``: live decode slots.  Their pending token is spliced
      into chunk column 0 with ``valid=1`` in-trace, and the chunked-scoring
      pass (:func:`model.chunk_prefill_step`) keeps them on the split
      cache/new decode formulation — a T=1 verify is exactly a decode, the
      identity the speculative engine already rests on — while prefill rows
      run the positional single-buffer formulation over the ``s_max``-wide
      cache, bit-identical to monolithic prefill (DESIGN.md §14).
    * the filling slot carries the real chunk with ``valid`` real tokens
      starting at position ``cache_len`` (chunk tails may be padding);
      ``seed_rows`` marks it on its FINAL chunk, when the prompt's last
      logit seeds the first generated token (monolithic prefill's sampled
      first token, bit-for-bit).
    * ``commit_rows`` = decode rows + the filling slot: only their caches
      advance; everything else keeps its state bit-for-bit (row-wise merge;
      on the paged layout unmasked rows write through the sentinel table).

    ``write_from`` is the per-row pool-write floor: positions below it are
    served by shared (increfed) prefix pages that must never be re-written
    — the recompute row of a fully-cached prompt and the first chunk after
    a prefix match both rely on it.  The dense layout has no shared rows;
    the operand is accepted and ignored there (one signature, one
    scheduler call site)."""
    def _body(params, tokens, caches, cache_len, block_tables, key, alive,
              eos_id, temperature, valid, write_from, commit_rows,
              decode_rows, seed_rows, tok):
        t = tokens.shape[1]
        tokens = tokens.at[:, 0].set(
            jnp.where(decode_rows, tok[:, 0], tokens[:, 0]))
        valid = jnp.where(decode_rows, jnp.int32(1),
                          jnp.asarray(valid, jnp.int32))
        if paged:
            logits_all, deltas = M.paged_chunk_prefill_step(
                params, tokens, caches, cache_len, block_tables, decode_rows,
                cfg, qc, page_size=page_size, s_max=s_max)
        else:
            logits_all, deltas = M.chunk_prefill_step(
                params, tokens, caches, cache_len, decode_rows, cfg, qc,
                s_max=s_max)
        # per-row logit at the last real chunk position (col 0 for decode
        # rows, ``valid-1`` for the filling slot)
        idx = jnp.clip(valid - 1, 0, t - 1)
        logits = jnp.take_along_axis(logits_all, idx[:, None, None],
                                     axis=1)[:, 0]
        key, sub = jax.random.split(key)
        nxt = sample_logits_dynamic(logits, sub, temperature)
        if paged:
            sentinel = _pool_sentinel(caches)
            bt_eff = block_tables
            if sentinel is not None:
                bt_eff = jnp.where(commit_rows[:, None], block_tables,
                                   sentinel)
            new_caches = M.commit_prefill_chunk_paged(
                caches, deltas, cache_len, valid, write_from, bt_eff, cfg,
                page_size=page_size)

            def merge(axis):
                def f(path, nw, old):
                    if M._is_pool_leaf(path):
                        return nw      # unmasked writes went to the sentinel
                    return _select_rows(nw, old, commit_rows, axis)
                return f

            merged = {
                "stages": jax.tree_util.tree_map_with_path(
                    merge(1), new_caches["stages"], caches["stages"]),
                "tail": jax.tree_util.tree_map_with_path(
                    merge(0), new_caches["tail"], caches["tail"]),
            }
        else:
            new_caches = M.commit_prefill_chunk(caches, deltas, cache_len,
                                                valid, cfg)
            merged = {
                "stages": jax.tree_util.tree_map(
                    lambda nw, old: _select_rows(nw, old, commit_rows, 1),
                    new_caches["stages"], caches["stages"]),
                "tail": jax.tree_util.tree_map(
                    lambda nw, old: _select_rows(nw, old, commit_rows, 0),
                    new_caches["tail"], caches["tail"]),
            }
        sample_rows = decode_rows | seed_rows
        tok_out = jnp.where(sample_rows[:, None], nxt, tok)
        not_eos = nxt[:, 0] != eos_id
        alive_out = jnp.where(seed_rows, not_eos,
                              jnp.where(decode_rows,
                                        jnp.logical_and(alive, not_eos),
                                        alive))
        return tok_out, merged, key, alive_out

    if paged:
        def step(params, tokens, caches, cache_len, block_tables, key,
                 alive, eos_id, temperature, valid, write_from, commit_rows,
                 decode_rows, seed_rows, tok):
            return _body(params, tokens, caches, cache_len, block_tables,
                         key, alive, eos_id, temperature, valid, write_from,
                         commit_rows, decode_rows, seed_rows, tok)
        _contract(step, name="prefill_chunk_paged", transfers_per_round=1,
                  int_psum_axes=("expand", "expert"),
                  dynamic_operands=("block_tables", "eos_id", "temperature",
                                    "valid", "write_from", "commit_rows",
                                    "decode_rows", "seed_rows"),
                  donate_argnums=(2,), budget_key="prefill_chunk_paged")
        return step

    def step(params, tokens, caches, cache_len, key, alive, eos_id,
             temperature, valid, write_from, commit_rows, decode_rows,
             seed_rows, tok):
        return _body(params, tokens, caches, cache_len, None, key, alive,
                     eos_id, temperature, valid, write_from, commit_rows,
                     decode_rows, seed_rows, tok)
    _contract(step, name="prefill_chunk", transfers_per_round=1,
              int_psum_axes=("expand", "expert"),
              dynamic_operands=("eos_id", "temperature", "valid",
                                "write_from", "commit_rows", "decode_rows",
                                "seed_rows"),
              donate_argnums=(2,), budget_key="prefill_chunk")
    return step


class Engine:
    def __init__(self, cfg: ArchConfig, params: Optional[PyTree] = None, *,
                 policy: Optional[ExpansionPolicy] = None,
                 artifact: Optional[Any] = None,
                 backend: Optional[str] = None,
                 serve_cfg: ServeConfig = ServeConfig(),
                 use_kernel: bool = False,
                 mesh: Optional[Any] = None,
                 placement: str = "replicated",
                 _bound_params: Optional[PyTree] = None):
        """Admit a model either as raw FP ``params`` (optionally expanded
        here when ``policy`` is given — the legacy per-engine path) or as a
        pre-built ``artifact`` (:class:`repro.api.QuantArtifact`): the
        quantized params are bound as-is, so a model is expanded once per
        process (at ``quantize`` time), not once per engine.  ``backend``
        picks the artifact execution path (``ref`` | ``pallas`` |
        ``pallas-packed``; see :class:`repro.api.Runtime`).

        ``mesh`` + ``placement`` serve the model multi-device (DESIGN.md
        §9): ``"term"`` scatters series terms over the mesh at admission
        (zero-plane padded when terms don't divide the axis) and runs every
        expanded GEMM as shard_map + one psum; ``"tensor"`` shards output
        columns.  Both serve the exact slot-scheduler workload of the
        replicated engine — same admitted requests, same generated tokens.

        Capacity knobs (``max_seq``, ``max_batch``, ``max_slots``,
        ``hbm_budget_bytes``, ``prefill_bucket``) are fixed at construction;
        ``temperature`` and ``eos_id`` are dynamic and may be swapped via
        ``engine.sc`` between runs without retracing."""
        from repro.dist.placement import check_placement, place_params
        self.cfg = cfg
        self.sc = serve_cfg
        self.mesh = mesh
        self.placement = check_placement(placement)
        if serve_cfg.scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {serve_cfg.scheduler!r}; "
                             f"one of {SCHEDULERS}")
        if artifact is not None:
            if params is not None or policy is not None:
                raise ValueError(
                    "pass either artifact= or (params, policy), not both")
            backend = backend or ("pallas" if use_kernel else "ref")
            self.qc = artifact.quant_context(backend)
            # _bound_params: a Runtime hands over its already backend-bound
            # (and mesh-placed) tree, so serve() does not re-derive and
            # re-place a second resident copy of the weights
            params = (_bound_params if _bound_params is not None
                      else artifact.runtime_params(backend))
            self.quant_seconds = artifact.quant_seconds  # paid once, upstream
        else:
            if params is None:
                raise ValueError("Engine needs params or an artifact")
            self.qc = QuantContext(policy=policy, use_kernel=use_kernel) if policy else FP
            t0 = time.perf_counter()
            if policy is not None:
                params = jax.jit(lambda p: PTQ.expand_params(p, policy))(params)
                params = jax.block_until_ready(params)
            self.quant_seconds = time.perf_counter() - t0
        if self.placement != "replicated":
            if self.qc.use_kernel:
                raise ValueError(
                    f"placement={self.placement!r} serves the reference "
                    f"path only (interpret-mode Pallas callbacks cannot be "
                    f"partitioned); use backend='ref'")
            if self.placement == "term":
                from repro.core.expansion import ExpandedTensor
                if not any(isinstance(l, ExpandedTensor)
                           for l in jax.tree_util.tree_leaves(
                               params,
                               is_leaf=lambda l: isinstance(l, ExpandedTensor))):
                    raise ValueError(
                        "placement='term' distributes series terms, but these "
                        "params carry no ExpandedTensor leaves (FP or "
                        "baseline-PTQ model) — use placement='tensor' or "
                        "'replicated'")
            if self.placement == "expert":
                kinds = tuple(cfg.stage_pattern) + tuple(cfg.tail_pattern)
                if "moe_attn" not in kinds:
                    raise ValueError(
                        "placement='expert' shards MoE experts, but this "
                        "arch has no moe_attn blocks — use placement="
                        "'term', 'tensor' or 'replicated'")
                if not _has_expanded(params):
                    raise ValueError(
                        "placement='expert' runs the grouped series GEMM "
                        "over sharded expert expansions, but these params "
                        "carry no ExpandedTensor leaves (FP or baseline-PTQ "
                        "model) — expand first (quantize) or use "
                        "placement='replicated'")
            # params may arrive pre-placed from Runtime — place_params is
            # idempotent there (padding an already-padded tree and device_put
            # onto an identical sharding are no-ops), so re-placing keeps the
            # direct Engine(..., mesh=..., placement=...) entry equivalent
            # without duplicating a Runtime's placed weights
            params = place_params(params, mesh, self.placement)
            if self.placement in ("term", "expert"):
                self.qc = dataclasses.replace(self.qc, mesh=mesh,
                                              placement=self.placement)
        self.has_moe = "moe_attn" in (tuple(cfg.stage_pattern)
                                      + tuple(cfg.tail_pattern))
        if self.has_moe:
            # serving routing contract (DESIGN.md §15): dropless per-token
            # dispatch.  A row's routing is a function of that row alone —
            # no capacity cumsum coupling it to co-scheduled rows — so slot
            # recycling, row masks, and batch composition never perturb a
            # request's tokens, and every placement serves the identical
            # stream.
            self.qc = dataclasses.replace(self.qc, moe_routing="token")
        self.params = params
        self.expanded = _has_expanded(params)
        self._validate_qos(serve_cfg)
        self.paged = serve_cfg.paged
        if self.paged:
            self._validate_paged(serve_cfg)
        self.chunked = serve_cfg.prefill_chunk > 0 or serve_cfg.prefix_cache
        if self.chunked:
            self._validate_chunked(serve_cfg)
        if serve_cfg.term_budget is not None:
            # static whole-engine truncation: by Theorem 1 the k-term prefix
            # is itself a coherent lower-bit model, so the engine simply
            # serves under a tighter QuantContext; per-request tiers below
            # are resolved RELATIVE to this context (they can only tighten)
            self.qc = dataclasses.replace(self.qc,
                                          term_budget=serve_cfg.term_budget)
        if serve_cfg.scheduler != "slots" or serve_cfg.spec_terms > 0:
            # tiers ride the masked slots dispatch loop; the grouped baseline
            # and the speculative loop (which spends the term axis on drafts)
            # serve the full context only
            self.tiers = {"full": Q.TierSpec("full", None, None)}
        else:
            self.tiers = Q.resolve_tiers(serve_cfg.tier_budgets,
                                         expanded=self.expanded)
        self._queue: List[Request] = []
        self._next_id = 0
        self.last_run_stats: Dict[str, Any] = {}
        self.last_request_metrics: Dict[int, Dict[str, float]] = {}

        s_max = serve_cfg.max_seq  # frozen at construction (jit closure)
        self._prefill = jax.jit(
            lambda p, batch: M.prefill(p, batch, cfg, self.qc, s_max=s_max))
        self._prefill_slot = jax.jit(_contract(
            lambda p, batch, lengths: M.prefill(p, batch, cfg, self.qc,
                                                s_max=s_max, lengths=lengths),
            name="prefill_slot", int_psum_axes=("expand", "expert"),
            budget_key="prefill"))
        self._scatter = jax.jit(M.scatter_cache_into_slot, donate_argnums=(0,))
        # fresh one-row cache for chunked-fill admission on dense engines:
        # a recycled slot keeps its previous occupant's ring positions and
        # recurrent carries, which monolithic admission overwrites wholesale
        # via _scatter but an incremental chunk commit would inherit
        self._fresh_row_cache = None
        self._moe_stats = False
        if self.paged:
            page = serve_cfg.page_size
            self._scatter_paged = jax.jit(
                lambda live, pref, slot, page_ids: M.scatter_cache_into_pages(
                    live, pref, slot, page_ids, page),
                donate_argnums=(0,))
            self._decode = jax.jit(
                make_paged_decode_step(cfg, self.qc, page, masked=True),
                donate_argnums=(2,))
        else:
            # per-round expert-load telemetry rides the fused decode step on
            # MoE archs (plain slots decode only: the spec/paged/chunk
            # dispatches stay stats-free — DESIGN.md §15)
            self._moe_stats = (self.has_moe
                               and serve_cfg.scheduler == "slots"
                               and serve_cfg.spec_terms == 0)
            self._decode = jax.jit(
                make_decode_sample_step(cfg, self.qc, masked=True,
                                        moe_stats=self._moe_stats),
                donate_argnums=(2,))
        # per-term-budget jitted callables (QoS tiers): budget None = the
        # engine's own context.  Populated lazily — an engine that never
        # serves a degraded tier never traces a truncated step.
        self._decode_by_budget: Dict[Optional[int], Any] = {None: self._decode}
        self._prefill_by_budget: Dict[Optional[int], Any] = {
            None: self._prefill_slot}
        # chunk-fused prefill steps, keyed like _decode_by_budget (lazily
        # traced — an engine that never chunks never traces one)
        self._chunk_by_budget: Dict[Optional[int], Any] = {}
        self._spec = None
        # with a chunked fill potentially in flight, speculative rounds
        # must be row-masked (an unmasked commit would corrupt the filling
        # slot's state / shared pages)
        self._spec_takes_mask = serve_cfg.spec_terms > 0 and self.chunked
        if serve_cfg.spec_terms > 0:
            self._validate_spec(serve_cfg)
            self.qc_draft = dataclasses.replace(
                self.qc, term_budget=serve_cfg.spec_terms)
            if self.paged:
                self._spec = jax.jit(
                    make_paged_spec_decode_step(cfg, self.qc, self.qc_draft,
                                                serve_cfg.spec_lookahead,
                                                serve_cfg.page_size,
                                                masked=self._spec_takes_mask),
                    donate_argnums=(2,))
            else:
                self._spec = jax.jit(
                    make_spec_decode_step(cfg, self.qc, self.qc_draft,
                                          serve_cfg.spec_lookahead,
                                          masked=self._spec_takes_mask),
                    donate_argnums=(2,))
        self._slots: Optional[SlotScheduler] = None

    def _validate_spec(self, sc: ServeConfig) -> None:
        """Self-speculative decoding preconditions, checked at construction:
        the knobs are capacity-like (fixed per engine), and a late failure
        would strand admitted requests."""
        from repro.core.expansion import ExpandedTensor
        if sc.scheduler != "slots":
            raise ValueError(
                "spec_terms>0 requires scheduler='slots' (the grouped legacy "
                "path is the bit-exactness baseline and stays speculation-free)")
        if sc.spec_lookahead < 1:
            raise ValueError(
                f"spec_lookahead must be >= 1, got {sc.spec_lookahead}")
        if not any(isinstance(l, ExpandedTensor)
                   for l in jax.tree_util.tree_leaves(
                       self.params,
                       is_leaf=lambda l: isinstance(l, ExpandedTensor))):
            raise ValueError(
                "spec_terms>0 drafts with a truncated series, but these "
                "params carry no ExpandedTensor leaves (FP or baseline-PTQ "
                "model) — there is no term axis to truncate")
        if "local" in (tuple(self.cfg.stage_pattern) + tuple(self.cfg.tail_pattern)) \
                and self.cfg.window < sc.spec_lookahead + 1:
            raise ValueError(
                f"spec_lookahead={sc.spec_lookahead} needs a local-attention "
                f"window of at least lookahead+1 (got window={self.cfg.window}): "
                f"a verify chunk must fit the ring without self-collision")

    def _validate_paged(self, sc: ServeConfig) -> None:
        """Paged-KV preconditions (capacity-like: fixed per engine)."""
        if sc.scheduler != "slots":
            raise ValueError(
                "paged=True requires scheduler='slots' (the grouped legacy "
                "path is the dense bit-exactness baseline)")
        if sc.chaos is not None:
            raise ValueError(
                "paged=True does not support chaos injection: a chaos-"
                "squeezed HBM budget would need live page-pool resizing — "
                "run chaos drills on the dense engine")
        if sc.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {sc.page_size}")
        if sc.num_pages < 0:
            raise ValueError(f"num_pages must be >= 0, got {sc.num_pages}")

    def _validate_chunked(self, sc: ServeConfig) -> None:
        """Chunked-prefill / prefix-cache preconditions (capacity-like:
        fixed per engine)."""
        kinds = set(tuple(self.cfg.stage_pattern) + tuple(self.cfg.tail_pattern))
        if sc.prefill_chunk < 0:
            raise ValueError(
                f"prefill_chunk must be >= 0, got {sc.prefill_chunk}")
        if sc.scheduler != "slots":
            raise ValueError(
                "prefill_chunk/prefix_cache require scheduler='slots' (the "
                "grouped legacy path prefills whole groups monolithically)")
        if "cross" in kinds:
            raise ValueError(
                "chunked prefill does not serve cross-attention archs: the "
                "chunk-scoring pass carries no image-KV side input, so the "
                "static cross caches would never be written")
        if self.qc.int8_kv:
            raise ValueError(
                "chunked prefill requires exact (fp) KV caches: int8_kv "
                "round-trips cached keys through a lossy quantizer, so a "
                "chunked prefill could never be token-identical to the "
                "monolithic pass it must reproduce")
        if sc.paged and sc.max_seq % sc.page_size != 0:
            raise ValueError(
                f"chunked prefill over the paged layout requires max_seq "
                f"({sc.max_seq}) divisible by page_size ({sc.page_size}): "
                f"the gathered pool buffer (max_pages * page_size wide) must "
                f"equal the dense slot capacity for the positional "
                f"formulation to be bit-identical across layouts")
        if sc.prefix_cache:
            if not sc.paged:
                raise ValueError(
                    "prefix_cache=True requires paged=True: prefixes are "
                    "shared at page granularity through block tables")
            if sc.tier_budgets is not None:
                raise ValueError(
                    "prefix_cache=True is incompatible with QoS tiers: a "
                    "cached page holds KV computed under ONE term budget, "
                    "and sharing it across tiers would break each tier's "
                    "bit-identity contract")
            stateful = kinds & {"local", "rglru", "ssm"}
            if kinds & {"attn", "moe_attn"} and stateful:
                raise ValueError(
                    f"prefix_cache=True cannot serve archs mixing paged "
                    f"attention with {sorted(stateful)} state: pages cannot "
                    f"reconstruct a matched prefix's per-slot ring/recurrent "
                    f"carries — serve this arch with prefix_cache=False")

    def _validate_qos(self, sc: ServeConfig) -> None:
        """QoS knob preconditions, checked at construction (capacity-like:
        fixed per engine, and a late failure would strand admitted work)."""
        if sc.term_budget is not None:
            if sc.term_budget < 1:
                raise ValueError(
                    f"term_budget must be >= 1, got {sc.term_budget}")
            if not self.expanded:
                raise ValueError(
                    "term_budget truncates the series term axis, but these "
                    "params carry no ExpandedTensor leaves (FP or baseline-"
                    "PTQ model) — there is no term axis to truncate")
        if sc.tier_budgets is not None:
            if sc.scheduler != "slots":
                raise ValueError(
                    "QoS tiers require scheduler='slots' (the grouped legacy "
                    "path is the bit-exactness baseline and serves 'full' "
                    "only)")
            if sc.spec_terms > 0:
                raise ValueError(
                    "QoS tiers and self-speculative decoding are mutually "
                    "exclusive: both spend the series term axis (drafts "
                    "truncate it already) — pick one per engine")
            if not self.expanded:
                raise ValueError(
                    "tier_budgets names truncated-series tiers, but these "
                    "params carry no ExpandedTensor leaves (FP or baseline-"
                    "PTQ model) — only quality='full' is servable")
        if sc.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {sc.max_queue}")

    # -- per-tier QuantContexts / jitted callables -----------------------
    def _norm_budget(self, budget: Optional[int]) -> Optional[int]:
        """Canonical per-dispatch budget: tightened to the engine's own
        static ``term_budget`` and collapsed to ``None`` when it equals the
        full context — so equal-context tiers share one jitted step (and
        the jit cache stays one entry per *distinct* truncation)."""
        if budget is None:
            return None
        b = int(budget)
        tb = self.qc.term_budget
        if tb is not None:
            b = min(b, tb)
            if b >= tb:
                return None
        return b

    def _qc_for(self, budget: Optional[int]) -> QuantContext:
        budget = self._norm_budget(budget)
        if budget is None:
            return self.qc
        return dataclasses.replace(self.qc, term_budget=budget)

    def _decode_for(self, budget: Optional[int]):
        """The masked fused decode step under ``term_budget=budget`` —
        identical construction to ``self._decode`` (only the QuantContext
        differs), so a tier's output is bit-identical to an engine built
        statically on that truncated context."""
        budget = self._norm_budget(budget)
        if budget is None:
            # Live attribute, not the dict entry: tests (and the watchdog
            # harness) monkeypatch ``eng._decode`` to observe dispatches.
            return self._decode
        if budget not in self._decode_by_budget:
            if self.paged:
                self._decode_by_budget[budget] = jax.jit(
                    make_paged_decode_step(self.cfg, self._qc_for(budget),
                                           self.sc.page_size, masked=True),
                    donate_argnums=(2,))
            else:
                self._decode_by_budget[budget] = jax.jit(
                    make_decode_sample_step(self.cfg, self._qc_for(budget),
                                            masked=True,
                                            moe_stats=self._moe_stats),
                    donate_argnums=(2,))
        return self._decode_by_budget[budget]

    def _chunk_for(self, budget: Optional[int]):
        """The chunk-fused prefill step under ``term_budget=budget`` —
        same lazy per-budget jit cache as ``_decode_for``, so a tier's
        chunks are scored by exactly the series prefix that will decode
        it."""
        budget = self._norm_budget(budget)
        if budget not in self._chunk_by_budget:
            if self.paged:
                fn = make_prefill_chunk_step(self.cfg, self._qc_for(budget),
                                             paged=True,
                                             page_size=self.sc.page_size,
                                             s_max=self.sc.max_seq)
            else:
                fn = make_prefill_chunk_step(self.cfg, self._qc_for(budget),
                                             paged=False,
                                             s_max=self.sc.max_seq)
            self._chunk_by_budget[budget] = jax.jit(fn, donate_argnums=(2,))
        return self._chunk_by_budget[budget]

    def _fresh_row(self):
        """A zero-initialized one-row dense cache, scattered into a slot at
        chunked-fill admission.  Chunk commits are incremental, so without
        this reset a recycled slot would resume from its previous
        occupant's local-ring ``slot_pos`` and rglru/ssm carries — stale
        state that monolithic admission's wholesale ``_scatter`` never
        exposes.  Built once (it is never donated: ``_scatter`` donates the
        live cache, argument 0)."""
        if self._fresh_row_cache is None:
            self._fresh_row_cache = M.init_cache(
                self.cfg, 1, self.sc.max_seq, int8_kv=self.qc.int8_kv,
                mesh=self.mesh)
        return self._fresh_row_cache

    def _prefill_slot_for(self, budget: Optional[int]):
        """Length-masked prefill under a tier's term budget: a degraded
        request's prompt is processed by the same truncated series that
        will decode it (required for the static-truncation bit-identity)."""
        budget = self._norm_budget(budget)
        if budget is None:
            return self._prefill_slot
        if budget not in self._prefill_by_budget:
            qc = self._qc_for(budget)
            cfg, s_max = self.cfg, self.sc.max_seq
            self._prefill_by_budget[budget] = jax.jit(
                lambda p, batch, lengths: M.prefill(p, batch, cfg, qc,
                                                    s_max=s_max,
                                                    lengths=lengths))
        return self._prefill_by_budget[budget]

    @property
    def spec_enabled(self) -> bool:
        return self._spec is not None

    @property
    def series_terms(self) -> Optional[int]:
        """Series terms the engine's own (full) context runs: the largest
        ExpandedTensor term count in the bound params, tightened by a
        static ``term_budget``.  ``None`` for FP/baseline-PTQ params (no
        term axis) — QoS metrics then report 0 effective terms."""
        if not self.expanded:
            return None
        from repro.core.expansion import ExpandedTensor
        t = max(l.num_terms for l in jax.tree_util.tree_leaves(
                    self.params,
                    is_leaf=lambda l: isinstance(l, ExpandedTensor))
                if isinstance(l, ExpandedTensor))
        if self.qc.term_budget is not None:
            t = min(t, self.qc.term_budget)
        return int(t)

    # ------------------------------------------------------------------
    def add_request(self, tokens: Sequence[int],
                    max_new_tokens: Optional[int] = None, *,
                    quality: str = "full",
                    deadline_s: Optional[float] = None,
                    priority: int = 0,
                    arrival: float = 0.0):
        """Queue a prompt; returns the request id, or a typed
        :class:`repro.infer.qos.Rejection` when the engine is saturated.

        Programmer errors (malformed prompt, impossible budget, a quality
        tier this engine does not serve) raise ``ValueError``; *load*
        conditions (queue at ``max_queue``, no usable slot under a squeezed
        HBM budget, an already-hopeless deadline) return a ``Rejection``
        result the caller can match on and retry
        (``repro.launch.common.submit_with_backoff``).

        ``quality`` picks the request's tier (``engine.tiers``); ``full``
        is always served at the engine's own context.  ``deadline_s`` is a
        wall-clock budget from *now*: a request that cannot finish in time
        is cancelled mid-run and its slot recycled.  Higher ``priority``
        admits first (FCFS within a priority level).  ``arrival > 0``
        delays the request's open-loop arrival to that many seconds after
        ``run()`` starts (the Poisson serving benchmark's offered-load
        knob); TTFT and queue-wait then measure from the arrival instant.

        Validates capacity here (a proper error, not an ``assert`` that
        vanishes under ``python -O``): the prompt plus its token budget —
        ``max_new_tokens`` if given, else at least one generated token —
        must fit ``ServeConfig.max_seq``.  A request without its own budget
        is re-checked against the run-level ``max_new_tokens`` at run time."""
        toks = [int(t) for t in tokens]
        if not toks:
            raise ValueError("empty prompt")
        need = len(toks) + (max_new_tokens if max_new_tokens is not None else 1)
        if max_new_tokens is not None and max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if need > self.sc.max_seq:
            raise ValueError(
                f"request rejected: prompt len {len(toks)} + max_new_tokens "
                f"{max_new_tokens if max_new_tokens is not None else 1} exceeds "
                f"ServeConfig.max_seq={self.sc.max_seq}")
        if quality not in self.tiers:
            raise ValueError(
                f"unknown quality {quality!r}: this engine serves "
                f"{sorted(self.tiers)} (degraded tiers need an expanded "
                f"model on the plain slots scheduler)")
        if deadline_s is not None and self.sc.scheduler != "slots":
            raise ValueError(
                "deadline_s requires scheduler='slots' (the grouped path "
                "drains groups to completion and cannot cancel mid-run)")
        now = time.perf_counter()
        if deadline_s is not None and deadline_s <= 0:
            return Q.Rejection(
                Q.RejectReason.DEADLINE_INFEASIBLE,
                detail=f"deadline_s={deadline_s} already expired",
                retryable=False, retry_after_s=0.0)
        if self.sc.max_queue > 0 and len(self._queue) >= self.sc.max_queue:
            return Q.Rejection(
                Q.RejectReason.CAPACITY,
                detail=f"queue at ServeConfig.max_queue={self.sc.max_queue}")
        if self.sc.scheduler == "slots" and self.sc.chaos is not None:
            # a chaos-squeezed HBM budget can leave zero usable slots: new
            # admissions are shed (typed + retryable) while in-flight work
            # rides out the squeeze under degraded budgets
            if self._slots is None:
                self._slots = SlotScheduler(self)
            if self._slots.usable_slots_now() == 0:
                return Q.Rejection(
                    Q.RejectReason.HBM,
                    detail="no usable slot under the effective HBM budget")
        rid = self._next_id
        self._next_id += 1
        if arrival < 0:
            raise ValueError(f"arrival must be >= 0, got {arrival}")
        if arrival > 0 and self.sc.scheduler != "slots":
            raise ValueError(
                "arrival > 0 requires scheduler='slots' (the grouped path "
                "forms its batches up front and cannot model open-loop "
                "arrivals)")
        self._queue.append(Request(
            rid=rid, tokens=toks, max_new_tokens=max_new_tokens,
            t_enqueue=now, quality=quality, priority=priority,
            deadline_s=deadline_s,
            deadline=(now + deadline_s) if deadline_s is not None else None,
            arrival=arrival))
        return rid

    def run(self, max_new_tokens: int = 16) -> Dict[int, List[int]]:
        """Drain the queue; returns request id -> generated tokens.

        Validation failures (a queued request whose run-level budget
        overflows ``max_seq``) raise *before any work* and leave the queue
        intact, so the caller can retry with a smaller budget."""
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if self.sc.scheduler == "grouped":
            return self._run_grouped(max_new_tokens)
        if self._slots is None:
            self._slots = SlotScheduler(self)
        try:
            out = self._slots.run(self._queue, max_new_tokens)
            self._queue = []
        finally:
            self.last_run_stats = self._slots.last_run_stats
            self.last_request_metrics = self._slots.last_request_metrics
        return out

    # -- legacy group-drain path (bit-exactness baseline) ----------------
    def _form_groups(self) -> List[List[Request]]:
        by_len: Dict[int, List[Request]] = defaultdict(list)
        for req in self._queue:
            by_len[len(req.tokens)].append(req)
        groups = []
        for _, reqs in sorted(by_len.items()):
            for i in range(0, len(reqs), self.sc.max_batch):
                groups.append(reqs[i:i + self.sc.max_batch])
        return groups

    def _run_grouped(self, max_new_tokens: int) -> Dict[int, List[int]]:
        groups = self._form_groups()
        for group in groups:             # validate everything before any work
            budgets = [req.max_new_tokens if req.max_new_tokens is not None
                       else max_new_tokens for req in group]
            for req, m in zip(group, budgets):
                # same contract as the slots path: the prefill-sampled first
                # token cannot be withheld, so a zero budget is an error, not
                # a silent one-token generation
                if m < 1:
                    raise ValueError(
                        f"request {req.rid}: effective max_new_tokens must "
                        f"be >= 1, got {m}")
            s = len(group[0].tokens)
            if s + max(budgets) > self.sc.max_seq:
                raise ValueError(
                    f"requests {[r.rid for r in group]}: prompt len {s} + "
                    f"max_new_tokens {max(budgets)} exceeds "
                    f"ServeConfig.max_seq={self.sc.max_seq}")
        out: Dict[int, List[int]] = {}
        key = jax.random.PRNGKey(self.sc.seed)
        temperature = jnp.float32(self.sc.temperature)
        eos = jnp.int32(self.sc.eos_id)
        capacity = self.sc.max_batch
        steps_total = 0        # decode DISPATCHES (final fetch runs none)
        occupied_steps = 0.0
        gen_tokens = 0
        prefill_s = 0.0
        t_run0 = time.perf_counter()
        for group in groups:
            prompts = np.array([req.tokens for req in group], np.int32)
            b, s = prompts.shape
            mask_all = jnp.ones((b,), bool)   # every row commits (no tiers)
            budgets = np.array([req.max_new_tokens if req.max_new_tokens is not None
                                else max_new_tokens for req in group])
            t_admit = time.perf_counter()
            logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
            key, sub = jax.random.split(key)        # fresh key per prefill:
            tok = self._sample(logits, sub)         # groups sample independently
            alive = tok[:, 0] != eos                # on-device EOS mask
            prefill_s += time.perf_counter() - t_admit
            gen = [[] for _ in group]
            alive_host = np.ones(b, bool)           # aliveness BEFORE tok
            clen = jnp.int32(s)
            for t in range(int(budgets.max())):
                # the ONE host transfer of this decode step
                tok_host, alive_after = jax.device_get((tok, alive))
                for i in range(b):
                    if alive_host[i]:
                        gen[i].append(int(tok_host[i, 0]))
                        gen_tokens += 1
                # per-request budgets cap the drain alongside the EOS mask
                budget_ok = np.array([len(g) < m for g, m in zip(gen, budgets)])
                alive_host = np.asarray(alive_after) & budget_ok
                if not alive_host.any():
                    break
                # count the dispatch here (the iteration that drains the last
                # pending tokens breaks above without decoding — counting at
                # the loop top overstated decode_steps by one per group)
                steps_total += 1
                occupied_steps += float(alive_host.sum()) / capacity
                tok, caches, key, alive = self._decode(
                    self.params, tok, caches, clen, key, alive, eos,
                    temperature, mask_all)
                clen = clen + 1
            t_done = time.perf_counter()
            for req, g in zip(group, gen):
                out[req.rid] = g
                req.t_admitted, req.t_first_token = t_admit, t_admit
                req.t_done, req.new_tokens = t_done, len(g)
        wall = time.perf_counter() - t_run0
        decode_s = max(wall - prefill_s, 1e-9)  # same accounting as slots
        self.last_request_metrics = {req.rid: req.metrics() for req in self._queue}
        self.last_run_stats = {
            "scheduler": "grouped",
            "placement": self.placement,
            "mesh_devices": self.mesh_devices,
            "n_slots": capacity,
            "requests": len(self._queue),
            "generated_tokens": gen_tokens,
            "decode_steps": steps_total,
            # alive-slot fraction at each decode dispatch — the same
            # definition the slots path uses, so the two are comparable
            "occupancy": (occupied_steps / steps_total
                          if steps_total else 0.0),
            "wall_seconds": wall,
            "prefill_seconds": prefill_s,
            "decode_seconds": decode_s,
            # zero/near-zero durations map to 0.0 (tiny CI runs must emit
            # finite, comparable metrics JSON — never inf/NaN)
            "decode_tokens_per_sec": Q.safe_rate(gen_tokens, decode_s),
            "tokens_per_sec": Q.safe_rate(gen_tokens, wall),
        }
        self._queue.clear()
        return out

    @property
    def mesh_devices(self) -> int:
        """Devices this engine's placement spans (1 when replicated)."""
        return self.mesh.size if self.mesh is not None else 1

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        return _sample_logits(logits, key, self.sc.temperature)
