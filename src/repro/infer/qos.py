"""QoS tiers, load-adaptive degradation, backpressure, and fault injection.

Theorem 1 makes every k-term prefix of an FP=xINT artifact a coherent
lower-bit model sharing weights/scales/KV layout with the full series, so a
serving engine can degrade *quality* at runtime — per request, per step —
without reloading weights.  This module is the serving robustness layer
built on that property (DESIGN.md §11):

* **tiers** — named quality levels (``"full"`` | ``"k2"`` | ``"k1"`` by
  default, or a custom ladder) that map each request to a
  ``QuantContext.term_budget``.  The slot scheduler routes every slot
  through its tier's budget, so one resident artifact serves all tiers;
* **load-adaptive degradation** — :class:`DegradeController`, a hysteresis
  state machine fed by queue depth, HBM admission headroom, and a
  deadline-miss estimator.  Under pressure the degradable tiers drop to
  their floor budget (the scheduler serves them cheaper and the queue
  drains faster); when pressure clears for ``cooldown_steps`` consecutive
  rounds, nominal budgets are restored;
* **backpressure** — admission rejections are typed *results*
  (:class:`Rejection` with a :class:`RejectReason`), not exceptions: the
  caller inspects ``reason``/``retryable``/``retry_after_s`` and retries
  (``repro.launch.common.submit_with_backoff`` is the bounded-backoff
  helper);
* **fault injection** — :class:`ChaosConfig` / :class:`ChaosInjector`: a
  seeded, deterministic harness that injects dispatch latency spikes,
  transient dispatch failures, and artificial HBM-budget squeezes into the
  scheduler loop, so degradation, deadlines, and the dispatch watchdog are
  CI-testable without real hardware faults.  Chaos perturbs *scheduling
  only* — it never reaches a jitted computation — so with degradation
  disabled (or only non-degradable tiers in flight) generated tokens are
  identical to a chaos-free run; when degradation responds to an injected
  squeeze, degradable tiers intentionally serve fewer terms and their
  tokens change accordingly (that IS the graceful-degradation response).
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.analysis.contracts import annotate as _contract

# the default quality ladder: tier name -> term budget (None = full series)
DEFAULT_TIER_BUDGETS: Tuple[Tuple[str, int], ...] = (("k2", 2), ("k1", 1))


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One quality tier: a named ``QuantContext.term_budget``.

    ``budget=None`` is the engine's full context (whatever the artifact and
    ``ServeConfig.term_budget`` define).  ``floor`` is the budget served
    while the scheduler is degraded; ``floor=None`` (or ``floor == budget``)
    marks the tier non-degradable — the ``full`` tier is always
    non-degradable, so its token-identity contract survives any load."""
    name: str
    budget: Optional[int]          # None = full series
    floor: Optional[int] = None    # degraded budget; None = never degrade

    @property
    def degradable(self) -> bool:
        return (self.floor is not None and self.budget is not None
                and self.floor < self.budget)

    def budget_now(self, degraded: bool) -> Optional[int]:
        return self.floor if (degraded and self.degradable) else self.budget


def resolve_tiers(tier_budgets: Optional[Tuple[Tuple[str, int], ...]],
                  *, expanded: bool) -> Dict[str, TierSpec]:
    """The tier table an engine serves: ``full`` plus the degradable ladder.

    ``tier_budgets`` is ``ServeConfig.tier_budgets`` (or the recipe's
    recorded ``qos_tiers``); ``None`` selects :data:`DEFAULT_TIER_BUDGETS`.
    Non-``full`` tiers truncate the series term axis, so a model without
    :class:`ExpandedTensor` leaves (``expanded=False``) serves ``full``
    only."""
    tiers = {"full": TierSpec("full", None, None)}
    if not expanded:
        return tiers
    ladder = DEFAULT_TIER_BUDGETS if tier_budgets is None else tier_budgets
    budgets = []
    for name, budget in ladder:
        if name == "full":
            raise ValueError("'full' is the implicit top tier; name custom "
                             "tiers something else")
        if name in tiers:
            raise ValueError(f"duplicate tier name {name!r}")
        if int(budget) < 1:
            raise ValueError(f"tier {name!r}: term budget must be >= 1, "
                             f"got {budget}")
        tiers[name] = TierSpec(name, int(budget))
        budgets.append(int(budget))
    if budgets:
        floor = min(budgets)
        for name in list(tiers):
            t = tiers[name]
            if t.budget is not None and floor < t.budget:
                tiers[name] = dataclasses.replace(t, floor=floor)
    return tiers


# ---------------------------------------------------------------------------
# typed admission rejections (backpressure)
# ---------------------------------------------------------------------------
class RejectReason(enum.Enum):
    CAPACITY = "capacity"          # request queue at ServeConfig.max_queue
    HBM = "hbm"                    # no usable slot under the (possibly
    #                                squeezed) HBM budget right now
    DEADLINE_INFEASIBLE = "deadline_infeasible"  # deadline already hopeless


@dataclasses.dataclass(frozen=True)
class Rejection:
    """A typed, retryable admission result (NOT an exception).

    ``Engine.add_request`` returns this instead of a request id when the
    engine is saturated: callers match on ``reason``, honor
    ``retry_after_s`` (a hint, not a promise), and give up when
    ``retryable`` is False.  ``submit_with_backoff`` in
    ``repro.launch.common`` implements the bounded retry loop."""
    reason: RejectReason
    detail: str = ""
    retryable: bool = True
    retry_after_s: float = 0.05


# ---------------------------------------------------------------------------
# load-adaptive degradation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DegradeConfig:
    """Thresholds of the scheduler's degradation state machine.

    Signals (evaluated once per scheduler round):
      * queue depth >= ``queue_high``  (0 = auto: 2x the slot pool);
      * HBM pressure: the usable slot count (admission headroom under the
        effective, possibly chaos-squeezed budget) fell below the pool
        while demand exceeds it;
      * predicted deadline-miss rate >= ``miss_rate_high`` (the estimator
        projects per-request completion from the round-time EMA).

    Any firing signal enters DEGRADED; recovery needs every signal clear
    (queue back at/below ``queue_low``, 0 = auto: the pool size) for
    ``cooldown_steps`` consecutive rounds — hysteresis so the budget does
    not flap across a threshold."""
    enabled: bool = True
    queue_high: int = 0            # 0 -> 2 * n_slots
    queue_low: int = 0             # 0 -> n_slots
    miss_rate_high: float = 0.5
    cooldown_steps: int = 4


class DegradeController:
    """NORMAL <-> DEGRADED hysteresis over the per-round pressure signals."""

    def __init__(self, cfg: DegradeConfig, n_slots: int):
        self.cfg = cfg
        self.queue_high = cfg.queue_high or 2 * n_slots
        self.queue_low = min(cfg.queue_low or n_slots, self.queue_high - 1)
        self.degraded = False
        self._clear_rounds = 0
        self.degraded_rounds = 0
        self.transitions = 0
        self.reasons: Dict[str, int] = {}

    def update(self, *, queue_depth: int, hbm_pressure: bool,
               miss_rate: float) -> bool:
        if not self.cfg.enabled:
            return False
        pressure = []
        if queue_depth >= self.queue_high:
            pressure.append("queue")
        if hbm_pressure:
            pressure.append("hbm")
        if miss_rate >= self.cfg.miss_rate_high:
            pressure.append("deadline")
        if pressure:
            if not self.degraded:
                self.degraded = True
                self.transitions += 1
            self._clear_rounds = 0
            for r in pressure:
                self.reasons[r] = self.reasons.get(r, 0) + 1
        elif self.degraded:
            clear = (queue_depth <= self.queue_low and not hbm_pressure
                     and miss_rate < self.cfg.miss_rate_high)
            if clear:
                self._clear_rounds += 1
                if self._clear_rounds >= self.cfg.cooldown_steps:
                    self.degraded = False
                    self.transitions += 1
                    self._clear_rounds = 0
            else:
                self._clear_rounds = 0
        if self.degraded:
            self.degraded_rounds += 1
        return self.degraded

    def stats(self) -> Dict[str, object]:
        return {"degraded_rounds": self.degraded_rounds,
                "degrade_transitions": self.transitions,
                "degrade_reasons": dict(self.reasons),
                "degraded_now": self.degraded}


def estimate_miss_rate(now: float, round_s: Optional[float], *,
                       active: list, queued: list, usable_slots: int,
                       tokens_per_round: float = 1.0) -> float:
    """Fraction of deadline-carrying requests projected to miss.

    ``active`` is ``(remaining_tokens, absolute_deadline)`` per occupied
    slot; ``queued`` the same for waiting requests (their wait is estimated
    as their queue position amortized over the usable slots).  ``round_s``
    is the scheduler's round-time EMA (None during warmup -> 0.0: no signal
    before evidence).  The estimate is intentionally coarse — it is a
    *pressure signal* for the degradation controller, not an SLO oracle."""
    if round_s is None or round_s <= 0.0:
        return 0.0
    total = miss = 0
    per_tok = round_s / max(tokens_per_round, 1e-9)
    for remaining, deadline in active:
        if deadline is None:
            continue
        total += 1
        if now + remaining * per_tok > deadline:
            miss += 1
    slots = max(usable_slots, 1)
    for pos, (remaining, deadline) in enumerate(queued):
        if deadline is None:
            continue
        total += 1
        wait = (pos // slots + 1) * remaining * per_tok
        if now + wait + remaining * per_tok > deadline:
            miss += 1
    return miss / total if total else 0.0


# ---------------------------------------------------------------------------
# fault injection (chaos harness)
# ---------------------------------------------------------------------------
class ChaosFailure(RuntimeError):
    """A chaos-injected transient dispatch failure (retryable)."""


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Seeded, deterministic fault injection for the scheduler loop.

    All injection happens on the *host* side of the loop, before a dispatch
    is issued — no jitted computation ever sees a fault, so a chaotic run
    emits exactly the tokens of a calm one as long as the degradation
    controller does not change any tier's budget in response (asserted in
    CI with degradation disabled; with it enabled, degraded tiers serve
    fewer terms under pressure by design).

    * ``latency_p``/``latency_s``: with probability ``latency_p`` a
      dispatch is preceded by a ``latency_s`` stall (a thermal/neighbor
      straggler stand-in) — the dispatch watchdog must flag the round;
    * ``fail_p``/``max_retries``: with probability ``fail_p`` a dispatch
      raises :class:`ChaosFailure` *before* running (the donated buffers
      are untouched, so the bounded retry is safe);
    * ``hbm_squeeze_start``/``steps``/``frac``: scheduler rounds
      ``[start, start+steps)`` shrink the effective HBM budget by ``frac``
      (an allocator-pressure / fragmentation stand-in) — admission headroom
      drops and the degradation controller must react, not reject."""
    seed: int = 0
    latency_p: float = 0.0
    latency_s: float = 0.02
    fail_p: float = 0.0
    max_retries: int = 3
    hbm_squeeze_start: int = -1    # first squeezed round (-1 = never)
    hbm_squeeze_steps: int = 0     # window length, in scheduler rounds
    hbm_squeeze_frac: float = 0.5  # fraction of the budget removed

    def __post_init__(self):
        for name in ("latency_p", "fail_p"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if not 0.0 <= self.hbm_squeeze_frac <= 1.0:
            raise ValueError("hbm_squeeze_frac must be in [0, 1], "
                             f"got {self.hbm_squeeze_frac}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")


class ChaosInjector:
    """Per-engine chaos state: a seeded RNG + a monotonic round counter
    (ticked once per scheduler round, across runs, so squeeze windows are
    reproducible for a given request sequence)."""

    def __init__(self, cfg: ChaosConfig, *, sleep=time.sleep):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.round = 0
        self.latency_injected = 0
        self.failures_injected = 0
        self._sleep = sleep

    def tick(self) -> None:
        self.round += 1

    @property
    def squeezing(self) -> bool:
        c = self.cfg
        return (c.hbm_squeeze_start >= 0
                and c.hbm_squeeze_start <= self.round
                < c.hbm_squeeze_start + c.hbm_squeeze_steps)

    def effective_hbm(self, budget_bytes: float) -> float:
        if self.squeezing:
            return budget_bytes * (1.0 - self.cfg.hbm_squeeze_frac)
        return budget_bytes

    def before_dispatch(self) -> None:
        """Host-side injection point, called immediately before a dispatch
        is issued.  May stall (latency spike) and may raise
        :class:`ChaosFailure` (transient failure) — never after the real
        dispatch ran, so retries never double-apply a donated buffer.
        The ordering contract is annotated below and proven by the
        :class:`repro.analysis.DonationLedger` mutation test."""
        c = self.cfg
        if c.latency_p and self.rng.random() < c.latency_p:
            self.latency_injected += 1
            self._sleep(c.latency_s)
        if c.fail_p and self.rng.random() < c.fail_p:
            self.failures_injected += 1
            raise ChaosFailure(
                f"chaos: injected transient dispatch failure "
                f"(round {self.round})")

    def stats(self) -> Dict[str, object]:
        return {"rounds": self.round,
                "latency_injected": self.latency_injected,
                "failures_injected": self.failures_injected,
                "squeezing_now": self.squeezing}


# chaos must fire BEFORE the dispatch that consumes donated buffers (the
# fused steps donate the caches, arg position 2): a retry after an injected
# failure re-issues the dispatch with buffers that were never consumed.
# Injecting *after* would donate first and retry on a freed buffer — the
# double-apply class the DonationLedger mutation test seeds.
_contract(ChaosInjector.before_dispatch, name="chaos_before_dispatch",
          donate_argnums=(2,))


def safe_rate(count: float, seconds: float, eps: float = 1e-9) -> float:
    """``count / seconds`` with zero/near-zero durations mapped to 0.0 —
    metrics JSON must stay finite and comparable on tiny CI runs."""
    return float(count) / seconds if seconds > eps else 0.0
