"""Slot-based continuous-batching scheduler.

The legacy engine drains equal-length request *groups* to completion: one
long prompt stalls the whole batch, and slots freed by EOS sit idle until
the group ends.  This module replaces group-drain with true continuous
batching over a fixed pool of decode *slots*:

* **FCFS admission**, gated by :func:`repro.infer.kvcache.max_batch_for_hbm`
  when an HBM budget is configured: the slot pool never outgrows what the
  caches + params fit in.  The accounting is mesh-aware and *per device*
  (``kvcache.param_bytes_per_device``): params scattered by
  ``placement="term"``/``"tensor"`` leave more per-device HBM for caches,
  so a sharded engine admits a larger slot pool under the same budget;
* **padded prefill-into-slot**: each admitted prompt is right-padded to a
  bucketed length (bounding jit retraces), prefilled with a per-row length
  mask, and its cache scattered into a free row of the live decode cache
  (:func:`repro.models.model.scatter_cache_into_slot`);
* **per-slot decode**: one fused decode+sample+EOS step serves every
  occupied slot at its own sequence position (vector ``cache_len``);
* **slot recycling**: EOS or per-request token budgets free a slot
  mid-stream, and the next queued request is admitted into it between
  decode steps (interleaved prefill/decode);
* **one host transfer per decode step**: the ``(tokens, alive)`` pair — the
  same contract the legacy engine established.

Per-request metrics (time-to-first-token, decode tokens/sec) and run-level
stats (slot occupancy, decode throughput) are collected on every run; the
serving benchmark reads them for ``BENCH_serving.json``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.infer import kvcache
from repro.models import model as M

PyTree = Any


@dataclasses.dataclass
class Request:
    """One queued generation request (FCFS order = rid order)."""
    rid: int
    tokens: List[int]
    max_new_tokens: Optional[int] = None   # None -> the run()-level default
    t_enqueue: float = 0.0
    # filled in by the scheduler:
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    new_tokens: int = 0

    @property
    def ttft_seconds(self) -> float:
        """Enqueue -> first generated token (includes queue wait)."""
        return max(0.0, self.t_first_token - self.t_enqueue)

    @property
    def tokens_per_sec(self) -> float:
        dt = self.t_done - self.t_admitted
        return self.new_tokens / dt if dt > 0 else 0.0

    def metrics(self) -> Dict[str, float]:
        return {"rid": self.rid, "prompt_len": len(self.tokens),
                "new_tokens": self.new_tokens,
                "ttft_s": self.ttft_seconds,
                "tokens_per_sec": self.tokens_per_sec,
                "queue_s": max(0.0, self.t_admitted - self.t_enqueue)}


def plan_slots(cfg, serve_cfg, params) -> int:
    """Size the decode-slot pool: the configured ``max_slots`` (or
    ``max_batch``), capped by HBM admission control when a budget is set.

    ``hbm_budget_bytes`` is the budget of ONE device; params are counted at
    their per-device resident size (``kvcache.param_bytes_per_device``), so
    scattering weights over a mesh frees budget for additional slots while
    the replicated caches are charged in full on every device."""
    n = serve_cfg.max_slots or serve_cfg.max_batch
    if serve_cfg.hbm_budget_bytes > 0:
        pbytes = kvcache.param_bytes_per_device(params)
        cap = kvcache.max_batch_for_hbm(cfg, serve_cfg.max_seq,
                                        serve_cfg.hbm_budget_bytes, pbytes)
        if cap < 1:
            raise ValueError(
                f"hbm_budget_bytes={serve_cfg.hbm_budget_bytes:.3g} cannot fit "
                f"params ({pbytes:.3g} B per device) plus one sequence of "
                f"max_seq={serve_cfg.max_seq} cache")
        n = min(n, cap)
    return max(1, n)


def bucket_length(l: int, bucket: int, max_seq: int) -> int:
    """Pad a prompt length up to a bucket multiple (bounds the number of
    distinct prefill shapes, hence jit compilations), capped at capacity."""
    b = max(1, bucket)
    return min(-(-l // b) * b, max_seq)


class SlotScheduler:
    """Continuous-batching executor behind ``ServeConfig(scheduler="slots")``.

    Owns no model state of its own: it drives the parent engine's jitted
    prefill / scatter / fused-decode callables (so jit caches persist across
    runs) and reads dynamic knobs (eos, temperature) from ``engine.sc`` at
    run time — both are dynamic operands of the decode step, so changing
    them never retraces.
    """

    def __init__(self, engine):
        self.eng = engine
        self.n_slots = plan_slots(engine.cfg, engine.sc, engine.params)
        self.last_run_stats: Dict[str, Any] = {}
        self.last_request_metrics: Dict[int, Dict[str, float]] = {}

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], max_new_tokens: int = 16
            ) -> Dict[int, List[int]]:
        eng, sc = self.eng, self.eng.sc
        n = self.n_slots
        # validate the whole batch up front (no partial-run surprises)
        for req in requests:
            m = req.max_new_tokens if req.max_new_tokens is not None else max_new_tokens
            if len(req.tokens) + m > sc.max_seq:
                raise ValueError(
                    f"request {req.rid}: prompt len {len(req.tokens)} + "
                    f"max_new_tokens {m} exceeds ServeConfig.max_seq={sc.max_seq}")

        queue = deque(requests)
        out: Dict[int, List[int]] = {}
        eos = jnp.int32(sc.eos_id)
        temperature = jnp.float32(sc.temperature)
        key = jax.random.PRNGKey(sc.seed)

        # the decode cache replicates across the mesh (per-slot KV rows are
        # identical on every device; only the weights are scattered)
        live = M.init_cache(eng.cfg, n, sc.max_seq, int8_kv=eng.qc.int8_kv,
                            mesh=eng.mesh)
        clen = np.zeros(n, np.int32)           # per-slot cache length (host)
        active = np.zeros(n, bool)             # slot occupied (host)
        budget = np.zeros(n, np.int64)         # remaining tokens per slot
        slot_req: List[Optional[Request]] = [None] * n
        tok = jnp.zeros((n, 1), jnp.int32)     # next token per slot (device)
        alive = jnp.zeros((n,), bool)          # EOS mask (device)

        steps = 0
        occupied_steps = 0.0
        gen_tokens = 0
        t_run0 = time.perf_counter()
        prefill_s = 0.0

        def admit():
            """FCFS: prefill queued requests into free slots (padded prompt,
            length-masked), scatter their caches into the live decode cache,
            and seed each slot with its first sampled token — all device-side
            (no host sync)."""
            nonlocal live, tok, alive, key, prefill_s
            t0 = time.perf_counter()
            while queue and not active.all():
                req = queue.popleft()
                slot = int(np.flatnonzero(~active)[0])
                l = len(req.tokens)
                p_len = bucket_length(l, sc.prefill_bucket, sc.max_seq)
                padded = np.zeros((1, p_len), np.int32)
                padded[0, :l] = req.tokens
                logits, pcache = eng._prefill_slot(
                    eng.params, {"tokens": jnp.asarray(padded)},
                    jnp.asarray([l], jnp.int32))
                live = eng._scatter(live, pcache, slot)
                key, sub = jax.random.split(key)
                first = eng._sample(logits, sub)           # (1, 1) on device
                tok = tok.at[slot, 0].set(first[0, 0])
                alive = alive.at[slot].set(first[0, 0] != eos)
                clen[slot] = l
                active[slot] = True
                m = (req.max_new_tokens if req.max_new_tokens is not None
                     else max_new_tokens)
                budget[slot] = m
                slot_req[slot] = req
                req.t_admitted = time.perf_counter()
                out[req.rid] = []
            prefill_s += time.perf_counter() - t0

        while queue or active.any():
            # interleaved prefill: fill any free slot BEFORE the fetch, so a
            # newly admitted slot's first (prefill-sampled) token is read by
            # this iteration's transfer and only then consumed by decode —
            # admitting between fetch and decode would overwrite it unread
            if queue and not active.all():
                admit()
            steps += 1
            occupied_steps += float(active.sum()) / n
            # the ONE host transfer of this decode step
            tok_host, alive_host = jax.device_get((tok, alive))
            now = time.perf_counter()
            for i in np.flatnonzero(active):
                req = slot_req[i]
                out[req.rid].append(int(tok_host[i, 0]))
                gen_tokens += 1
                if req.t_first_token == 0.0:
                    req.t_first_token = now
                budget[i] -= 1
                if not bool(alive_host[i]) or budget[i] <= 0:
                    req.t_done = now
                    req.new_tokens = len(out[req.rid])
                    active[i] = False
                    slot_req[i] = None              # slot freed -> recyclable
            if not active.any():
                continue                            # admit or exit at the top
            # snapshot clen: the host mutates it below, and numpy->device
            # transfers may alias the host buffer (CPU zero-copy)
            tok, live, key, alive = eng._decode(
                eng.params, tok, live, jnp.asarray(clen.copy()), key, alive,
                eos, temperature)
            clen[active] += 1
        wall = time.perf_counter() - t_run0

        decode_s = max(wall - prefill_s, 1e-9)
        self.last_request_metrics = {r.rid: r.metrics() for r in requests}
        self.last_run_stats = {
            "scheduler": "slots",
            "placement": eng.placement,
            "mesh_devices": eng.mesh_devices,
            "n_slots": n,
            "requests": len(requests),
            "generated_tokens": gen_tokens,
            "decode_steps": steps,
            "occupancy": occupied_steps / steps if steps else 0.0,
            "wall_seconds": wall,
            "prefill_seconds": prefill_s,
            "decode_seconds": decode_s,
            "decode_tokens_per_sec": gen_tokens / decode_s,
            "tokens_per_sec": gen_tokens / wall if wall > 0 else 0.0,
        }
        return out
