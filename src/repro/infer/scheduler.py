"""Slot-based continuous-batching scheduler.

The legacy engine drains equal-length request *groups* to completion: one
long prompt stalls the whole batch, and slots freed by EOS sit idle until
the group ends.  This module replaces group-drain with true continuous
batching over a fixed pool of decode *slots*:

* **FCFS admission**, gated by :func:`repro.infer.kvcache.max_batch_for_hbm`
  when an HBM budget is configured: the slot pool never outgrows what the
  caches + params fit in.  The accounting is mesh-aware and *per device*
  (``kvcache.param_bytes_per_device``): params scattered by
  ``placement="term"``/``"tensor"`` leave more per-device HBM for caches,
  so a sharded engine admits a larger slot pool under the same budget;
* **padded prefill-into-slot**: each admitted prompt is right-padded to a
  bucketed length (bounding jit retraces), prefilled with a per-row length
  mask, and its cache scattered into a free row of the live decode cache
  (:func:`repro.models.model.scatter_cache_into_slot`);
* **per-slot decode**: one fused decode+sample+EOS step serves every
  occupied slot at its own sequence position (vector ``cache_len``);
* **slot recycling**: EOS or per-request token budgets free a slot
  mid-stream, and the next queued request is admitted into it between
  decode steps (interleaved prefill/decode);
* **one host transfer per decode step**: the ``(tokens, alive)`` pair — the
  same contract the legacy engine established.

Per-request metrics (time-to-first-token, decode tokens/sec) and run-level
stats (slot occupancy, decode throughput) are collected on every run; the
serving benchmark reads them for ``BENCH_serving.json``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.infer import kvcache
from repro.models import model as M

PyTree = Any


@dataclasses.dataclass
class Request:
    """One queued generation request (FCFS order = rid order)."""
    rid: int
    tokens: List[int]
    max_new_tokens: Optional[int] = None   # None -> the run()-level default
    t_enqueue: float = 0.0
    # filled in by the scheduler:
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    new_tokens: int = 0

    @property
    def ttft_seconds(self) -> float:
        """Enqueue -> first generated token (includes queue wait)."""
        return max(0.0, self.t_first_token - self.t_enqueue)

    @property
    def tokens_per_sec(self) -> float:
        dt = self.t_done - self.t_admitted
        return self.new_tokens / dt if dt > 0 else 0.0

    def metrics(self) -> Dict[str, float]:
        return {"rid": self.rid, "prompt_len": len(self.tokens),
                "new_tokens": self.new_tokens,
                "ttft_s": self.ttft_seconds,
                "tokens_per_sec": self.tokens_per_sec,
                "queue_s": max(0.0, self.t_admitted - self.t_enqueue)}


def plan_slots(cfg, serve_cfg, params) -> int:
    """Size the decode-slot pool: the configured ``max_slots`` (or
    ``max_batch``), capped by HBM admission control when a budget is set.

    ``hbm_budget_bytes`` is the budget of ONE device; params are counted at
    their per-device resident size (``kvcache.param_bytes_per_device``), so
    scattering weights over a mesh frees budget for additional slots while
    the replicated caches are charged in full on every device.  Speculative
    engines (``spec_terms > 0``) charge each slot's cache TWICE: the fused
    round drafts on a functional copy while the committed caches stay live
    for verify/commit, so peak KV residency is ~2x per slot."""
    n = serve_cfg.max_slots or serve_cfg.max_batch
    if serve_cfg.hbm_budget_bytes > 0:
        pbytes = kvcache.param_bytes_per_device(params)
        copies = 2.0 if serve_cfg.spec_terms > 0 else 1.0
        cap = kvcache.max_batch_for_hbm(cfg, serve_cfg.max_seq,
                                        serve_cfg.hbm_budget_bytes, pbytes,
                                        cache_copies=copies)
        if cap < 1:
            raise ValueError(
                f"hbm_budget_bytes={serve_cfg.hbm_budget_bytes:.3g} cannot fit "
                f"params ({pbytes:.3g} B per device) plus one sequence of "
                f"max_seq={serve_cfg.max_seq} cache")
        n = min(n, cap)
    return max(1, n)


def bucket_length(l: int, bucket: int, max_seq: int) -> int:
    """Pad a prompt length up to a bucket multiple (bounds the number of
    distinct prefill shapes, hence jit compilations), capped at capacity."""
    b = max(1, bucket)
    return min(-(-l // b) * b, max_seq)


class SlotScheduler:
    """Continuous-batching executor behind ``ServeConfig(scheduler="slots")``.

    Owns no model state of its own: it drives the parent engine's jitted
    prefill / scatter / fused-decode callables (so jit caches persist across
    runs) and reads dynamic knobs (eos, temperature) from ``engine.sc`` at
    run time — both are dynamic operands of the decode step, so changing
    them never retraces.
    """

    def __init__(self, engine):
        self.eng = engine
        self.n_slots = plan_slots(engine.cfg, engine.sc, engine.params)
        self.last_run_stats: Dict[str, Any] = {}
        self.last_request_metrics: Dict[int, Dict[str, float]] = {}

    # ------------------------------------------------------------------
    def _validate(self, requests: List[Request], max_new_tokens: int) -> None:
        """Validate the whole batch up front (no partial-run surprises).

        The effective per-request budget must be >= 1: generation always
        emits the prefill-sampled token first, so a zero budget cannot be
        honored silently — it is rejected here on BOTH scheduler paths (the
        grouped engine runs the same check), not just at ``add_request``."""
        sc = self.eng.sc
        for req in requests:
            m = (req.max_new_tokens if req.max_new_tokens is not None
                 else max_new_tokens)
            if m < 1:
                raise ValueError(
                    f"request {req.rid}: effective max_new_tokens must be "
                    f">= 1, got {m} (the prefill-sampled first token cannot "
                    f"be withheld)")
            if len(req.tokens) + m > sc.max_seq:
                raise ValueError(
                    f"request {req.rid}: prompt len {len(req.tokens)} + "
                    f"max_new_tokens {m} exceeds ServeConfig.max_seq={sc.max_seq}")

    def _init_pool(self):
        """Zeroed slot-pool state: the live decode cache (replicated across
        the mesh — per-slot KV rows are identical on every device; only the
        weights are scattered) plus per-slot host bookkeeping."""
        eng, sc, n = self.eng, self.eng.sc, self.n_slots
        return {
            "live": M.init_cache(eng.cfg, n, sc.max_seq,
                                 int8_kv=eng.qc.int8_kv, mesh=eng.mesh),
            "clen": np.zeros(n, np.int32),     # per-slot cache length (host)
            "active": np.zeros(n, bool),       # slot occupied (host)
            "budget": np.zeros(n, np.int64),   # remaining tokens per slot
            "slot_req": [None] * n,
            "tok": jnp.zeros((n, 1), jnp.int32),  # next token/slot (device)
            "alive": jnp.zeros((n,), bool),    # EOS mask (device)
            "key": jax.random.PRNGKey(sc.seed),
            "prefill_s": 0.0,
        }

    def _admit(self, st, queue, out, max_new_tokens: int) -> None:
        """FCFS: prefill queued requests into free slots (padded prompt,
        length-masked), scatter their caches into the live decode cache,
        and seed each slot with its first sampled token — all device-side
        (no host sync)."""
        eng, sc = self.eng, self.eng.sc
        eos = jnp.int32(sc.eos_id)
        t0 = time.perf_counter()
        while queue and not st["active"].all():
            req = queue.popleft()
            slot = int(np.flatnonzero(~st["active"])[0])
            l = len(req.tokens)
            p_len = bucket_length(l, sc.prefill_bucket, sc.max_seq)
            padded = np.zeros((1, p_len), np.int32)
            padded[0, :l] = req.tokens
            logits, pcache = eng._prefill_slot(
                eng.params, {"tokens": jnp.asarray(padded)},
                jnp.asarray([l], jnp.int32))
            st["live"] = eng._scatter(st["live"], pcache, slot)
            st["key"], sub = jax.random.split(st["key"])
            first = eng._sample(logits, sub)           # (1, 1) on device
            st["tok"] = st["tok"].at[slot, 0].set(first[0, 0])
            st["alive"] = st["alive"].at[slot].set(first[0, 0] != eos)
            st["clen"][slot] = l
            st["active"][slot] = True
            m = (req.max_new_tokens if req.max_new_tokens is not None
                 else max_new_tokens)
            st["budget"][slot] = m
            st["slot_req"][slot] = req
            req.t_admitted = time.perf_counter()
            out[req.rid] = []
        st["prefill_s"] += time.perf_counter() - t0

    def _finish_stats(self, requests, *, gen_tokens, steps, occupied_steps,
                      wall, prefill_s, extra=None) -> None:
        eng = self.eng
        decode_s = max(wall - prefill_s, 1e-9)
        self.last_request_metrics = {r.rid: r.metrics() for r in requests}
        self.last_run_stats = {
            "scheduler": "slots",
            "placement": eng.placement,
            "mesh_devices": eng.mesh_devices,
            "n_slots": self.n_slots,
            "requests": len(requests),
            "generated_tokens": gen_tokens,
            "decode_steps": steps,
            "occupancy": occupied_steps / steps if steps else 0.0,
            "wall_seconds": wall,
            "prefill_seconds": prefill_s,
            "decode_seconds": decode_s,
            "decode_tokens_per_sec": gen_tokens / decode_s,
            "tokens_per_sec": gen_tokens / wall if wall > 0 else 0.0,
        }
        if extra:
            self.last_run_stats.update(extra)

    def run(self, requests: List[Request], max_new_tokens: int = 16
            ) -> Dict[int, List[int]]:
        eng, sc = self.eng, self.eng.sc
        n = self.n_slots
        self._validate(requests, max_new_tokens)
        if eng.spec_enabled:
            return self._run_spec(requests, max_new_tokens)

        queue = deque(requests)
        out: Dict[int, List[int]] = {}
        eos = jnp.int32(sc.eos_id)
        temperature = jnp.float32(sc.temperature)
        st = self._init_pool()
        active, clen, budget = st["active"], st["clen"], st["budget"]

        steps = 0             # decode DISPATCHES — the final drain iteration
        occupied_steps = 0.0  # (emitting last pending tokens) dispatches none
        gen_tokens = 0
        t_run0 = time.perf_counter()

        while queue or active.any():
            # interleaved prefill: fill any free slot BEFORE the fetch, so a
            # newly admitted slot's first (prefill-sampled) token is read by
            # this iteration's transfer and only then consumed by decode —
            # admitting between fetch and decode would overwrite it unread
            if queue and not active.all():
                self._admit(st, queue, out, max_new_tokens)
            # the ONE host transfer of this decode step
            tok_host, alive_host = jax.device_get((st["tok"], st["alive"]))
            now = time.perf_counter()
            for i in np.flatnonzero(active):
                req = st["slot_req"][i]
                out[req.rid].append(int(tok_host[i, 0]))
                gen_tokens += 1
                if req.t_first_token == 0.0:
                    req.t_first_token = now
                budget[i] -= 1
                if not bool(alive_host[i]) or budget[i] <= 0:
                    req.t_done = now
                    req.new_tokens = len(out[req.rid])
                    active[i] = False
                    st["slot_req"][i] = None    # slot freed -> recyclable
            if not active.any():
                continue                        # admit or exit at the top
            # count the decode dispatch HERE, after the drain check: counting
            # at the loop top overstated decode_steps by one per drain (an
            # iteration that fetches+emits but dispatches no decode) and
            # correspondingly understated occupancy
            steps += 1
            occupied_steps += float(active.sum()) / n
            # snapshot clen: the host mutates it below, and numpy->device
            # transfers may alias the host buffer (CPU zero-copy)
            st["tok"], st["live"], st["key"], st["alive"] = eng._decode(
                eng.params, st["tok"], st["live"], jnp.asarray(clen.copy()),
                st["key"], st["alive"], eos, temperature)
            clen[active] += 1
        wall = time.perf_counter() - t_run0
        self._finish_stats(requests, gen_tokens=gen_tokens, steps=steps,
                           occupied_steps=occupied_steps, wall=wall,
                           prefill_s=st["prefill_s"])
        return out

    # ------------------------------------------------------------------
    def _run_spec(self, requests: List[Request], max_new_tokens: int
                  ) -> Dict[int, List[int]]:
        """Self-speculative serving loop (DESIGN.md §10).

        Each round is ONE fused dispatch (draft γ tokens with the truncated
        series, verify the chunk with the full series, commit the accepted
        prefix) and ONE host transfer carrying up to γ+1 tokens per slot:
        the pre-round pending token plus the round's full-model tokens and
        accept counts.  Emission order per slot — pending token, then the
        accepted drafts, then the full-model correction becomes the next
        pending token — reproduces the non-speculative greedy stream
        token-for-token."""
        eng, sc = self.eng, self.eng.sc
        n = self.n_slots
        gamma = sc.spec_lookahead
        if sc.temperature > 0:
            raise ValueError(
                "speculative decoding serves greedy only (temperature=0): "
                "draft acceptance compares argmaxes; lossless speculative "
                "sampling would need rejection sampling on the verify logits")
        queue = deque(requests)
        out: Dict[int, List[int]] = {}
        st = self._init_pool()
        active, clen, budget = st["active"], st["clen"], st["budget"]

        rounds = 0
        occupied_steps = 0.0
        gen_tokens = 0
        drafted = 0
        accepted = 0
        t_run0 = time.perf_counter()

        while queue or active.any():
            if queue and not active.all():
                self._admit(st, queue, out, max_new_tokens)
            rounds += 1
            occupied_steps += float(active.sum()) / n
            tok_pre = st["tok"]                # pending tokens entering round
            st["tok"], st["live"], full, accept = eng._spec(
                eng.params, st["tok"], st["live"], jnp.asarray(clen.copy()))
            # the ONE host transfer of this round (up to γ+1 tokens/slot)
            tok_host, full_host, acc_host = jax.device_get(
                (tok_pre, full, accept))
            now = time.perf_counter()
            for i in np.flatnonzero(active):
                req = st["slot_req"][i]
                m_i = int(acc_host[i])
                drafted += gamma
                accepted += m_i
                # pending token first, then the m accepted draft tokens
                # (full_host[i, :m] — identical to the drafts by acceptance);
                # the correction full_host[i, m] stays on device as the next
                # pending token
                emit = [int(tok_host[i, 0])] +                     [int(t) for t in full_host[i, :m_i]]
                if req.t_first_token == 0.0:
                    req.t_first_token = now
                done = False
                for t in emit:
                    out[req.rid].append(t)
                    gen_tokens += 1
                    budget[i] -= 1
                    if t == sc.eos_id or budget[i] <= 0:
                        done = True
                        break
                clen[i] += m_i + 1             # mirrors commit_verify
                if done:
                    req.t_done = now
                    req.new_tokens = len(out[req.rid])
                    active[i] = False
                    st["slot_req"][i] = None
        wall = time.perf_counter() - t_run0
        self._finish_stats(
            requests, gen_tokens=gen_tokens, steps=rounds,
            occupied_steps=occupied_steps, wall=wall,
            prefill_s=st["prefill_s"],
            extra={
                "spec_terms": sc.spec_terms,
                "spec_lookahead": gamma,
                "spec_rounds": rounds,
                "draft_tokens": drafted,
                "accepted_draft_tokens": accepted,
                "acceptance_rate": accepted / drafted if drafted else 0.0,
                "tokens_per_round": gen_tokens / rounds if rounds else 0.0,
            })
        return out
