"""Slot-based continuous-batching scheduler with a QoS robustness layer.

The legacy engine drains equal-length request *groups* to completion: one
long prompt stalls the whole batch, and slots freed by EOS sit idle until
the group ends.  This module replaces group-drain with true continuous
batching over a fixed pool of decode *slots*:

* **priority admission** (FCFS within a priority level), gated by
  :func:`repro.infer.kvcache.max_batch_for_hbm` when an HBM budget is
  configured: the slot pool never outgrows what the caches + params fit in.
  The accounting is mesh-aware and *per device*
  (``kvcache.param_bytes_per_device``): params scattered by
  ``placement="term"``/``"tensor"`` leave more per-device HBM for caches,
  so a sharded engine admits a larger slot pool under the same budget;
* **padded prefill-into-slot**: each admitted prompt is right-padded to a
  bucketed length (bounding jit retraces), prefilled with a per-row length
  mask — under its tier's term budget — and its cache scattered into a
  free row of the live decode cache
  (:func:`repro.models.model.scatter_cache_into_slot`);
* **per-slot decode under per-tier term budgets** (DESIGN.md §11): each
  iteration issues ONE masked fused decode+sample+EOS dispatch per
  *distinct effective term budget*; only member rows commit their
  token/alive/cache writes, so every slot advances exactly one token under
  its own tier's ``QuantContext.term_budget`` while sharing one live cache.
  Single-tier workloads collapse to one dispatch per step — the exact
  stream of the tier-free engine;
* **load-adaptive degradation**: a :class:`repro.infer.qos.DegradeController`
  watches queue depth, HBM admission headroom (chaos squeezes shrink the
  effective budget via :func:`repro.infer.kvcache.usable_slots`) and a
  deadline-miss estimate; under pressure, degradable tiers serve their
  floor budget until the pressure clears for a cooldown;
* **deadlines**: an expired request is cancelled — before admission (never
  occupying a slot) or mid-run (its slot recycled immediately) — and
  reported with ``status="cancelled"``;
* **slot recycling**: EOS, per-request token budgets or deadline cancels
  free a slot mid-stream, and the next queued request is admitted into it
  between decode steps (interleaved prefill/decode);
* **one host transfer per decode step**: the ``(tokens, alive)`` pair — the
  same contract the legacy engine established;
* **fault tolerance hooks**: every dispatch passes the
  :class:`repro.infer.qos.ChaosInjector` injection point (latency spikes
  stall, transient failures retry — always *before* the real dispatch, so
  donated buffers are never double-applied), and a
  :class:`repro.dist.fault.DispatchWatchdog` flags stalled rounds.

Per-request metrics (time-to-first-token, decode tokens/sec) and run-level
stats (slot occupancy, decode throughput, per-tier QoS counters) are
collected on every run; the serving and QoS benchmarks read them for
``BENCH_serving.json`` / ``BENCH_qos.json``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import fault as FD
from repro.infer import kvcache
from repro.infer import qos as Q
from repro.models import model as M

PyTree = Any

# no-hang backstop: consecutive scheduler rounds with nothing dispatchable
# (e.g. a chaos HBM squeeze left zero usable slots) before aborting.  Idle
# rounds tick the chaos round clock, so finite squeeze windows always pass
# well below this.
_IDLE_CAP = 100_000


class SchedulerError(RuntimeError):
    """The scheduler cannot make progress (e.g. a chaos squeeze left zero
    usable slots past the no-hang backstop).  Typed so callers can
    distinguish a stalled schedule from arbitrary runtime failures — and so
    the backstop survives ``python -O`` (it is a raise, never an assert)."""


@dataclasses.dataclass
class Request:
    """One queued generation request (admission order: priority, then rid)."""
    rid: int
    tokens: List[int]
    max_new_tokens: Optional[int] = None   # None -> the run()-level default
    t_enqueue: float = 0.0
    quality: str = "full"                  # tier name (engine.tiers)
    priority: int = 0                      # higher admits first
    deadline_s: Optional[float] = None     # wall budget from enqueue (info)
    deadline: Optional[float] = None       # absolute perf_counter() deadline
    # open-loop arrival offset (seconds from run() start); 0.0 = already
    # queued.  The Poisson serving benchmark sets this so offered load is
    # independent of service rate (arrivals never wait on completions).
    arrival: float = 0.0
    # filled in by the scheduler:
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    new_tokens: int = 0
    status: str = "ok"                     # "ok" | "cancelled"

    @property
    def ttft_seconds(self) -> float:
        """Enqueue -> first generated token (includes queue wait); 0.0 for
        a request cancelled before its first token."""
        if self.t_first_token <= 0.0:
            return 0.0
        return max(0.0, self.t_first_token - self.t_enqueue)

    @property
    def tokens_per_sec(self) -> float:
        # safe_rate: zero/near-zero durations (tiny CI runs, cancelled
        # requests) map to 0.0, never inf/NaN
        return Q.safe_rate(self.new_tokens, self.t_done - self.t_admitted)

    @property
    def deadline_missed(self) -> Optional[bool]:
        """None when the request carries no deadline."""
        if self.deadline is None:
            return None
        return self.status == "cancelled" or self.t_done > self.deadline

    @property
    def itl_seconds(self) -> float:
        """Mean inter-token latency: first token -> done, per emitted gap
        (0.0 for single-token or cancelled-early requests)."""
        if self.new_tokens < 2 or self.t_first_token <= 0.0 \
                or self.t_done <= self.t_first_token:
            return 0.0
        return (self.t_done - self.t_first_token) / (self.new_tokens - 1)

    def metrics(self) -> Dict[str, Any]:
        m = {"rid": self.rid, "prompt_len": len(self.tokens),
             "new_tokens": self.new_tokens,
             "ttft_s": self.ttft_seconds,
             "itl_s": self.itl_seconds,
             "tokens_per_sec": self.tokens_per_sec,
             "queue_s": max(0.0, self.t_admitted - self.t_enqueue),
             "quality": self.quality,
             "priority": self.priority,
             "status": self.status}
        if self.deadline is not None:
            m["deadline_missed"] = bool(self.deadline_missed)
        return m


def plan_slots(cfg, serve_cfg, params, *, int8_kv: bool = False) -> int:
    """Size the decode-slot pool: the configured ``max_slots`` (or
    ``max_batch``), capped by HBM admission control when a budget is set.

    ``hbm_budget_bytes`` is the budget of ONE device; params are counted at
    their per-device resident size (``kvcache.param_bytes_per_device``), so
    scattering weights over a mesh frees budget for additional slots while
    the replicated caches are charged in full on every device.  Speculative
    engines (``spec_terms > 0``) charge each slot's cache TWICE: the fused
    round drafts on a functional copy while the committed caches stay live
    for verify/commit, so peak KV residency is ~2x per slot.

    ``int8_kv`` engines charge the int8 KV byte cost (values + scales), not
    the bf16 cost — an int8-KV engine admits MORE slots under the same
    budget instead of silently over-charging ~2x.

    Paged engines (``serve_cfg.paged``) are capped at page granularity
    (:func:`kvcache.max_slots_paged`): a slot is charged its fixed state
    plus ONE page, the floor any live slot needs — the page allocator, not
    this bound, gates how far concurrent sequences can actually grow."""
    n = serve_cfg.max_slots or serve_cfg.max_batch
    if serve_cfg.hbm_budget_bytes > 0:
        pbytes = kvcache.param_bytes_per_device(params)
        copies = 2.0 if serve_cfg.spec_terms > 0 else 1.0
        if getattr(serve_cfg, "paged", False):
            cap = kvcache.max_slots_paged(
                cfg, serve_cfg.max_seq, serve_cfg.page_size,
                serve_cfg.hbm_budget_bytes, pbytes,
                cache_copies=copies, int8_kv=int8_kv)
        else:
            cap = kvcache.max_batch_for_hbm(cfg, serve_cfg.max_seq,
                                            serve_cfg.hbm_budget_bytes, pbytes,
                                            cache_copies=copies,
                                            int8_kv=int8_kv)
        if cap < 1:
            raise ValueError(
                f"hbm_budget_bytes={serve_cfg.hbm_budget_bytes:.3g} cannot fit "
                f"params ({pbytes:.3g} B per device) plus one sequence of "
                f"max_seq={serve_cfg.max_seq} cache")
        n = min(n, cap)
    return max(1, n)


def bucket_length(l: int, bucket: int, max_seq: int) -> int:
    """Pad a prompt length up to a bucket multiple (bounds the number of
    distinct prefill shapes, hence jit compilations), capped at capacity."""
    b = max(1, bucket)
    return min(-(-l // b) * b, max_seq)


class SlotScheduler:
    """Continuous-batching executor behind ``ServeConfig(scheduler="slots")``.

    Owns no model state of its own: it drives the parent engine's jitted
    prefill / scatter / fused-decode callables (so jit caches persist across
    runs) and reads dynamic knobs (eos, temperature) from ``engine.sc`` at
    run time — both are dynamic operands of the decode step, so changing
    them never retraces.  Chaos state (:class:`repro.infer.qos.ChaosInjector`)
    is per-scheduler and its round clock is monotonic ACROSS runs, so a
    squeeze window hits a reproducible point of a request sequence.
    """

    def __init__(self, engine):
        self.eng = engine
        sc = engine.sc
        self.paged = bool(getattr(engine, "paged", False))
        self.n_slots = plan_slots(engine.cfg, sc, engine.params,
                                  int8_kv=engine.qc.int8_kv)
        self.last_run_stats: Dict[str, Any] = {}
        self.last_request_metrics: Dict[int, Dict[str, float]] = {}
        # HBM admission-headroom model (per device; same accounting as
        # plan_slots) — evaluated every round so chaos squeezes and real
        # budget changes shrink the *usable* pool mid-run.  int8-KV engines
        # charge int8 cache bytes, not bf16 (else admission under-admits 2x).
        self._pbytes = kvcache.param_bytes_per_device(engine.params)
        self._copies = 2.0 if sc.spec_terms > 0 else 1.0
        self._per_seq = kvcache.total_cache_bytes(
            engine.cfg, 1, sc.max_seq,
            int8_kv=engine.qc.int8_kv) * self._copies
        if self.paged:
            self.page_size = sc.page_size
            self.mp = kvcache.pages_for(sc.max_seq, sc.page_size)
            self._pb = kvcache.page_bytes(engine.cfg, sc.page_size,
                                          int8_kv=engine.qc.int8_kv)
            self.num_pages = sc.num_pages or kvcache.plan_pages(
                engine.cfg, sc.max_seq, sc.page_size, self.n_slots,
                hbm_bytes=sc.hbm_budget_bytes, param_bytes=self._pbytes,
                cache_copies=self._copies, int8_kv=engine.qc.int8_kv)
            # num_pages == 0 only for attention-free archs (nothing pages);
            # block tables stay inert all-sentinel and no pages are reserved
            self.alloc = (kvcache.PageAllocator(self.num_pages)
                          if self.num_pages > 0 else None)
            self._sentinel = self.num_pages
            self.bt = np.full((self.n_slots, self.mp), self._sentinel,
                              np.int32)
            self._pages_hwm = 0
        # chunked prefill + shared-prefix cache (DESIGN.md §14)
        self.chunk = int(getattr(sc, "prefill_chunk", 0) or 0)
        self.prefix_on = bool(getattr(sc, "prefix_cache", False))
        self.prefix: Optional[kvcache.PrefixCache] = None
        self._fill: Dict[int, Dict[str, Any]] = {}   # slot -> fill progress
        self._prefix_stats = self._zero_prefix_stats()
        self.chaos = (Q.ChaosInjector(sc.chaos)
                      if sc.chaos is not None else None)
        self.watchdog = self._new_watchdog()
        self.retries = 0               # chaos-failure redispatches (lifetime)

    @staticmethod
    def _zero_prefix_stats() -> Dict[str, int]:
        return {"chunk_dispatches": 0, "tokens_computed": 0,
                "tokens_reused": 0, "hits": 0, "misses": 0,
                "evictions": 0, "trie_nodes_end": 0}

    def _new_watchdog(self) -> FD.DispatchWatchdog:
        sc = self.eng.sc
        # with latency injection on, an absolute stall ceiling below the
        # injected spike makes flagging deterministic (EMA-relative alone
        # depends on how fast clean rounds happen to be)
        stall = (0.5 * sc.chaos.latency_s
                 if sc.chaos is not None and sc.chaos.latency_p > 0 else 0.0)
        return FD.DispatchWatchdog(stall_s=stall)

    # ------------------------------------------------------------------
    def _effective_hbm(self) -> float:
        """The HBM budget admission control sees *this round*.  With no
        explicit budget configured, the exact-fit budget (params + the
        planned pool's caches) is implied so a chaos squeeze still has a
        well-defined quantity to shrink."""
        budget = self.eng.sc.hbm_budget_bytes
        if budget <= 0:
            budget = self._pbytes + self.n_slots * self._per_seq
        if self.chaos is not None:
            budget = self.chaos.effective_hbm(budget)
        return budget

    def usable_slots_now(self) -> int:
        """Slots the effective (possibly squeezed) budget can serve.  On the
        paged engine admission is page-granular: the allocator (not a
        max_seq-charged bound) gates admission, so the whole pool is usable
        whenever pages are free (chaos squeezes are rejected at
        construction)."""
        if self.paged:
            return self.n_slots
        return kvcache.usable_slots(
            self.eng.cfg, self.eng.sc.max_seq, self._effective_hbm(),
            self._pbytes, self.n_slots, cache_copies=self._copies,
            int8_kv=self.eng.qc.int8_kv)

    def hbm_headroom_now(self, active_slots: int) -> float:
        return kvcache.hbm_headroom(
            self.eng.cfg, self.eng.sc.max_seq, self._effective_hbm(),
            self._pbytes, active_slots, cache_copies=self._copies,
            int8_kv=self.eng.qc.int8_kv)

    # ------------------------------------------------------------------
    def _validate(self, requests: List[Request], max_new_tokens: int) -> None:
        """Validate the whole batch up front (no partial-run surprises).

        The effective per-request budget must be >= 1: generation always
        emits the prefill-sampled token first, so a zero budget cannot be
        honored silently — it is rejected here on BOTH scheduler paths (the
        grouped engine runs the same check), not just at ``add_request``."""
        sc = self.eng.sc
        for req in requests:
            m = (req.max_new_tokens if req.max_new_tokens is not None
                 else max_new_tokens)
            if m < 1:
                raise ValueError(
                    f"request {req.rid}: effective max_new_tokens must be "
                    f">= 1, got {m} (the prefill-sampled first token cannot "
                    f"be withheld)")
            if len(req.tokens) + m > sc.max_seq:
                raise ValueError(
                    f"request {req.rid}: prompt len {len(req.tokens)} + "
                    f"max_new_tokens {m} exceeds ServeConfig.max_seq={sc.max_seq}")
            if req.quality not in self.eng.tiers:
                raise ValueError(
                    f"request {req.rid}: unknown quality {req.quality!r}; "
                    f"this engine serves {sorted(self.eng.tiers)}")

    @staticmethod
    def _order(requests: List[Request]) -> List[Request]:
        """Admission order: higher ``priority`` first; ``sorted`` is stable,
        so requests within a level stay FCFS (rid order)."""
        return sorted(requests, key=lambda r: -r.priority)

    def _init_pool(self):
        """Zeroed slot-pool state: the live decode cache (replicated across
        the mesh — per-slot KV rows are identical on every device; only the
        weights are scattered) plus per-slot host bookkeeping.  Paged
        engines get page pools + a fresh allocator and all-sentinel block
        tables instead of dense ``(n, max_seq)`` KV rows."""
        eng, sc, n = self.eng, self.eng.sc, self.n_slots
        if self.paged:
            live = M.init_paged_cache(
                eng.cfg, n, sc.max_seq, page_size=self.page_size,
                num_pages=self.num_pages, int8_kv=eng.qc.int8_kv,
                mesh=eng.mesh)
            if self.num_pages > 0:
                self.alloc = kvcache.PageAllocator(self.num_pages)
            self.bt[:] = self._sentinel
            self._pages_hwm = 0
        else:
            live = M.init_cache(eng.cfg, n, sc.max_seq,
                                int8_kv=eng.qc.int8_kv, mesh=eng.mesh)
        # fresh trie per run: it references pages of the per-run allocator
        # (on attention-free archs nothing pages — the trie stays off)
        self.prefix = (kvcache.PrefixCache(self.alloc, self.page_size)
                       if self.prefix_on and self.paged
                       and self.alloc is not None else None)
        self._fill = {}
        self._prefix_stats = self._zero_prefix_stats()
        return {
            "live": live,
            "clen": np.zeros(n, np.int32),     # per-slot cache length (host)
            "active": np.zeros(n, bool),       # slot occupied (host)
            "budget": np.zeros(n, np.int64),   # remaining tokens per slot
            "slot_req": [None] * n,
            "tok": jnp.zeros((n, 1), jnp.int32),  # next token/slot (device)
            "alive": jnp.zeros((n,), bool),    # EOS mask (device)
            "key": jax.random.PRNGKey(sc.seed),
            "prefill_s": 0.0,
        }

    def _reserve_pages(self, slot: int, prompt_len: int, budget: int,
                       matched: Optional[List[int]] = None) -> bool:
        """Reserve this request's FULL page footprint up front (no lazy
        growth, hence no mid-stream allocation deadlock): enough pages to
        cover prompt + every token its budget can emit — plus a verify
        chunk's worth (γ+1) on speculative engines, whose commit may write
        past the budget boundary within the final round.  All-or-nothing:
        on failure the block-table row is untouched and admission stops.

        ``matched`` (already increfed by :meth:`PrefixCache.match`, owned
        by the caller) heads the block-table row; only the uncovered tail
        is freshly allocated.  When the free list falls short the trie is
        asked to evict LRU refcount-1 pages before giving up."""
        if not self.paged or self.alloc is None:
            return True
        matched = matched or []
        need = prompt_len + budget
        if self.eng.spec_enabled:
            need += self.eng.sc.spec_lookahead + 1
        n_total = min(kvcache.pages_for(need, self.page_size), self.mp)
        n_own = max(0, n_total - len(matched))
        pages = self.alloc.alloc(n_own)
        if pages is None and self.prefix is not None:
            shortfall = n_own - self.alloc.free_pages
            if self.prefix.evict(shortfall) >= shortfall:
                pages = self.alloc.alloc(n_own)
        if pages is None:
            return False
        row = np.full(self.mp, self._sentinel, np.int32)
        row[:len(matched)] = matched
        row[len(matched):len(matched) + len(pages)] = pages
        self.bt[slot] = row
        self._pages_hwm = max(self._pages_hwm, self.alloc.pages_in_use)
        return True

    def _prefix_match(self, req: Request) -> tuple:
        """Trie walk for a request's prompt -> (matched page ids, matched
        token count).  The returned pages are increfed for this request;
        the caller must either splice them into the slot's block-table row
        (freed wholesale on release) or free them on reservation failure."""
        if self.prefix is None:
            return [], 0
        pages, toks = self.prefix.match(req.tokens)
        return pages, toks

    def _release_pages(self, slot: int) -> None:
        """Return a recycled slot's pages to the free list (sentinel padding
        is ignored by the allocator) and reset its table row."""
        if not self.paged or self.alloc is None:
            return
        self.alloc.free(int(p) for p in self.bt[slot])
        self.bt[slot] = self._sentinel

    def _next_eligible(self, queue, now: float) -> Optional[Request]:
        """First queued request that has ARRIVED (open-loop ``arrival``
        offsets make t_enqueue a future instant until then).  The queue is
        priority-then-FCFS ordered, so the scan preserves that order among
        arrived requests."""
        for r in queue:
            if r.t_enqueue <= now:
                return r
        return None

    def _admit(self, st, queue, out, max_new_tokens: int, *,
               limit: Optional[int] = None, degraded: bool = False) -> None:
        """Prefill queued requests into free slots (padded prompt,
        length-masked, under the request's tier term budget), scatter their
        caches into the live decode cache, and seed each slot with its
        first sampled token — all device-side (no host sync).  ``limit``
        caps concurrently-occupied slots at the usable pool (HBM admission
        headroom under the effective budget).  On the paged engine each
        admission first reserves its full page footprint; a failed
        reservation stops admission this round (strict priority/FCFS — a
        later smaller request never jumps a starved larger one)."""
        eng, sc = self.eng, self.eng.sc
        eos = jnp.int32(sc.eos_id)
        limit = self.n_slots if limit is None else limit
        t0 = time.perf_counter()
        while queue:
            occ = st["active"] | self._fill_mask()
            if occ.all() or int(occ.sum()) >= limit:
                break
            if self.prefix is not None and self._fill:
                # serialize admissions while a fill is in flight: the trie
                # only publishes a prompt's pages when its FINAL chunk
                # commits (_advance_fill), so admitting a sibling now would
                # miss pages it could have reused a few rounds later.
                # Costs no throughput — _plan_chunk already serializes
                # fills to one chunk per round from the oldest slot.
                break
            req = self._next_eligible(queue, time.perf_counter())
            if req is None:
                break
            slot = int(np.flatnonzero(~occ)[0])
            l = len(req.tokens)
            m = (req.max_new_tokens if req.max_new_tokens is not None
                 else max_new_tokens)
            matched_pages, matched = self._prefix_match(req)
            if not self._reserve_pages(slot, l, m, matched_pages):
                if matched_pages:
                    self.alloc.free(matched_pages)
                break
            queue.remove(req)
            tier = eng.tiers[req.quality]
            if self.chunk > 0 or matched > 0:
                # chunked fill (or a warm prefix suffix): the prompt enters
                # the decode rounds as per-round chunks instead of one
                # monolithic prefill dispatch.  A fully cached prompt still
                # recomputes its LAST token (the seed logit must come from
                # somewhere); its pool writes sit below the write floor and
                # divert to the sentinel, so shared pages stay untouched.
                start = min(matched, l - 1)
                if not self.paged:
                    # reset the slot row: chunk commits are incremental, so
                    # a recycled slot must not inherit the previous
                    # occupant's ring positions / recurrent carries
                    st["live"] = eng._scatter(st["live"], eng._fresh_row(),
                                              slot)
                st["clen"][slot] = start
                st["slot_req"][slot] = req
                self._fill[slot] = {
                    "req": req, "pos": start, "end": l, "wf": matched,
                    "budget": m,
                    "b_eff": eng._norm_budget(tier.budget_now(degraded)),
                }
                self._prefix_stats["tokens_reused"] += start
                req.t_admitted = time.perf_counter()
                out[req.rid] = []
                continue
            p_len = bucket_length(l, sc.prefill_bucket, sc.max_seq)
            padded = np.zeros((1, p_len), np.int32)
            padded[0, :l] = req.tokens
            prefill = eng._prefill_slot_for(tier.budget_now(degraded))
            logits, pcache = prefill(
                eng.params, {"tokens": jnp.asarray(padded)},
                jnp.asarray([l], jnp.int32))
            if self.paged:
                st["live"] = eng._scatter_paged(
                    st["live"], pcache, slot, jnp.asarray(self.bt[slot]))
            else:
                st["live"] = eng._scatter(st["live"], pcache, slot)
            st["key"], sub = jax.random.split(st["key"])
            first = eng._sample(logits, sub)           # (1, 1) on device
            st["tok"] = st["tok"].at[slot, 0].set(first[0, 0])
            st["alive"] = st["alive"].at[slot].set(first[0, 0] != eos)
            st["clen"][slot] = l
            st["active"][slot] = True
            st["budget"][slot] = m
            st["slot_req"][slot] = req
            self._prefix_stats["tokens_computed"] += l
            if self.prefix is not None:
                # adopt this prompt's full pages (bucket-pad garbage only
                # ever lands in the partial page / own decode pages, which
                # the trie never adopts)
                self.prefix.insert(req.tokens,
                                   [int(p) for p in self.bt[slot]])
            req.t_admitted = time.perf_counter()
            out[req.rid] = []
        st["prefill_s"] += time.perf_counter() - t0

    def _fill_mask(self) -> np.ndarray:
        mask = np.zeros(self.n_slots, bool)
        if self._fill:
            mask[list(self._fill)] = True
        return mask

    # -- deadlines ------------------------------------------------------
    def _cancel(self, req: Request, out, now: float) -> None:
        req.status = "cancelled"
        req.t_done = now
        gen = out.setdefault(req.rid, [])
        req.new_tokens = len(gen)

    def _cancel_expired(self, st, queue, out, now: float) -> int:
        """Deadline enforcement: an expired queued request is cancelled
        without ever occupying a slot; an expired running request is
        cancelled mid-run and its slot recycled immediately."""
        n_cancelled = 0
        for req in [r for r in queue
                    if r.deadline is not None and now > r.deadline]:
            queue.remove(req)
            self._cancel(req, out, now)
            n_cancelled += 1
        for i in np.flatnonzero(st["active"]):
            req = st["slot_req"][i]
            if req.deadline is not None and now > req.deadline:
                self._cancel(req, out, now)
                st["active"][i] = False
                st["slot_req"][i] = None
                self._release_pages(int(i))
                n_cancelled += 1
        for slot in [s for s, f in self._fill.items()
                     if f["req"].deadline is not None
                     and now > f["req"].deadline]:
            self._cancel(self._fill[slot]["req"], out, now)
            del self._fill[slot]
            st["slot_req"][slot] = None
            self._release_pages(int(slot))   # matched increfs drop with the row
            n_cancelled += 1
        return n_cancelled

    def _miss_rate(self, st, queue, now: float, usable: int,
                   max_new_tokens: int) -> float:
        return Q.estimate_miss_rate(
            now, self.watchdog.ema,
            active=[(int(st["budget"][i]), st["slot_req"][i].deadline)
                    for i in np.flatnonzero(st["active"])],
            queued=[((r.max_new_tokens if r.max_new_tokens is not None
                      else max_new_tokens), r.deadline) for r in queue],
            usable_slots=usable)

    # -- chaos-wrapped dispatch ----------------------------------------
    def _dispatch(self, fn, args):
        """Issue one jitted dispatch through the chaos injection point.

        Injection happens strictly BEFORE the real dispatch: a retried
        round has touched no donated buffer, so the retry re-issues the
        identical computation and a chaotic run's tokens match a calm
        run's bit-for-bit.  Retries are bounded by
        ``ChaosConfig.max_retries``; exhaustion re-raises."""
        if self.chaos is None:
            return fn(*args)
        attempt = 0
        while True:
            try:
                self.chaos.before_dispatch()
                return fn(*args)
            except Q.ChaosFailure:
                attempt += 1
                self.retries += 1
                if attempt > self.chaos.cfg.max_retries:
                    raise

    def _budget_groups(self, st, degraded: bool):
        """Active slots bucketed by *effective* (normalized) term budget.

        Deterministic dispatch order: the full context first, then
        descending budgets.  A single-tier workload lands in exactly one
        bucket, so its per-step dispatch count — and its jitted step — are
        identical to the tier-free engine's."""
        groups: Dict[Optional[int], List[int]] = {}
        for i in np.flatnonzero(st["active"]):
            tier = self.eng.tiers[st["slot_req"][i].quality]
            eff = self.eng._norm_budget(tier.budget_now(degraded))
            groups.setdefault(eff, []).append(int(i))
        order = sorted(groups, key=lambda b: (0, 0) if b is None else (1, -b))
        return [(b, groups[b]) for b in order]

    # -- chunked prefill (DESIGN.md §14) -------------------------------
    def _plan_chunk(self, st) -> Optional[Dict[str, Any]]:
        """This round's prefill chunk: the OLDEST filling slot (FCFS —
        insertion-ordered dict) contributes one chunk of up to
        ``prefill_chunk`` tokens (with ``prefill_chunk=0``, the whole
        remaining suffix at a bucketed width — the warm-prefix monolithic
        case).  Returns the host-side arrays the fused dispatch needs, or
        None when nothing is filling."""
        if not self._fill:
            return None
        sc = self.eng.sc
        slot = next(iter(self._fill))
        f = self._fill[slot]
        remaining = f["end"] - f["pos"]
        if self.chunk > 0:
            width = min(self.chunk, sc.max_seq)
        else:
            width = bucket_length(remaining, sc.prefill_bucket, sc.max_seq)
        valid = min(width, remaining)
        n = self.n_slots
        tokens = np.zeros((n, width), np.int32)
        tokens[slot, :valid] = f["req"].tokens[f["pos"]:f["pos"] + valid]
        valid_np = np.zeros(n, np.int32)
        valid_np[slot] = valid
        wf_np = np.zeros(n, np.int32)
        wf_np[slot] = f["wf"]
        return {"slot": slot, "f": f, "valid": valid, "tokens": tokens,
                "valid_np": valid_np, "wf_np": wf_np,
                "final": f["pos"] + valid >= f["end"], "b_eff": f["b_eff"]}

    def _dispatch_chunk(self, st, chunk, decode_mask: np.ndarray, clen_dev,
                        bt_dev, eos, temperature) -> None:
        """One chunk-fused dispatch: the filling slot's chunk plus (when
        ``decode_mask`` has members) the decode rows of the budget group it
        fused with.  Updates tok/live/key/alive exactly like a decode
        dispatch — non-committing rows keep their state bit-for-bit."""
        n = self.n_slots
        commit = decode_mask.copy()
        commit[chunk["slot"]] = True
        seed = np.zeros(n, bool)
        seed[chunk["slot"]] = chunk["final"]
        fn = self.eng._chunk_for(chunk["b_eff"])
        args = [self.eng.params, jnp.asarray(chunk["tokens"]), st["live"],
                clen_dev]
        if self.paged:
            args.append(bt_dev)
        args += [st["key"], st["alive"], eos, temperature,
                 jnp.asarray(chunk["valid_np"]), jnp.asarray(chunk["wf_np"]),
                 jnp.asarray(commit), jnp.asarray(decode_mask),
                 jnp.asarray(seed), st["tok"]]
        st["tok"], st["live"], st["key"], st["alive"] = \
            self._dispatch(fn, tuple(args))
        self._prefix_stats["chunk_dispatches"] += 1

    def _advance_fill(self, st, chunk) -> None:
        """Post-dispatch bookkeeping for the chunk: advance the fill cursor
        and cache length; on the final chunk promote the slot to a live
        decode row (its seed token was just sampled on device, exactly
        where monolithic admission leaves a fresh slot) and publish the
        prompt's pages to the trie."""
        slot, f = chunk["slot"], chunk["f"]
        st["clen"][slot] += chunk["valid"]
        f["pos"] += chunk["valid"]
        self._prefix_stats["tokens_computed"] += chunk["valid"]
        if chunk["final"]:
            del self._fill[slot]
            st["active"][slot] = True
            st["budget"][slot] = f["budget"]
            if self.prefix is not None:
                self.prefix.insert(f["req"].tokens,
                                   [int(p) for p in self.bt[slot]])

    def _retire_prefix(self) -> None:
        """End-of-run prefix-cache teardown: snapshot trie stats into the
        run's prefix ledger, audit trie/allocator coherence, then drop the
        trie's own page references so ``pages_in_use_end == 0`` (and
        ``PageAllocator.check()``) keep holding — the cache is per-run;
        cross-run persistence would pin pool pages past the run report."""
        if self.prefix is None:
            return
        ps = self.prefix.stats()
        self._prefix_stats["hits"] = ps["hits"]
        self._prefix_stats["misses"] = ps["misses"]
        self._prefix_stats["evictions"] = ps["evictions"]
        self._prefix_stats["trie_nodes_end"] = ps["nodes"]
        self.prefix.check()
        self.prefix.release_all()

    # ------------------------------------------------------------------
    def _finish_stats(self, requests, *, gen_tokens, steps, occupied_steps,
                      wall, prefill_s, extra=None) -> None:
        eng = self.eng
        decode_s = max(wall - prefill_s, 1e-9)
        self.last_request_metrics = {r.rid: r.metrics() for r in requests}
        self.last_run_stats = {
            "scheduler": "slots",
            "placement": eng.placement,
            "mesh_devices": eng.mesh_devices,
            "n_slots": self.n_slots,
            "requests": len(requests),
            "generated_tokens": gen_tokens,
            "decode_steps": steps,
            "occupancy": occupied_steps / steps if steps else 0.0,
            "wall_seconds": wall,
            "prefill_seconds": prefill_s,
            "decode_seconds": decode_s,
            # zero/near-zero durations map to 0.0 (finite metrics JSON on
            # tiny CI runs — never inf/NaN)
            "decode_tokens_per_sec": Q.safe_rate(gen_tokens, decode_s),
            "tokens_per_sec": Q.safe_rate(gen_tokens, wall),
        }
        if extra:
            self.last_run_stats.update(extra)

    def _qos_extra(self, requests, tier_stats, ctrl, st, queue, *,
                   dispatches, usable_min, retries_before) -> Dict[str, Any]:
        """Per-tier QoS metrics + controller/chaos/watchdog summaries for
        ``last_run_stats`` (the QoS benchmark's raw material)."""
        full_terms = self.eng.series_terms or 0
        tiers: Dict[str, Any] = {}
        for name, ts in tier_stats.items():
            group = [r for r in requests if r.quality == name]
            if not group:
                continue
            dl = [r for r in group if r.deadline is not None]
            hits = sum(1 for r in dl
                       if r.status == "ok" and r.t_done <= r.deadline)
            member = ts["member_steps"]
            tiers[name] = {
                "requests": len(group),
                "served_tokens": ts["served_tokens"],
                "nominal_terms": (full_terms
                                  if self.eng.tiers[name].budget is None
                                  else self.eng.tiers[name].budget),
                "mean_effective_terms": (ts["term_steps"] / member
                                         if member else 0.0),
                "degraded_step_fraction": (ts["degraded_steps"] / member
                                           if member else 0.0),
                "cancelled": sum(1 for r in group
                                 if r.status == "cancelled"),
                "deadline_total": len(dl),
                "deadline_hits": hits,
                "deadline_hit_rate": hits / len(dl) if dl else 1.0,
            }
        extra: Dict[str, Any] = {
            "tiers": tiers,
            "dispatches": dispatches,
            "usable_slots_min": usable_min,
            "cancelled": sum(1 for r in requests if r.status == "cancelled"),
            "dispatch_retries": self.retries - retries_before,
            "slots_leaked": int(st["active"].sum()),   # invariant: 0
            "queue_leftover": len(queue),              # invariant: 0
            "watchdog": self.watchdog.stats(),
        }
        if ctrl is not None:
            extra["qos"] = ctrl.stats()
        if self.chaos is not None:
            extra["chaos"] = self.chaos.stats()
        if self.paged:
            in_use = self.alloc.pages_in_use if self.alloc else 0
            extra["paged"] = {
                "page_size": self.page_size,
                "num_pages": self.num_pages,
                "pages_hwm": self._pages_hwm,
                "pages_in_use_end": in_use,       # invariant: 0 (no leaks)
                "page_bytes": self._pb,
                # peak paged-KV HBM vs the dense pool the same slots would
                # pin at max_seq — the headline admission win
                "kv_bytes_hwm": self._pages_hwm * self._pb,
                "kv_bytes_dense": self.n_slots * self.mp * self._pb,
            }
            if self.alloc is not None:
                self.alloc.check()                # leak/corruption audit
        if self.chunk > 0 or self.prefix_on:
            extra["prefix"] = dict(self._prefix_stats)
            extra["prefix"]["prefill_chunk"] = self.chunk
        return extra

    # -- MoE expert-load telemetry (DESIGN.md §15) ---------------------
    def _moe_init(self):
        """Device-side accumulators for the per-dispatch MoE stats rider
        (None when the engine's decode step carries no stats)."""
        if not getattr(self.eng, "_moe_stats", False):
            return None
        e = self.eng.cfg.num_experts
        return {"load": jnp.zeros((e,), jnp.int32),
                "round_max": jnp.float32(0.0),
                "round_mean": jnp.float32(0.0),
                "dropped": jnp.int32(0),
                "assigned": jnp.int32(0),
                "dispatches": 0}

    @staticmethod
    def _fold_moe(moe, mst) -> None:
        """Fold one dispatch's stats rider into the accumulators ON DEVICE
        (a handful of (E,)-sized adds — the host transfer happens once, at
        the end of the run, never per round)."""
        lf = mst["load"].astype(jnp.float32)
        moe["load"] = moe["load"] + mst["load"]
        moe["round_max"] = moe["round_max"] + jnp.max(lf)
        moe["round_mean"] = moe["round_mean"] + jnp.mean(lf)
        moe["dropped"] = moe["dropped"] + mst["dropped"]
        moe["assigned"] = moe["assigned"] + mst["assigned"]
        moe["dispatches"] += 1

    @staticmethod
    def _moe_extra(moe) -> Dict[str, Any]:
        """Expert-imbalance summary for ``last_run_stats["moe"]``: per-round
        mean of max/mean tokens-per-expert (layer-summed), their ratio, and
        the drop fraction (structurally 0.0 under token routing)."""
        if moe is None or moe["dispatches"] == 0:
            return {}
        load, rmax, rmean, dropped, assigned = jax.device_get(
            (moe["load"], moe["round_max"], moe["round_mean"],
             moe["dropped"], moe["assigned"]))
        n = moe["dispatches"]
        max_r, mean_r = float(rmax) / n, float(rmean) / n
        return {"moe": {
            "dispatches": n,
            "tokens_per_expert": [int(v) for v in load],
            "max_tokens_per_expert": max_r,
            "mean_tokens_per_expert": mean_r,
            "imbalance": (max_r / mean_r) if mean_r > 0 else 0.0,
            "drop_fraction": (float(dropped) / float(assigned)
                              if assigned else 0.0),
        }}

    @staticmethod
    def _apply_arrivals(requests: List[Request], t0: float) -> None:
        """Open-loop arrivals: a request with ``arrival > 0`` enqueues at
        ``t0 + arrival`` (a future t_enqueue keeps it ineligible until that
        instant, and TTFT/queue-wait metrics measure from arrival, not from
        run start)."""
        for r in requests:
            if r.arrival > 0:
                r.t_enqueue = t0 + r.arrival

    @staticmethod
    def _idle_sleep(queue, now: float) -> bool:
        """True when the pool is idle only because no queued request has
        arrived yet (open loop): sleep toward the next arrival instead of
        burning no-progress rounds against the idle cap."""
        nxt = min(r.t_enqueue for r in queue)
        if nxt <= now:
            return False
        time.sleep(min(nxt - now, 0.05))
        return True

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], max_new_tokens: int = 16
            ) -> Dict[int, List[int]]:
        eng, sc = self.eng, self.eng.sc
        n = self.n_slots
        self._validate(requests, max_new_tokens)
        if eng.spec_enabled:
            return self._run_spec(requests, max_new_tokens)

        queue = deque(self._order(requests))
        out: Dict[int, List[int]] = {}
        eos = jnp.int32(sc.eos_id)
        temperature = jnp.float32(sc.temperature)
        st = self._init_pool()
        active, clen, budget = st["active"], st["clen"], st["budget"]
        ctrl = Q.DegradeController(sc.degrade, n)
        self.watchdog = wd = self._new_watchdog()
        tier_stats = {name: {"served_tokens": 0, "member_steps": 0,
                             "term_steps": 0, "degraded_steps": 0}
                      for name in eng.tiers}
        full_terms = eng.series_terms or 0

        steps = 0             # decode DISPATCH iterations — the final drain
        occupied_steps = 0.0  # (emitting last pending tokens) dispatches none
        gen_tokens = 0
        moe = self._moe_init()
        dispatches = 0        # masked group dispatches (>= steps with tiers)
        idle_iters = 0
        usable_min = n
        retries0 = self.retries
        t_run0 = time.perf_counter()
        self._apply_arrivals(requests, t_run0)
        t_prev = None

        while queue or active.any() or self._fill:
            now = time.perf_counter()
            # 1) deadline enforcement (queued + running + filling)
            self._cancel_expired(st, queue, out, now)
            # 2) effective capacity under the (possibly squeezed) budget
            usable = self.usable_slots_now()
            usable_min = min(usable_min, usable)
            # 3) degradation controller: queue depth / HBM headroom /
            #    projected deadline misses.  Paged: pressure is page-pool
            #    exhaustion (requests waiting on a drained free list), not
            #    the dense max_seq-charged bound.
            if self.paged:
                pressure = (self.alloc is not None and queue
                            and self.alloc.free_pages == 0)
            else:
                pressure = (usable < n
                            and int(active.sum()) + len(queue) > usable)
            degraded = ctrl.update(
                queue_depth=len(queue),
                hbm_pressure=bool(pressure),
                miss_rate=self._miss_rate(st, queue, now, usable,
                                          max_new_tokens))
            # interleaved prefill: fill any free slot BEFORE the fetch, so a
            # newly admitted slot's first (prefill-sampled) token is read by
            # this iteration's transfer and only then consumed by decode —
            # admitting between fetch and decode would overwrite it unread.
            # Filling slots count as occupied.
            occ = active | self._fill_mask()
            if queue and not occ.all() and int(occ.sum()) < usable:
                self._admit(st, queue, out, max_new_tokens, limit=usable,
                            degraded=degraded)
            if not active.any() and not self._fill:
                if not queue:
                    continue               # drained -> loop exits
                # open-loop gap: everything queued is still in the future —
                # sleep toward the next arrival (never counts as idle)
                if self._idle_sleep(queue, time.perf_counter()):
                    continue
                # queue pending but nothing admittable (squeeze left zero
                # usable slots): spin the chaos round clock — windows are
                # counted in rounds, so the squeeze passes — with a hard
                # cap as the no-hang backstop
                if self.chaos is not None:
                    self.chaos.tick()
                idle_iters += 1
                if idle_iters > _IDLE_CAP:
                    raise SchedulerError(
                        f"scheduler made no progress for {_IDLE_CAP} rounds "
                        f"({len(queue)} queued, {usable} usable slots)")
                continue
            idle_iters = 0
            if active.any():
                # the ONE host transfer of this decode step (fill-only
                # rounds fetch nothing: no live row has a pending token)
                tok_host, alive_host = jax.device_get(
                    (st["tok"], st["alive"]))
                now = time.perf_counter()
                for i in np.flatnonzero(active):
                    req = st["slot_req"][i]
                    out[req.rid].append(int(tok_host[i, 0]))
                    gen_tokens += 1
                    tier_stats[req.quality]["served_tokens"] += 1
                    if req.t_first_token == 0.0:
                        req.t_first_token = now
                    budget[i] -= 1
                    if not bool(alive_host[i]) or budget[i] <= 0:
                        req.t_done = now
                        req.new_tokens = len(out[req.rid])
                        active[i] = False
                        st["slot_req"][i] = None  # slot freed -> recyclable
                        self._release_pages(int(i))
                if not active.any() and not self._fill:
                    if self.chaos is not None:
                        self.chaos.tick()
                    continue                    # admit or exit at the top
            # count the decode dispatch HERE, after the drain check: counting
            # at the loop top overstated decode_steps by one per drain (an
            # iteration that fetches+emits but dispatches no decode) and
            # correspondingly understated occupancy
            steps += 1
            occupied_steps += float(active.sum()) / n
            # snapshot clen: the host mutates it below, and numpy->device
            # transfers may alias the host buffer (CPU zero-copy)
            clen_dev = jnp.asarray(clen.copy())
            bt_dev = jnp.asarray(self.bt.copy()) if self.paged else None
            chunk = self._plan_chunk(st)
            chunk_fused = False
            # one masked dispatch per distinct effective term budget: only
            # member rows commit token/alive/cache writes, so every active
            # slot advances exactly one token under its own tier's context.
            # The budget group matching the filling request's tier absorbs
            # this round's prefill chunk into its dispatch (chunk-fused).
            for b_eff, members in self._budget_groups(st, degraded):
                mask = np.zeros(n, bool)
                mask[members] = True
                dispatches += 1
                if chunk is not None and not chunk_fused \
                        and b_eff == chunk["b_eff"]:
                    self._dispatch_chunk(st, chunk, mask, clen_dev, bt_dev,
                                         eos, temperature)
                    chunk_fused = True
                else:
                    if self.paged:
                        args = (eng.params, st["tok"], st["live"], clen_dev,
                                bt_dev, st["key"], st["alive"], eos,
                                temperature, jnp.asarray(mask))
                    else:
                        args = (eng.params, st["tok"], st["live"], clen_dev,
                                st["key"], st["alive"], eos, temperature,
                                jnp.asarray(mask))
                    res = self._dispatch(eng._decode_for(b_eff), args)
                    st["tok"], st["live"], st["key"], st["alive"] = res[:4]
                    if moe is not None and len(res) > 4:
                        self._fold_moe(moe, res[4])
                terms = full_terms if b_eff is None else b_eff
                for i in members:
                    req = st["slot_req"][i]
                    ts = tier_stats[req.quality]
                    ts["member_steps"] += 1
                    ts["term_steps"] += terms
                    if degraded and eng.tiers[req.quality].degradable:
                        ts["degraded_steps"] += 1
            if chunk is not None and not chunk_fused:
                # no decode group shares the fill's tier budget (or nothing
                # is decoding): the chunk dispatches standalone
                self._dispatch_chunk(st, chunk, np.zeros(n, bool), clen_dev,
                                     bt_dev, eos, temperature)
                dispatches += 1
            clen[active] += 1
            if chunk is not None:
                self._advance_fill(st, chunk)
            if self.chaos is not None:
                self.chaos.tick()
            now2 = time.perf_counter()
            if t_prev is not None:
                wd.observe(steps, now2 - t_prev)
            t_prev = now2
        wall = time.perf_counter() - t_run0
        self._retire_prefix()
        extra = self._qos_extra(requests, tier_stats, ctrl, st, queue,
                                dispatches=dispatches, usable_min=usable_min,
                                retries_before=retries0)
        extra.update(self._moe_extra(moe))
        self._finish_stats(requests, gen_tokens=gen_tokens, steps=steps,
                           occupied_steps=occupied_steps, wall=wall,
                           prefill_s=st["prefill_s"], extra=extra)
        return out

    # ------------------------------------------------------------------
    def _run_spec(self, requests: List[Request], max_new_tokens: int
                  ) -> Dict[int, List[int]]:
        """Self-speculative serving loop (DESIGN.md §10).

        Each round is ONE fused dispatch (draft γ tokens with the truncated
        series, verify the chunk with the full series, commit the accepted
        prefix) and ONE host transfer carrying up to γ+1 tokens per slot:
        the pre-round pending token plus the round's full-model tokens and
        accept counts.  Emission order per slot — pending token, then the
        accepted drafts, then the full-model correction becomes the next
        pending token — reproduces the non-speculative greedy stream
        token-for-token.

        QoS tiers are not served here (the term axis is already spent on
        drafting; the engine's tier table is ``full``-only), but deadlines,
        chaos injection and the dispatch watchdog apply round-wise exactly
        as on the plain path."""
        eng, sc = self.eng, self.eng.sc
        n = self.n_slots
        gamma = sc.spec_lookahead
        if sc.temperature > 0:
            raise ValueError(
                "speculative decoding serves greedy only (temperature=0): "
                "draft acceptance compares argmaxes; lossless speculative "
                "sampling would need rejection sampling on the verify logits")
        queue = deque(self._order(requests))
        out: Dict[int, List[int]] = {}
        st = self._init_pool()
        active, clen, budget = st["active"], st["clen"], st["budget"]
        self.watchdog = wd = self._new_watchdog()
        tier_stats = {name: {"served_tokens": 0, "member_steps": 0,
                             "term_steps": 0, "degraded_steps": 0}
                      for name in eng.tiers}

        rounds = 0
        dispatches = 0
        occupied_steps = 0.0
        gen_tokens = 0
        drafted = 0
        accepted = 0
        idle_iters = 0
        usable_min = n
        retries0 = self.retries
        t_run0 = time.perf_counter()
        self._apply_arrivals(requests, t_run0)
        t_prev = None
        eos = jnp.int32(sc.eos_id)
        temperature = jnp.float32(sc.temperature)   # greedy (0) by contract

        while queue or active.any() or self._fill:
            now = time.perf_counter()
            self._cancel_expired(st, queue, out, now)
            usable = self.usable_slots_now()
            usable_min = min(usable_min, usable)
            occ = active | self._fill_mask()
            if queue and not occ.all() and int(occ.sum()) < usable:
                self._admit(st, queue, out, max_new_tokens, limit=usable)
            if not active.any() and not self._fill:
                if not queue:
                    continue
                if self._idle_sleep(queue, time.perf_counter()):
                    continue
                if self.chaos is not None:
                    self.chaos.tick()
                idle_iters += 1
                if idle_iters > _IDLE_CAP:
                    raise SchedulerError(
                        f"scheduler made no progress for {_IDLE_CAP} rounds "
                        f"({len(queue)} queued, {usable} usable slots)")
                continue
            idle_iters = 0
            clen_dev = jnp.asarray(clen.copy())
            bt_dev = jnp.asarray(self.bt.copy()) if self.paged else None
            chunk = self._plan_chunk(st)
            if active.any():
                rounds += 1
                dispatches += 1
                occupied_steps += float(active.sum()) / n
                tok_pre = st["tok"]            # pending tokens entering round
                if self.paged:
                    spec_args = (eng.params, st["tok"], st["live"], clen_dev,
                                 bt_dev)
                else:
                    spec_args = (eng.params, st["tok"], st["live"], clen_dev)
                if eng._spec_takes_mask:
                    # masked variant: filling slots (and empty rows) must not
                    # see draft-chunk writes in their ring/recurrent/paged
                    # state — only active rows commit
                    spec_args = spec_args + (jnp.asarray(active.copy()),)
                st["tok"], st["live"], full, accept = self._dispatch(
                    eng._spec, spec_args)
                # chunk dispatched AFTER spec: its tok passthrough reads the
                # round's new pending tokens and writes only the seed row
                if chunk is not None:
                    self._dispatch_chunk(st, chunk, np.zeros(n, bool),
                                         clen_dev, bt_dev, eos, temperature)
                    dispatches += 1
                # the ONE host transfer of this round (up to γ+1 tokens/slot)
                tok_host, full_host, acc_host = jax.device_get(
                    (tok_pre, full, accept))
                now = time.perf_counter()
                for i in np.flatnonzero(active):
                    req = st["slot_req"][i]
                    m_i = int(acc_host[i])
                    drafted += gamma
                    accepted += m_i
                    # pending token first, then the m accepted draft tokens
                    # (full_host[i, :m] — identical to the drafts by
                    # acceptance); the correction full_host[i, m] stays on
                    # device as the next pending token
                    emit = [int(tok_host[i, 0])] + \
                        [int(t) for t in full_host[i, :m_i]]
                    if req.t_first_token == 0.0:
                        req.t_first_token = now
                    done = False
                    for t in emit:
                        out[req.rid].append(t)
                        gen_tokens += 1
                        tier_stats[req.quality]["served_tokens"] += 1
                        budget[i] -= 1
                        if t == sc.eos_id or budget[i] <= 0:
                            done = True
                            break
                    clen[i] += m_i + 1         # mirrors commit_verify
                    if done:
                        req.t_done = now
                        req.new_tokens = len(out[req.rid])
                        active[i] = False
                        st["slot_req"][i] = None
                        self._release_pages(int(i))
                now2 = time.perf_counter()
                if t_prev is not None:
                    wd.observe(rounds, now2 - t_prev)
                t_prev = now2
            elif chunk is not None:
                # fill-only round: no live decode row, no host transfer
                self._dispatch_chunk(st, chunk, np.zeros(n, bool), clen_dev,
                                     bt_dev, eos, temperature)
                dispatches += 1
            if chunk is not None:
                self._advance_fill(st, chunk)
            if self.chaos is not None:
                self.chaos.tick()
        wall = time.perf_counter() - t_run0
        self._retire_prefix()
        extra = self._qos_extra(requests, tier_stats, None, st, queue,
                                dispatches=dispatches, usable_min=usable_min,
                                retries_before=retries0)
        extra.update({
            "spec_terms": sc.spec_terms,
            "spec_lookahead": gamma,
            "spec_rounds": rounds,
            "draft_tokens": drafted,
            "accepted_draft_tokens": accepted,
            "acceptance_rate": accepted / drafted if drafted else 0.0,
            "tokens_per_round": gen_tokens / rounds if rounds else 0.0,
        })
        self._finish_stats(
            requests, gen_tokens=gen_tokens, steps=rounds,
            occupied_steps=occupied_steps, wall=wall,
            prefill_s=st["prefill_s"], extra=extra)
        return out
