"""KV/state-cache accounting, page allocator, and sizing helpers.

Cache construction lives with the blocks (models/blocks.init_block_cache,
models/model.init_cache); this module provides the size model used by the
serving engine's admission control and the roofline's memory-term notes,
plus the :class:`PageAllocator` behind the paged KV cache
(``ServeConfig(paged=True)``): growing attention KV lives in a global
per-layer page pool indexed through per-slot block tables, so a slot's
resident HBM is ``pages_reserved * page_bytes`` instead of
``max_seq * bytes_per_token`` — the page-granular accounting in
:func:`plan_pages` / :func:`max_slots_paged` is what raises concurrent
slot count for short sequences.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig
from repro.models.ssm import ssm_dims


def cache_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2,
                          s_max: int = 0, int8_kv: bool = False
                          ) -> Dict[str, float]:
    """Bytes of cache that grow per sequence position, and fixed state bytes.

    ``s_max`` (the decode capacity) bounds the local-attention ring: the
    allocator caps the ring at ``min(window, s_max)``
    (``models.blocks.init_block_cache``), so charging the full window when
    ``s_max < window`` over-counts and makes ``max_batch_for_hbm`` /
    ``plan_slots`` under-admit.  ``s_max=0`` keeps the unbounded (allocation-
    free roofline) estimate.

    ``int8_kv`` charges the growing attention KV at its *stored* width —
    int8 planes plus one f32 scale per (position, kv-head) — instead of
    ``dtype_bytes``.  Charging 2-byte KV while serving int8 over-counts the
    attention caches ~2x and under-admits (the admission-control bug this
    parameter fixes); local rings / cross KV stay fp regardless."""
    growing = 0.0
    fixed = 0.0
    blocks = tuple(cfg.stage_pattern) * cfg.num_stages + tuple(cfg.tail_pattern)
    ring = min(cfg.window, s_max if s_max > 0 else 1 << 30)
    for kind in blocks:
        if kind in ("attn", "moe_attn"):
            if int8_kv:  # int8 planes + f32 per-(pos, kv-head) scales
                growing += 2 * cfg.num_kv_heads * (cfg.head_dim * 1 + 4)
            else:
                growing += 2 * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
        elif kind == "local":
            fixed += 2 * ring * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
        elif kind == "cross":
            fixed += 2 * cfg.num_image_tokens * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
        elif kind == "rglru":
            fixed += (cfg.rnn_width + 3 * cfg.rnn_width) * dtype_bytes
        elif kind == "ssm":
            d = ssm_dims(cfg)
            fixed += (d["heads"] * d["p"] * d["n"] + (cfg.ssm_conv - 1) * d["conv_ch"]) * dtype_bytes
    return {"growing_per_token": growing, "fixed": fixed}


def total_cache_bytes(cfg: ArchConfig, batch: int, s_max: int,
                      dtype_bytes: int = 2, int8_kv: bool = False) -> float:
    c = cache_bytes_per_token(cfg, dtype_bytes, s_max=s_max, int8_kv=int8_kv)
    grow = c["growing_per_token"] * s_max
    return batch * (grow + c["fixed"])


def max_batch_for_hbm(cfg: ArchConfig, s_max: int, hbm_bytes: float,
                      param_bytes: float, dtype_bytes: int = 2,
                      cache_copies: float = 1.0, int8_kv: bool = False) -> int:
    """Admission control: largest decode batch whose caches + params fit.

    ``cache_copies`` charges each sequence's cache more than once —
    speculative engines pass 2.0 because the fused draft+verify round holds
    a transient functional copy of the caches at peak (the originals must
    stay live for verify/commit while the draft decodes on a copy).
    ``int8_kv`` must mirror the engine's KV storage width (see
    :func:`cache_bytes_per_token`)."""
    per_seq = total_cache_bytes(cfg, 1, s_max, dtype_bytes, int8_kv=int8_kv) \
        * max(cache_copies, 1.0)
    free = hbm_bytes - param_bytes
    return max(0, int(np.floor(free / max(per_seq, 1.0))))


def hbm_headroom(cfg: ArchConfig, s_max: int, hbm_bytes: float,
                 param_bytes: float, active_slots: int,
                 dtype_bytes: int = 2, cache_copies: float = 1.0,
                 int8_kv: bool = False) -> float:
    """Free HBM after params + the caches of ``active_slots`` sequences.

    The serving scheduler's admission-headroom signal: when a chaos-squeezed
    (or genuinely shrunken) effective budget drives this toward zero, the
    degradation controller reacts *before* admissions would have to be
    rejected.  May be negative: the active set already exceeds the
    (squeezed) budget — existing slots keep running, new admissions wait."""
    per_seq = total_cache_bytes(cfg, 1, s_max, dtype_bytes, int8_kv=int8_kv) \
        * max(cache_copies, 1.0)
    return float(hbm_bytes - param_bytes - active_slots * per_seq)


def usable_slots(cfg: ArchConfig, s_max: int, hbm_bytes: float,
                 param_bytes: float, n_slots: int,
                 dtype_bytes: int = 2, cache_copies: float = 1.0,
                 int8_kv: bool = False) -> int:
    """Slots the (possibly squeezed) effective budget can serve right now:
    ``max_batch_for_hbm`` capped at the planned pool, floored at 0 (a
    transient squeeze may leave no admission headroom at all — the
    scheduler then degrades and waits instead of rejecting)."""
    if hbm_bytes <= 0:
        return n_slots
    cap = max_batch_for_hbm(cfg, s_max, hbm_bytes, param_bytes, dtype_bytes,
                            cache_copies=cache_copies, int8_kv=int8_kv)
    return max(0, min(n_slots, cap))


# ---------------------------------------------------------------------------
# paged KV: page-granular sizing + the allocator (DESIGN.md §13)
# ---------------------------------------------------------------------------
def page_bytes(cfg: ArchConfig, page_size: int, dtype_bytes: int = 2,
               int8_kv: bool = False) -> float:
    """HBM bytes ONE page id costs across every attention layer's pool.

    A page id indexes the same physical slot of every attn/moe_attn layer's
    pool (one block table serves the whole stack), so a page's cost is the
    summed per-token growing KV bytes times the page size."""
    c = cache_bytes_per_token(cfg, dtype_bytes, int8_kv=int8_kv)
    return c["growing_per_token"] * page_size


def pages_for(length: int, page_size: int) -> int:
    """Pages covering ``length`` positions (ceil division)."""
    return -(-max(0, int(length)) // page_size)


def fixed_state_bytes(cfg: ArchConfig, s_max: int, dtype_bytes: int = 2
                      ) -> float:
    """Per-slot bytes that do NOT page: local rings, cross KV, recurrent
    state (always dense per-slot rows, paged or not)."""
    return cache_bytes_per_token(cfg, dtype_bytes, s_max=s_max)["fixed"]


def plan_pages(cfg: ArchConfig, s_max: int, page_size: int, n_slots: int,
               hbm_bytes: float = 0.0, param_bytes: float = 0.0,
               dtype_bytes: int = 2, cache_copies: float = 1.0,
               int8_kv: bool = False) -> int:
    """Size the global page pool.

    Without an HBM budget: enough pages for every slot at full capacity
    (``n_slots * ceil(s_max / page)`` — dense-equivalent worst case).  With
    a budget: whatever fits after params and the per-slot fixed state,
    floored at one sequence's worth so a configured pool is never unusable.
    ``cache_copies`` (speculative engines) scales the page cost, mirroring
    :func:`max_batch_for_hbm`."""
    per_slot_pages = pages_for(s_max, page_size)
    if hbm_bytes <= 0:
        return n_slots * per_slot_pages
    fixed = fixed_state_bytes(cfg, s_max, dtype_bytes) * max(cache_copies, 1.0)
    pb = page_bytes(cfg, page_size, dtype_bytes, int8_kv=int8_kv) \
        * max(cache_copies, 1.0)
    free = hbm_bytes - param_bytes - n_slots * fixed
    if pb <= 0:       # attention-free arch: nothing pages
        return 0
    return max(per_slot_pages, int(np.floor(free / pb)))


def max_slots_paged(cfg: ArchConfig, s_max: int, page_size: int,
                    hbm_bytes: float, param_bytes: float,
                    dtype_bytes: int = 2, cache_copies: float = 1.0,
                    int8_kv: bool = False, mean_len: float = 0.0) -> int:
    """Page-granular admission bound: slots whose fixed state plus
    ``ceil(mean_len / page)`` pages fit the budget.  ``mean_len=0`` charges
    one page per slot (the floor any live slot needs) — the *upper* bound
    the paged scheduler can reach when sequences are short; compare with
    :func:`max_batch_for_hbm`, which charges every slot ``s_max``."""
    copies = max(cache_copies, 1.0)
    fixed = fixed_state_bytes(cfg, s_max, dtype_bytes) * copies
    pb = page_bytes(cfg, page_size, dtype_bytes, int8_kv=int8_kv) * copies
    pages = max(1, pages_for(mean_len, page_size)) if pb > 0 else 0
    per_slot = fixed + pages * pb
    free = hbm_bytes - param_bytes
    return max(0, int(np.floor(free / max(per_slot, 1.0))))


class PageAllocator:
    """Fixed-size-page allocator: free list + per-page refcounts.

    Host-side bookkeeping for the paged KV cache: page ids index the global
    per-layer pools; id ``num_pages`` is the *sentinel* (a real, in-bounds
    pool row that absorbs writes from masked-out or unallocated table slots
    and is never read unmasked).  Refcounts support shared pages (the
    prefix-caching roadmap item): :meth:`alloc` returns pages at refcount 1,
    :meth:`incref` adds sharers, :meth:`free` decrements and returns a page
    to the free list only at zero.  Double-free and foreign-page frees
    raise — the property test's invariant."""

    def __init__(self, num_pages: int):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = int(num_pages)
        self._free = deque(range(self.num_pages))
        self._ref = np.zeros(self.num_pages, np.int32)

    @property
    def sentinel(self) -> int:
        return self.num_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def refcount(self, page: int) -> int:
        """Current reference count of ``page`` (0 = free)."""
        if not (0 <= page < self.num_pages):
            raise ValueError(f"refcount of foreign page {page}")
        return int(self._ref[page])

    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` fresh pages at refcount 1, or None when the pool cannot
        cover the request (all-or-nothing: no partial allocation to roll
        back)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def incref(self, pages: Sequence[int]) -> None:
        for p in pages:
            if not (0 <= p < self.num_pages) or self._ref[p] < 1:
                raise ValueError(f"incref of unallocated page {p}")
            self._ref[p] += 1

    def free(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; a page returns to the free list when
        its last reference drops.  Sentinel ids are ignored (a block-table
        row is freed wholesale, padding included)."""
        for p in pages:
            if p == self.sentinel:
                continue
            if not (0 <= p < self.num_pages) or self._ref[p] < 1:
                raise ValueError(f"double/foreign free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)

    def check(self) -> None:
        """Leak/corruption audit: every page is either free (ref 0) or
        referenced (ref >= 1), and the free list holds exactly the ref-0
        pages with no duplicates."""
        free = sorted(self._free)
        if len(set(free)) != len(free):
            raise AssertionError("free list holds duplicate pages")
        ref0 = sorted(int(p) for p in np.flatnonzero(self._ref == 0))
        if free != ref0:
            raise AssertionError(
                f"free list {free} != ref-0 pages {ref0} (leak or corruption)")


class PrefixCache:
    """Radix trie over prompt token prefixes at page granularity.

    Each node covers exactly ``page_size`` tokens (keyed by that token
    tuple) and owns one page id in the paged KV pool.  The trie holds its
    *own* reference on every adopted page, so a cached prefix outlives the
    request that produced it: :meth:`match` walks the trie for a new prompt
    and increfs the matched run *on behalf of the caller* (the scheduler
    splices those ids into the request's block table and later frees the
    whole row, dropping exactly the reference ``match`` took).  Because the
    low-bit series expansion is a deterministic function of the prompt
    (PAPER.md Theorem 1), matched pages are bit-identical to what a cold
    prefill would have written — sharing them preserves token-level output.

    Only *full* pages are cached: a prompt's trailing partial page also
    holds decode positions, which diverge across requests.  Eviction is
    LRU over leaf nodes whose page refcount is 1 (trie-only — a page any
    live block table still references is never reclaimed); removing a leaf
    can expose its parent to the next sweep.  A logical clock orders
    recency so behaviour is deterministic under test.
    """

    def __init__(self, alloc: PageAllocator, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.alloc = alloc
        self.page_size = int(page_size)
        self._children: Dict[tuple, dict] = {}
        self._clock = 0
        self._n_nodes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def nodes(self) -> int:
        return self._n_nodes

    def _key(self, tokens: Sequence[int], pi: int) -> tuple:
        lo = pi * self.page_size
        return tuple(int(t) for t in tokens[lo:lo + self.page_size])

    def match(self, tokens: Sequence[int]) -> tuple:
        """Longest cached page run for ``tokens`` -> (page_ids, n_tokens).

        Increfs every returned page for the caller; the caller owns those
        references (typically released via the block-table row free)."""
        self._clock += 1
        pages: List[int] = []
        children = self._children
        for pi in range(len(tokens) // self.page_size):
            node = children.get(self._key(tokens, pi))
            if node is None:
                break
            node["clock"] = self._clock
            pages.append(node["page"])
            children = node["children"]
        if pages:
            self.alloc.incref(pages)
            self.hits += 1
        else:
            self.misses += 1
        return pages, len(pages) * self.page_size

    def insert(self, tokens: Sequence[int], page_ids: Sequence[int]) -> int:
        """Adopt the full prompt pages of ``tokens`` (backed by
        ``page_ids``, the request's block-table row) into the trie.

        Existing nodes are kept as-is (first writer wins — a concurrent
        cold duplicate's own pages simply free when its row releases); new
        nodes take one trie-owned reference on their page.  Returns the
        number of newly adopted pages."""
        self._clock += 1
        adopted = 0
        children = self._children
        for pi in range(len(tokens) // self.page_size):
            key = self._key(tokens, pi)
            node = children.get(key)
            if node is None:
                page = int(page_ids[pi])
                if not (0 <= page < self.alloc.num_pages):
                    raise ValueError(
                        f"cannot adopt sentinel/foreign page {page}")
                self.alloc.incref([page])
                node = {"page": page, "children": {}, "clock": self._clock}
                children[key] = node
                self._n_nodes += 1
                adopted += 1
            else:
                node["clock"] = self._clock
            children = node["children"]
        return adopted

    def _leaves(self) -> List[tuple]:
        out: List[tuple] = []
        stack = [self._children]
        while stack:
            children = stack.pop()
            for key, node in children.items():
                if node["children"]:
                    stack.append(node["children"])
                elif self.alloc.refcount(node["page"]) == 1:
                    out.append((node["clock"], children, key, node))
        return out

    def evict(self, n_pages: int) -> int:
        """Free up to ``n_pages`` pages by dropping least-recently-used
        leaf nodes whose page only the trie references.  Returns the number
        actually freed (0 when nothing is evictable)."""
        freed = 0
        while freed < n_pages:
            cands = sorted(self._leaves(), key=lambda c: c[0])
            if not cands:
                break
            for _, children, key, node in cands:
                if freed >= n_pages:
                    break
                del children[key]
                self._n_nodes -= 1
                self.alloc.free([node["page"]])
                freed += 1
                self.evictions += 1
        return freed

    def release_all(self) -> None:
        """Drop every trie-owned reference and clear the trie (end of a
        serving run — the pool and allocator are rebuilt per run)."""
        stack = [self._children]
        while stack:
            children = stack.pop()
            for node in children.values():
                stack.append(node["children"])
                self.alloc.free([node["page"]])
        self._children = {}
        self._n_nodes = 0

    def check(self) -> None:
        """Audit: node keys span exactly one page, no page is owned by two
        nodes, and every owned page is live in the allocator."""
        seen = set()
        stack = [self._children]
        while stack:
            children = stack.pop()
            for key, node in children.items():
                if len(key) != self.page_size:
                    raise AssertionError(f"trie key of length {len(key)}")
                if node["page"] in seen:
                    raise AssertionError(
                        f"page {node['page']} owned by two trie nodes")
                seen.add(node["page"])
                if self.alloc.refcount(node["page"]) < 1:
                    raise AssertionError(
                        f"trie references freed page {node['page']}")
                stack.append(node["children"])

    def stats(self) -> Dict[str, int]:
        return {"nodes": self._n_nodes, "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions}


def param_bytes(params) -> float:
    """Total *logical* bytes of a (possibly expanded) parameter pytree.

    ``ExpandedTensor`` leaves flatten to their component arrays, so INT
    planes + FP scales are counted at their stored widths.  For a pytree
    sharded over a mesh this is the global footprint summed over all
    devices; per-device admission control uses
    :func:`param_bytes_per_device`."""
    import jax

    return float(sum(leaf.size * leaf.dtype.itemsize
                     for leaf in jax.tree_util.tree_leaves(params)))


def param_bytes_per_device(params) -> float:
    """Bytes of the parameter pytree resident on ONE device.

    Mesh-aware: a leaf carrying a ``jax.sharding`` (e.g. series planes
    scattered over the ``"expand"`` axis by ``placement="term"``, or
    column-sharded ``"tensor"`` leaves) is counted at its shard size;
    replicated / host leaves count in full.  Equals :func:`param_bytes` for
    an unsharded tree, so the serving engine uses this unconditionally for
    HBM admission control."""
    import jax

    total = 0.0
    for leaf in jax.tree_util.tree_leaves(params):
        n = leaf.size
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "shard_shape"):
            n = int(np.prod(sharding.shard_shape(leaf.shape), dtype=np.int64))
        total += float(n) * leaf.dtype.itemsize
    return total
