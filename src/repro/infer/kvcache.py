"""KV/state-cache accounting and helpers.

Cache construction lives with the blocks (models/blocks.init_block_cache,
models/model.init_cache); this module provides the size model used by the
serving engine's admission control and the roofline's memory-term notes.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import ArchConfig
from repro.models.ssm import ssm_dims


def cache_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2,
                          s_max: int = 0) -> Dict[str, float]:
    """Bytes of cache that grow per sequence position, and fixed state bytes.

    ``s_max`` (the decode capacity) bounds the local-attention ring: the
    allocator caps the ring at ``min(window, s_max)``
    (``models.blocks.init_block_cache``), so charging the full window when
    ``s_max < window`` over-counts and makes ``max_batch_for_hbm`` /
    ``plan_slots`` under-admit.  ``s_max=0`` keeps the unbounded (allocation-
    free roofline) estimate."""
    growing = 0.0
    fixed = 0.0
    blocks = tuple(cfg.stage_pattern) * cfg.num_stages + tuple(cfg.tail_pattern)
    ring = min(cfg.window, s_max if s_max > 0 else 1 << 30)
    for kind in blocks:
        if kind in ("attn", "moe_attn"):
            growing += 2 * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
        elif kind == "local":
            fixed += 2 * ring * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
        elif kind == "cross":
            fixed += 2 * cfg.num_image_tokens * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
        elif kind == "rglru":
            fixed += (cfg.rnn_width + 3 * cfg.rnn_width) * dtype_bytes
        elif kind == "ssm":
            d = ssm_dims(cfg)
            fixed += (d["heads"] * d["p"] * d["n"] + (cfg.ssm_conv - 1) * d["conv_ch"]) * dtype_bytes
    return {"growing_per_token": growing, "fixed": fixed}


def total_cache_bytes(cfg: ArchConfig, batch: int, s_max: int, dtype_bytes: int = 2) -> float:
    c = cache_bytes_per_token(cfg, dtype_bytes, s_max=s_max)
    grow = c["growing_per_token"] * s_max
    return batch * (grow + c["fixed"])


def max_batch_for_hbm(cfg: ArchConfig, s_max: int, hbm_bytes: float,
                      param_bytes: float, dtype_bytes: int = 2,
                      cache_copies: float = 1.0) -> int:
    """Admission control: largest decode batch whose caches + params fit.

    ``cache_copies`` charges each sequence's cache more than once —
    speculative engines pass 2.0 because the fused draft+verify round holds
    a transient functional copy of the caches at peak (the originals must
    stay live for verify/commit while the draft decodes on a copy)."""
    per_seq = total_cache_bytes(cfg, 1, s_max, dtype_bytes) * max(cache_copies, 1.0)
    free = hbm_bytes - param_bytes
    return max(0, int(np.floor(free / max(per_seq, 1.0))))


def hbm_headroom(cfg: ArchConfig, s_max: int, hbm_bytes: float,
                 param_bytes: float, active_slots: int,
                 dtype_bytes: int = 2, cache_copies: float = 1.0) -> float:
    """Free HBM after params + the caches of ``active_slots`` sequences.

    The serving scheduler's admission-headroom signal: when a chaos-squeezed
    (or genuinely shrunken) effective budget drives this toward zero, the
    degradation controller reacts *before* admissions would have to be
    rejected.  May be negative: the active set already exceeds the
    (squeezed) budget — existing slots keep running, new admissions wait."""
    per_seq = total_cache_bytes(cfg, 1, s_max, dtype_bytes) \
        * max(cache_copies, 1.0)
    return float(hbm_bytes - param_bytes - active_slots * per_seq)


def usable_slots(cfg: ArchConfig, s_max: int, hbm_bytes: float,
                 param_bytes: float, n_slots: int,
                 dtype_bytes: int = 2, cache_copies: float = 1.0) -> int:
    """Slots the (possibly squeezed) effective budget can serve right now:
    ``max_batch_for_hbm`` capped at the planned pool, floored at 0 (a
    transient squeeze may leave no admission headroom at all — the
    scheduler then degrades and waits instead of rejecting)."""
    if hbm_bytes <= 0:
        return n_slots
    cap = max_batch_for_hbm(cfg, s_max, hbm_bytes, param_bytes, dtype_bytes,
                            cache_copies=cache_copies)
    return max(0, min(n_slots, cap))


def param_bytes(params) -> float:
    """Total *logical* bytes of a (possibly expanded) parameter pytree.

    ``ExpandedTensor`` leaves flatten to their component arrays, so INT
    planes + FP scales are counted at their stored widths.  For a pytree
    sharded over a mesh this is the global footprint summed over all
    devices; per-device admission control uses
    :func:`param_bytes_per_device`."""
    import jax

    return float(sum(leaf.size * leaf.dtype.itemsize
                     for leaf in jax.tree_util.tree_leaves(params)))


def param_bytes_per_device(params) -> float:
    """Bytes of the parameter pytree resident on ONE device.

    Mesh-aware: a leaf carrying a ``jax.sharding`` (e.g. series planes
    scattered over the ``"expand"`` axis by ``placement="term"``, or
    column-sharded ``"tensor"`` leaves) is counted at its shard size;
    replicated / host leaves count in full.  Equals :func:`param_bytes` for
    an unsharded tree, so the serving engine uses this unconditionally for
    HBM admission control."""
    import jax

    total = 0.0
    for leaf in jax.tree_util.tree_leaves(params):
        n = leaf.size
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "shard_shape"):
            n = int(np.prod(sharding.shard_shape(leaf.shape), dtype=np.int64))
        total += float(n) * leaf.dtype.itemsize
    return total
