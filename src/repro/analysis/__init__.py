"""Static analysis: mechanized serving-correctness contracts (DESIGN.md §12).

Every serving PR so far hand-discovered the same bug classes: f32 partial
psums breaking the integer-domain exactness contract (PR 4), retraces from
dynamic operands marked static (PR 3), drain-loop host transfers miscounted
(PR 5), bare asserts compiled out under ``-O``, and numeric-constant tables
duplicated across modules drifting apart (PR 5 bug #5).  This package
mechanizes those contracts so they are *proved on every commit* instead of
re-found by hand:

* :mod:`repro.analysis.jaxpr_check` — a jaxpr walker that traces any jitted
  callable and checks declared contracts: the integer-domain psum rule (no
  float ``psum`` on the ``"expand"`` mesh axis), host-callback censuses,
  MXU/kernel dispatch budgets, a runtime donation ledger (donated buffers
  never reused — the chaos double-apply class), a host-transfer census
  (``device_get`` per decode round <= 1), and a retrace tripwire over jit
  caches;
* :mod:`repro.analysis.lint` — repo-specific AST lint rules
  (``python -m repro.analysis lint``): no bare ``assert`` on runtime paths,
  no dynamic operands in ``static_argnames``, no duplicated numeric-constant
  tables (``repro/numerics.py`` is the single source), no cache-busting
  ``jax.jit`` in loops;
* :mod:`repro.analysis.budgets` — a committed per-entry-point budget ledger
  (``analysis_budgets.json``): dispatch/transfer/retrace budgets for the
  fused decode, QoS-masked, spec-decode, and prefill steps, asserted by
  ``tests/test_analysis.py`` and the CI ``analysis`` job;
* :mod:`repro.analysis.contracts` — the lightweight declaration layer the
  serving entry points annotate themselves with (``infer/serve.py``,
  ``dist/expansion_parallel.py``), read back by the checkers.

Every checker has a mutation self-test (seed the bug, assert the checker
fires with a pointed ``file:line`` diagnostic) — a checker that cannot fail
is not a check.
"""
from repro.analysis.contracts import Contract, annotate, get_contract

# jaxpr_check pulls in jax; resolve its names lazily so runtime modules
# (infer/, dist/) can import the stdlib-only contracts layer without
# paying for — or cycling through — the analysis machinery.
_LAZY = {
    "AnalysisViolation": "jaxpr_check",
    "DonationLedger": "jaxpr_check",
    "TransferCensus": "jaxpr_check",
    "Violation": "jaxpr_check",
    "check_integer_psum": "jaxpr_check",
    "check_budget": "jaxpr_check",
    "check_no_retrace": "jaxpr_check",
    "count_host_callbacks": "jaxpr_check",
    "dispatch_census": "jaxpr_check",
    "gemm_dispatch_count": "jaxpr_check",
    "jit_cache_sizes": "jaxpr_check",
    "kernel_structure": "jaxpr_check",
    "LintError": "lint",
    "run_lint": "lint",
    "load_budgets": "budgets",
    "measure_budgets": "budgets",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.analysis.{_LAZY[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))


__all__ = ["Contract", "annotate", "get_contract"] + sorted(_LAZY)
