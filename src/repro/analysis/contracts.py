"""Contract declarations: what an entry point promises, machine-readable.

The serving stack annotates its entry points (``infer/serve.py`` step
factories, ``dist/expansion_parallel.py``) with a :class:`Contract` —
the invariants each callable promises — and the checkers in
:mod:`repro.analysis.jaxpr_check` / :mod:`repro.analysis.budgets` read the
annotation back instead of hard-coding per-function knowledge.  This module
is stdlib-only on purpose: ``repro.infer`` imports it at module load, so it
must never pull in jax-heavy analysis machinery (no import cycle).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

#: attribute name the annotation is stored under
ATTR = "__repro_contract__"


@dataclasses.dataclass(frozen=True)
class Contract:
    """The machine-checkable promises of one serving entry point.

    Fields are ceilings/requirements a checker enforces; ``None`` means
    "not contracted" (the checker skips that dimension):

    * ``transfers_per_round`` — host ``device_get`` calls the driving loop
      may issue per dispatch round (the one-transfer serving contract);
    * ``int_psum_axes`` — mesh axes on which every ``psum`` inside the
      traced computation must reduce *integers* (the Abelian exactness
      contract of DESIGN.md §9; f32 partial sums reassociate per device
      count — the PR 4 divergence class);
    * ``float_psum_waiver`` — human-readable reason a float psum is allowed
      (e.g. the weight-only path has no requantization amplifier); when
      set, :func:`~repro.analysis.jaxpr_check.check_integer_psum` is run
      with the waiver and only *reports*, never fails, float reductions;
    * ``dynamic_operands`` — operand names that must NEVER appear in
      ``static_argnames`` anywhere in the repo (the temperature-retrace
      class; lint rule REPRO102 enforces the global denylist);
    * ``donate_argnums`` — positions the caller donates; the
      :class:`~repro.analysis.jaxpr_check.DonationLedger` uses this to
      assert a donated buffer is never passed again (chaos double-apply);
    * ``budget_key`` — entry under ``analysis_budgets.json`` carrying this
      callable's dispatch budgets.
    """
    name: str
    transfers_per_round: Optional[int] = None
    int_psum_axes: Tuple[str, ...] = ()
    float_psum_waiver: str = ""
    dynamic_operands: Tuple[str, ...] = ()
    donate_argnums: Tuple[int, ...] = ()
    budget_key: str = ""

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def annotate(fn, **kwargs):
    """Attach a :class:`Contract` to ``fn`` (returns ``fn``, decorator-style).

    ``annotate(step, name="fused_decode", transfers_per_round=1, ...)``
    """
    setattr(fn, ATTR, Contract(**kwargs))
    return fn


def get_contract(fn) -> Optional[Contract]:
    """The :class:`Contract` attached to ``fn`` (following ``__wrapped__``
    and jit-wrapper chains), or ``None``."""
    for obj in (fn, getattr(fn, "__wrapped__", None),
                getattr(fn, "_fun", None)):
        if obj is not None and hasattr(obj, ATTR):
            return getattr(obj, ATTR)
    return None
