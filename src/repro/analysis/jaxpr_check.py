"""Jaxpr/runtime contract checkers for the serving stack (DESIGN.md §12).

One generic walker (:func:`iter_eqns` — recurses into every sub-jaxpr:
``pjit``, ``shard_map``, ``scan``/``while``/``cond`` branches, Pallas kernel
bodies) feeds several checkers, each mechanizing a bug class a previous PR
found by hand:

* :func:`check_integer_psum` — the Abelian exactness contract (DESIGN.md
  §9): every ``psum`` over a contracted mesh axis must reduce integers.
  An f32 partial psum reassociates per device count and the ulp wobble
  amplifies through activation requantization (~1e-4/step) — the PR 4
  token-divergence class;
* :func:`count_host_callbacks` — host round-trips compiled INTO the graph
  (``pure_callback``/``io_callback``/``debug_callback``): the fused serving
  steps contract to zero;
* :func:`dispatch_census` / :func:`check_budget` — primitive counts
  (MXU ``dot_general``, ``pallas_call``, collectives) checked against the
  committed ledger (``analysis_budgets.json``);
* :class:`TransferCensus` — runtime census of ``jax.device_get`` per
  dispatch round (the one-transfer serving contract; the PR 5
  drain-miscount class), with caller ``file:line`` attribution;
* :class:`DonationLedger` — runtime audit that a donated buffer is never
  passed again after the dispatch consumed it (the chaos double-apply
  class; CPU jax ignores donation, so the hazard is *silent* here and
  real on TPU);
* :func:`jit_cache_sizes` / :func:`check_no_retrace` — the retrace
  tripwire (the PR 3 temperature-retrace class): pinned jit-cache sizes
  across dynamic-operand changes.

The kernel-structure introspection that seeded this module
(``kernel_structure``/``gemm_dispatch_count``) lives here now;
``kernels/ops.py`` re-exports it for the existing tests/benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax

# primitive-name sets (jax 0.4.x: psum lowers to "psum2" inside shard_map,
# "psum" under pmap/older paths; keep both)
PSUM_PRIMS = ("psum", "psum2")
CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback")
COLLECTIVE_PRIMS = PSUM_PRIMS + ("all_gather", "reduce_scatter", "all_to_all",
                                 "ppermute")


class AnalysisViolation(AssertionError):
    """A checked contract failed.  Carries the individual findings."""

    def __init__(self, violations: Sequence["Violation"]):
        self.violations = list(violations)
        super().__init__(
            "\n".join(str(v) for v in self.violations) or "contract violated")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One pointed finding: which rule, where, and what was seen."""
    rule: str
    where: str          # "file:line" or a jaxpr path like "pjit/shard_map"
    message: str

    def __str__(self) -> str:
        return f"{self.where}: [{self.rule}] {self.message}"


def _raise_or_return(violations: List[Violation], strict: bool):
    if violations and strict:
        raise AnalysisViolation(violations)
    return violations


# ---------------------------------------------------------------------------
# generic jaxpr walking
# ---------------------------------------------------------------------------
def child_jaxprs(params: Dict[str, Any]) -> List[Any]:
    """Every sub-jaxpr reachable from one equation's params: ClosedJaxprs
    (``pjit``, scan/while/cond branches), raw Jaxprs (``shard_map``,
    ``pallas_call`` bodies), and lists/tuples of either."""
    out = []
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for vv in vs:
            inner = getattr(vv, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                out.append(inner)     # ClosedJaxpr -> unwrap to raw
            elif hasattr(vv, "eqns"):
                out.append(vv)        # raw Jaxpr (shard_map, pallas bodies)
    return out


def iter_eqns(jaxpr, path: str = "") -> Iterator[Tuple[Any, str]]:
    """Yield ``(eqn, path)`` for every equation, depth-first through all
    sub-jaxprs; ``path`` is the slash-joined chain of enclosing primitive
    names (e.g. ``"pjit/shard_map"``) for pointed diagnostics."""
    for e in jaxpr.eqns:
        yield e, path
        sub_path = f"{path}/{e.primitive.name}" if path else e.primitive.name
        for sub in child_jaxprs(e.params):
            yield from iter_eqns(sub, sub_path)


def trace(fn: Callable, *args, **kwargs):
    """``jax.make_jaxpr`` with kwargs folded in (tracing never executes
    device code, so this is cheap enough for CI)."""
    return jax.make_jaxpr(partial(fn, **kwargs))(*args)


def _eqn_site(eqn) -> str:
    """Best-effort ``file:line`` for an equation from its source_info."""
    try:
        from jax._src import source_info_util
        for fr in source_info_util.user_frames(eqn.source_info):
            return f"{fr.file_name}:{fr.start_line}"
    except Exception:
        pass
    try:  # fallback: first non-jax raw frame (raw frames carry .line_num)
        for fr in eqn.source_info.traceback.frames:
            fname = getattr(fr, "file_name", "")
            if fname and "site-packages" not in fname and "jax/_src" not in fname:
                return f"{fname}:{fr.line_num}"
    except Exception:
        pass
    return "<unknown>"


# ---------------------------------------------------------------------------
# integer-domain psum rule (DESIGN.md §9)
# ---------------------------------------------------------------------------
def check_integer_psum(fn: Callable, *args,
                       axes: Sequence[str] = ("expand",),
                       strict: bool = True, **kwargs) -> List[Violation]:
    """Every ``psum`` over any mesh axis in ``axes`` must reduce an integer
    (or bool) dtype — the Abelian group of Theorem 2 realized in Z, where
    the reduction is genuinely order-independent.  A float psum on the term
    axis is the PR 4 divergence class: its association depends on device
    count and the deviation amplifies through activation requantization.

    ``strict=True`` raises :class:`AnalysisViolation`; ``strict=False``
    returns the findings (the weight-only waiver path)."""
    jaxpr = trace(fn, *args, **kwargs)
    axes = set(axes)
    violations: List[Violation] = []
    for eqn, path in iter_eqns(jaxpr.jaxpr):
        if eqn.primitive.name not in PSUM_PRIMS:
            continue
        eqn_axes = set(a for a in eqn.params.get("axes", ())
                       if isinstance(a, str))
        if not (eqn_axes & axes):
            continue
        for v in eqn.invars:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and jax.numpy.issubdtype(dt, jax.numpy.floating):
                violations.append(Violation(
                    rule="integer-psum",
                    where=_eqn_site(eqn),
                    message=(f"{eqn.primitive.name} over mesh axis "
                             f"{sorted(eqn_axes & axes)} reduces {dt} (in "
                             f"{path or 'top level'}); the exactness "
                             f"contract requires an integer domain — psum "
                             f"int32 accumulators and scale replicated "
                             f"(DESIGN.md §9)")))
    return _raise_or_return(violations, strict)


# ---------------------------------------------------------------------------
# host-callback census (in-graph host round trips)
# ---------------------------------------------------------------------------
def count_host_callbacks(fn: Callable, *args, **kwargs) -> int:
    """Host callbacks compiled into the traced computation.  The fused
    serving steps contract to 0: an in-graph callback is a hidden host
    sync per dispatch (and cannot be partitioned on a mesh)."""
    jaxpr = trace(fn, *args, **kwargs)
    return sum(1 for e, _ in iter_eqns(jaxpr.jaxpr)
               if e.primitive.name in CALLBACK_PRIMS)


# ---------------------------------------------------------------------------
# dispatch census + budget check
# ---------------------------------------------------------------------------
def dispatch_census(fn: Callable, *args, **kwargs) -> Dict[str, int]:
    """Primitive counts of the traced computation (flattened through every
    sub-jaxpr): the quantities ``analysis_budgets.json`` budgets.

    Keys: ``dot_general`` (MXU dispatches), ``pallas_call`` (fused-kernel
    dispatches), ``psum``/``all_gather``/... (collectives), ``callbacks``,
    ``round`` (quantization rounds), ``scatter`` (cache writes)."""
    jaxpr = trace(fn, *args, **kwargs)
    census: Dict[str, int] = {
        "dot_general": 0, "pallas_call": 0, "callbacks": 0, "round": 0,
        "psum": 0, "all_gather": 0, "scatter": 0,
    }
    for e, _ in iter_eqns(jaxpr.jaxpr):
        name = e.primitive.name
        if name in PSUM_PRIMS:
            census["psum"] += 1
        elif name in CALLBACK_PRIMS:
            census["callbacks"] += 1
        elif name.startswith("scatter") or name == "dynamic_update_slice":
            census["scatter"] += 1
        elif name in census:
            census[name] += 1
    return census


def check_budget(measured: Dict[str, int], budget: Dict[str, int], *,
                 entry: str, strict: bool = True) -> List[Violation]:
    """Compare a census against a committed budget: keys in ``budget`` are
    ceilings (``<=``); a measured count above its ceiling is a violation.
    Growing a budget is a deliberate, reviewed edit to the JSON — never an
    accident."""
    violations = [
        Violation(
            rule="dispatch-budget",
            where=f"analysis_budgets.json:{entry}",
            message=(f"{key}: measured {measured.get(key, 0)} exceeds the "
                     f"budget {ceiling} — if intentional, bump the committed "
                     f"ledger in the same PR"))
        for key, ceiling in budget.items()
        if measured.get(key, 0) > ceiling
    ]
    return _raise_or_return(violations, strict)


# ---------------------------------------------------------------------------
# runtime host-transfer census (device_get per dispatch round)
# ---------------------------------------------------------------------------
class TransferCensus:
    """Counts host transfers (``jax.device_get``) between dispatch rounds.

    Usage::

        census = TransferCensus()
        eng._decode = census.wrap_dispatch(eng._decode)   # round boundary
        with census:
            eng.run(...)
        census.check(max_per_round=1)     # raises with file:line on breach

    Every ``jax.device_get`` inside the ``with`` is recorded with its
    caller's ``file:line``; ``wrap_dispatch`` marks round boundaries.  The
    serving contract (DESIGN.md §6): exactly ONE transfer per decode round
    — a second one is a hidden host sync that serializes the pipeline."""

    def __init__(self):
        self.events: List[Tuple[str, str]] = []   # ("transfer"|"round", site)
        self._orig = None

    # -- instrumentation -------------------------------------------------
    def __enter__(self):
        import inspect

        self._orig = jax.device_get

        def counted_device_get(x):
            site = "<unknown>"
            try:
                fr = inspect.stack()[1]
                site = f"{fr.filename}:{fr.lineno}"
            except Exception:
                pass
            self.events.append(("transfer", site))
            return self._orig(x)

        jax.device_get = counted_device_get
        return self

    def __exit__(self, *exc):
        jax.device_get = self._orig
        self._orig = None
        return False

    def wrap_dispatch(self, fn, label: str = "dispatch"):
        """Wrap a jitted dispatch callable so each call marks a round
        boundary (attribute-preserving: ``_cache_size`` etc. still reachable
        via ``__wrapped__``)."""
        import functools

        @functools.wraps(fn)
        def marked(*args, **kwargs):
            self.events.append(("round", label))
            return fn(*args, **kwargs)

        marked.__wrapped__ = fn
        return marked

    # -- results ---------------------------------------------------------
    def per_round(self) -> List[List[str]]:
        """Transfer sites grouped per dispatch round.  Transfers before the
        first round boundary (prefill/admission) land in group 0; each
        dispatch opens a new group."""
        groups: List[List[str]] = [[]]
        for kind, site in self.events:
            if kind == "round":
                groups.append([])
            else:
                groups[-1].append(site)
        return groups

    @property
    def transfers(self) -> int:
        return sum(1 for k, _ in self.events if k == "transfer")

    @property
    def rounds(self) -> int:
        return sum(1 for k, _ in self.events if k == "round")

    def check(self, max_per_round: int = 1, *, skip_first: bool = True,
              strict: bool = True) -> List[Violation]:
        """Assert no dispatch round saw more than ``max_per_round``
        transfers.  ``skip_first`` exempts the pre-first-dispatch group
        (admission/prefill transfers are not decode-round traffic)."""
        groups = self.per_round()
        start = 1 if skip_first else 0
        violations = [
            Violation(
                rule="transfer-census",
                where=", ".join(sorted(set(g))) or "<none>",
                message=(f"round {i}: {len(g)} host transfers "
                         f"(contract: <= {max_per_round} per decode round)"))
            for i, g in enumerate(groups[start:], start=start)
            if len(g) > max_per_round
        ]
        return _raise_or_return(violations, strict)


# ---------------------------------------------------------------------------
# donation ledger (double-apply audit)
# ---------------------------------------------------------------------------
class DonationLedger:
    """Runtime audit: a buffer passed in a donated position is consumed —
    passing it (or any alias of it) to a later audited call is the chaos
    double-apply class.  CPU jax *ignores* donation, so the reuse silently
    "works" here and corrupts state on TPU; this ledger makes the hazard a
    deterministic failure on any backend.

    Usage::

        ledger = DonationLedger()
        step = ledger.wrap(eng._decode, donate_argnums=(2,))
        out = step(params, tok, caches, ...)   # caches now spent
        step(params, tok, caches, ...)         # -> AnalysisViolation
    """

    def __init__(self):
        self._spent: Dict[int, str] = {}       # id(leaf) -> where donated
        self.violations: List[Violation] = []

    @staticmethod
    def _leaf_ids(tree) -> List[int]:
        return [id(l) for l in jax.tree_util.tree_leaves(tree)
                if hasattr(l, "dtype")]        # arrays only, skip python ints

    def wrap(self, fn, donate_argnums: Sequence[int], label: str = "dispatch"):
        import functools

        @functools.wraps(fn)
        def audited(*args, **kwargs):
            # 1) reuse check on EVERY array argument (donated or not): a
            #    spent buffer must never be read again, not just re-donated
            for pos, a in enumerate(args):
                for lid in self._leaf_ids(a):
                    if lid in self._spent:
                        v = Violation(
                            rule="donation-reuse",
                            where=self._spent[lid],
                            message=(f"{label}: argument {pos} contains a "
                                     f"buffer already donated there — "
                                     f"double-apply (donation is a no-op on "
                                     f"CPU but frees the buffer on TPU)"))
                        self.violations.append(v)
                        raise AnalysisViolation([v])
            out = fn(*args, **kwargs)
            # 2) mark donated inputs spent AFTER a successful dispatch (a
            #    failed dispatch never consumed them — the chaos-retry rule)
            import inspect
            site = "<unknown>"
            try:
                fr = inspect.stack()[1]
                site = f"{fr.filename}:{fr.lineno}"
            except Exception:
                pass
            for pos in donate_argnums:
                if pos < len(args):
                    for lid in self._leaf_ids(args[pos]):
                        self._spent[lid] = f"{site} (arg {pos})"
            return out

        audited.__wrapped__ = fn
        return audited


# ---------------------------------------------------------------------------
# retrace tripwire
# ---------------------------------------------------------------------------
def jit_cache_sizes(callables: Dict[str, Any]) -> Dict[str, int]:
    """``name -> _cache_size()`` for a dict of jitted callables (unwraps
    census/ledger wrappers); entries without a cache report -1."""
    out = {}
    for name, fn in callables.items():
        # walk the wrapper chain until something exposes a jit cache (a
        # jitted fn ALSO has __wrapped__ = the raw python fn, so test for
        # the cache before unwrapping further)
        size = None
        seen = 0
        while fn is not None and seen < 8:
            size = getattr(fn, "_cache_size", None)
            if callable(size):
                break
            fn = getattr(fn, "__wrapped__", None)
            seen += 1
        out[name] = int(size()) if callable(size) else -1
    return out


def check_no_retrace(callables: Dict[str, Any], *, max_traces: int = 1,
                     strict: bool = True) -> List[Violation]:
    """Every jitted callable must hold at most ``max_traces`` cached traces
    — more means a dynamic operand retraced it (the PR 3 temperature class:
    an operand marked static retraces per distinct value)."""
    violations = [
        Violation(
            rule="retrace",
            where=name,
            message=(f"jit cache holds {size} traces (contract: <= "
                     f"{max_traces}) — a dynamic operand is being treated "
                     f"as static, or shapes vary per call"))
        for name, size in jit_cache_sizes(callables).items()
        if size > max_traces
    ]
    return _raise_or_return(violations, strict)


# ---------------------------------------------------------------------------
# dense-score materialization tripwire (paged attention, DESIGN.md §13)
# ---------------------------------------------------------------------------
def check_no_dense_scores(fn: Callable, *args, batch: int,
                          seq_sizes: Sequence[int],
                          strict: bool = True, **kwargs) -> List[Violation]:
    """No float intermediate of the traced computation may carry BOTH a
    ``batch``-sized axis and an axis whose size is in ``seq_sizes`` (the
    dense cache capacity ``max_seq`` and any padded variants, e.g.
    ``ceil(max_seq/page) * page``).

    This is the paged-attention memory contract: the whole point of paging
    is that per-step attention streams KV page-by-page, so a materialized
    ``(B, ..., max_seq)`` score/probability tensor — or a dense per-slot KV
    row — reappearing in the paged dispatch silently reverts the HBM win.
    The DENSE reference path trips this check by construction (its scores
    and cache rows are exactly that shape), which is the calibration that
    the tripwire can see the bug class at all.

    Choose fixture dims collision-free: ``batch`` and every entry of
    ``seq_sizes`` must differ from vocab/hidden/head dims, or unrelated
    tensors (logits, embeddings) false-positive."""
    jaxpr = trace(fn, *args, **kwargs)
    sizes = set(int(s) for s in seq_sizes)
    violations: List[Violation] = []
    seen = set()
    for eqn, path in iter_eqns(jaxpr.jaxpr):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            shape = tuple(getattr(aval, "shape", ()) or ())
            dt = getattr(aval, "dtype", None)
            if dt is None or not jax.numpy.issubdtype(dt, jax.numpy.floating):
                continue
            if batch not in shape:
                continue
            # the seq-sized axis must be a DIFFERENT axis than the one
            # matched as batch (batch == a seq size would self-match)
            rest = list(shape)
            rest.remove(batch)
            if not any(d in sizes for d in rest):
                continue
            key = (shape, str(dt), _eqn_site(eqn))
            if key in seen:
                continue
            seen.add(key)
            violations.append(Violation(
                rule="no-dense-scores",
                where=_eqn_site(eqn),
                message=(f"float intermediate {dt}{list(shape)} carries both "
                         f"the batch axis ({batch}) and a dense sequence "
                         f"axis ({sorted(sizes & set(rest))}) in "
                         f"{path or 'top level'} — paged attention must "
                         f"stream KV per page, never materialize per-slot "
                         f"(B, max_seq) score/cache tensors (DESIGN.md §13)")))
    return _raise_or_return(violations, strict)


# ---------------------------------------------------------------------------
# Pallas kernel-structure introspection (moved from kernels/ops.py; the
# public names remain re-exported there)
# ---------------------------------------------------------------------------
def _count_prim(jaxpr, name: str) -> int:
    total = 0
    for e in jaxpr.eqns:
        if e.primitive.name == name:
            total += 1
        for sub in child_jaxprs(e.params):
            total += _count_prim(sub, name)
    return total


def _is_var(v) -> bool:
    return not hasattr(v, "val")          # jaxpr Literals carry .val


def _count_ref_reads(jaxpr, tainted) -> int:
    """Reads (``get``) of any ref in ``tainted``, following refs positionally
    through cond branches and nested calls."""
    total = 0
    for e in jaxpr.eqns:
        if e.primitive.name == "get" and e.invars and _is_var(e.invars[0]) \
                and e.invars[0] in tainted:
            total += 1
        if e.primitive.name == "cond":
            ops = e.invars[1:]
            for br in e.params["branches"]:
                sub = br.jaxpr if hasattr(br, "jaxpr") else br
                sub_taint = {bv for bv, ov in zip(sub.invars, ops)
                             if _is_var(ov) and ov in tainted}
                total += _count_ref_reads(sub, sub_taint)
        elif e.primitive.name in ("closed_call", "pjit", "core_call"):
            for sub in child_jaxprs(e.params):
                sub_taint = {bv for bv, ov in zip(sub.invars, e.invars)
                             if _is_var(ov) and ov in tainted}
                total += _count_ref_reads(sub, sub_taint)
    return total


def kernel_structure(fn, *args, **kwargs) -> List[Dict[str, int]]:
    """Trace ``fn(*args, **kwargs)`` and report, per Pallas kernel dispatched:

    * ``dot_dispatches``      — MXU ``dot_general`` issues per grid block
      (the acceptance metric: the series kernel must issue <= ta);
    * ``out_ref_reads``       — reads of the HBM output ref inside the
      kernel body (0 == no read-modify-write accumulation);
    * ``quantize_rounds``     — total ``round`` ops in the body;
    * ``unguarded_rounds``    — ``round`` ops at the kernel's top level,
      i.e. NOT inside a ``pl.when`` guard (0 == quantize-once is guarded).
    """
    jaxpr = jax.make_jaxpr(partial(fn, **kwargs))(*args)
    stats: List[Dict[str, int]] = []

    def visit(jx):
        for e in jx.eqns:
            if e.primitive.name == "pallas_call":
                inner = e.params["jaxpr"]
                gm = e.params["grid_mapping"]
                lo = gm.num_index_operands + gm.num_inputs
                out_refs = set(inner.invars[lo:lo + gm.num_outputs])
                top_rounds = sum(1 for q in inner.eqns if q.primitive.name == "round")
                stats.append({
                    "dot_dispatches": _count_prim(inner, "dot_general"),
                    "out_ref_reads": _count_ref_reads(inner, out_refs),
                    "quantize_rounds": _count_prim(inner, "round"),
                    "unguarded_rounds": top_rounds,
                })
            for sub in child_jaxprs(e.params):
                visit(sub)

    visit(jaxpr.jaxpr)
    return stats


def gemm_dispatch_count(fn, *args, **kwargs) -> int:
    """Total MXU dot dispatches per grid block across all Pallas kernels
    dispatched by ``fn`` (0 when no kernel is dispatched)."""
    return sum(s["dot_dispatches"] for s in kernel_structure(fn, *args, **kwargs))
