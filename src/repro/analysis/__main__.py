"""CLI for the static-analysis subsystem (the CI ``analysis`` job driver).

    python -m repro.analysis lint [PATH ...]      # AST lint (REPRO1xx)
    python -m repro.analysis budgets [--update]   # dispatch-budget ledger
    python -m repro.analysis contracts            # dump declared contracts
    python -m repro.analysis report [-o FILE]     # everything, as JSON

Exit status is nonzero when any check finds a violation, so each
subcommand is CI-gating as-is.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: default lint roots, repo-relative (resolved against this file so the CLI
#: works from any cwd)
_SRC_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
DEFAULT_LINT_PATHS = (os.path.join(_SRC_ROOT, "repro"),)


def _cmd_lint(args) -> int:
    from repro.analysis.lint import run_lint

    paths = args.paths or list(DEFAULT_LINT_PATHS)
    errors = run_lint(paths)
    for e in errors:
        print(e)
    print(f"lint: {len(errors)} finding(s) in {', '.join(paths)}")
    return 1 if errors else 0


def _cmd_budgets(args) -> int:
    from repro.analysis import budgets as B

    if args.update:
        data = B.write_budgets()
        print(f"wrote {B.LEDGER_PATH} "
              f"({len([k for k in data if not k.startswith('_')])} entries)")
        return 0
    violations = B.check_budgets(strict=False)
    for v in violations:
        print(v)
    print(f"budgets: {len(violations)} violation(s) vs {B.LEDGER_PATH}")
    return 1 if violations else 0


def _contract_table():
    """name -> contract dict for every annotated entry point (imports the
    serving stack, so jax loads here — not at CLI startup)."""
    from repro.analysis.contracts import get_contract
    from repro.configs.base import get_arch
    from repro.infer import qos as Q
    from repro.infer import serve as S
    from repro.models.layers import FP

    cfg = get_arch("qwen2_1_5b", smoke=True)
    carriers = [
        S.make_decode_sample_step(cfg, FP, masked=False),
        S.make_decode_sample_step(cfg, FP, masked=True),
        S.make_spec_decode_step(cfg, FP, FP, 2),
        Q.ChaosInjector.before_dispatch,
    ]
    try:
        # the prefill contract lives on an Engine's jitted slot-prefill
        # (jit construction never traces, so this is cheap)
        import jax
        from repro.models import model as M
        eng = S.Engine(cfg, M.init_params(jax.random.PRNGKey(0), cfg))
        carriers.append(eng._prefill_slot)
    except Exception:
        pass
    try:
        from repro.dist import expansion_parallel as EP
        carriers.append(EP.term_parallel_apply)
    except Exception:
        pass
    out = {}
    for fn in carriers:
        c = get_contract(fn)
        if c is not None:
            out[c.name] = c.to_json()
    return out


def _cmd_contracts(args) -> int:
    table = _contract_table()
    print(json.dumps(table, indent=2, sort_keys=True))
    return 0


def _cmd_report(args) -> int:
    """Full checker report: lint + budgets + contracts, one JSON document
    (the CI artifact)."""
    from repro.analysis import budgets as B
    from repro.analysis.lint import run_lint

    lint_errors = run_lint(list(DEFAULT_LINT_PATHS))
    budget_violations = B.check_budgets(strict=False)
    report = {
        "lint": [str(e) for e in lint_errors],
        "budgets": {
            "ledger": B.LEDGER_PATH,
            "measured": B.measure_budgets(),
            "violations": [str(v) for v in budget_violations],
        },
        "contracts": _contract_table(),
        "ok": not lint_errors and not budget_violations,
    }
    text = json.dumps(report, indent=2, sort_keys=True)
    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")
        print(f"wrote {args.output} (ok={report['ok']})")
    else:
        print(text)
    return 0 if report["ok"] else 1


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.analysis",
                                description=__doc__.splitlines()[0])
    sub = p.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("lint", help="AST lint (REPRO1xx rules)")
    sp.add_argument("paths", nargs="*", help="files/dirs (default: src/repro)")
    sp.set_defaults(fn=_cmd_lint)

    sp = sub.add_parser("budgets", help="check the dispatch-budget ledger")
    sp.add_argument("--update", action="store_true",
                    help="re-measure and rewrite analysis_budgets.json")
    sp.set_defaults(fn=_cmd_budgets)

    sp = sub.add_parser("contracts", help="dump declared entry-point contracts")
    sp.set_defaults(fn=_cmd_contracts)

    sp = sub.add_parser("report", help="full JSON report (CI artifact)")
    sp.add_argument("-o", "--output", default="", help="write JSON here")
    sp.set_defaults(fn=_cmd_report)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
