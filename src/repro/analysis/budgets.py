"""Dispatch-budget ledger: committed primitive-count ceilings per entry point.

``analysis_budgets.json`` (next to this file) pins, for each serving entry
point, a ceiling on the primitive counts of its traced computation
(:func:`repro.analysis.jaxpr_check.dispatch_census`): MXU ``dot_general``
dispatches, Pallas kernel calls, host callbacks, quantization ``round``
ops, collectives, cache scatters.  ``tests/test_analysis.py`` and the CI
``analysis`` job assert measured <= budget on the smoke model, so a change
that silently doubles dispatches (a fori_loop unrolled, a fusion broken, a
debug callback left in) fails review-visibly: growing a budget is a
deliberate edit to the committed JSON in the same PR.

Entries (keyed by the ``Contract.budget_key`` of the annotated entry point,
all measured on the ``qwen2_1_5b`` smoke arch, W8A8, reference path):

* ``decode``        — the fused decode+sample+EOS step (unmasked);
* ``decode_masked`` — the QoS row-masked variant (tier dispatch unit);
* ``spec_decode``   — the fused draft-gamma + verify speculative round;
* ``prefill``       — padded prefill-into-slot;
* ``decode_paged``  — the paged (block-table) masked decode step;
* ``spec_decode_paged`` — the paged speculative round;
* ``prefill_chunk`` / ``prefill_chunk_paged`` — the chunk-fused
  decode+prefill round (DESIGN.md §14);
* ``spec_decode_masked`` / ``spec_decode_paged_masked`` — the row-masked
  speculative rounds chunked engines dispatch;
* ``decode_moe`` — the masked decode step on the ``moe_attn`` smoke arch
  (``grok_1_314b``, stats rider on): its ``dot_general`` ceiling pins the
  grouped series-GEMM dispatch count at O(terms) per MoE layer — a regression
  to per-expert loops (O(E·terms) dispatches) blows the budget (DESIGN.md
  §15).

Heavy imports (jax, the model zoo) happen inside functions only: importing
this module costs nothing, so ``python -m repro.analysis`` can lint without
tracing models.  Refresh the ledger with
``python -m repro.analysis budgets --update`` after an intentional change.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

LEDGER_PATH = os.path.join(os.path.dirname(__file__), "analysis_budgets.json")

#: census keys that are budgeted (ceilings); keys a census reports but the
#: ledger omits are unconstrained
BUDGETED_KEYS = ("dot_general", "pallas_call", "callbacks", "round",
                 "psum", "all_gather", "scatter")

#: the fixture every entry is measured on (committed alongside the numbers
#: so a ledger mismatch is attributable)
FIXTURE = {"arch": "qwen2_1_5b", "smoke": True, "policy": "W8A8",
           "max_seq": 32, "batch": 2, "spec_lookahead": 2, "page_size": 8,
           "moe_arch": "grok_1_314b"}


def load_budgets(path: str = LEDGER_PATH) -> Dict[str, Dict[str, int]]:
    """The committed ledger: ``{entry: {census_key: ceiling}}`` (the
    ``_fixture`` metadata entry is stripped)."""
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    return {k: v for k, v in data.items() if not k.startswith("_")}


def _fixture_steps():
    """Build the four traced entry points + their inputs on the smoke model.

    Returns ``{entry: (fn, args)}`` ready for ``dispatch_census(fn, *args)``.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_arch
    from repro.core import ptq as PTQ
    from repro.core.policy import W8A8
    from repro.infer import serve as S
    from repro.models import model as M
    from repro.models.layers import QuantContext

    fx = FIXTURE
    cfg = get_arch(fx["arch"], smoke=fx["smoke"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qc = QuantContext(policy=W8A8)
    params_q = PTQ.expand_params(params, W8A8)

    b, s_max = fx["batch"], fx["max_seq"]
    prompt = jnp.ones((b, 8), jnp.int32)
    lengths = jnp.full((b,), 8, jnp.int32)
    _, caches = M.prefill(params_q, {"tokens": prompt}, cfg, qc, s_max=s_max)

    tok = jnp.ones((b, 1), jnp.int32)
    cache_len = jnp.full((b,), 8, jnp.int32)
    key = jax.random.PRNGKey(1)
    alive = jnp.ones((b,), bool)
    eos = jnp.asarray(-1, jnp.int32)
    temp = jnp.asarray(0.0, jnp.float32)
    row_mask = jnp.ones((b,), bool)

    import dataclasses
    decode = S.make_decode_sample_step(cfg, qc, masked=False)
    masked = S.make_decode_sample_step(cfg, qc, masked=True)
    qc_draft = dataclasses.replace(qc, term_budget=1)
    spec = S.make_spec_decode_step(cfg, qc, qc_draft, fx["spec_lookahead"])

    def prefill_slot(p, batch, ln):
        return M.prefill(p, batch, cfg, qc, s_max=s_max, lengths=ln)

    # paged layout: sequential per-slot block tables over a dense-equivalent
    # pool (census budgets shape-level structure, not values)
    page = fx["page_size"]
    mp = -(-s_max // page)
    pcaches = M.init_paged_cache(cfg, b, s_max, page_size=page,
                                 num_pages=b * mp)
    bt = jnp.arange(b * mp, dtype=jnp.int32).reshape(b, mp)
    paged = S.make_paged_decode_step(cfg, qc, page, masked=True)
    spec_paged = S.make_paged_spec_decode_step(cfg, qc, qc_draft,
                                               fx["spec_lookahead"], page)

    # chunked-prefill round (C=4 chunk width, all rows committing/seeding —
    # the shape-level superset of fused and standalone chunk rounds) and the
    # row-masked speculative variants chunked engines use
    C = 4
    chunk_tokens = jnp.ones((b, C), jnp.int32)
    valid = jnp.full((b,), C, jnp.int32)
    wf = jnp.zeros((b,), jnp.int32)
    commit = jnp.ones((b,), bool)
    dec = jnp.zeros((b,), bool)
    seed = jnp.ones((b,), bool)
    chunk = S.make_prefill_chunk_step(cfg, qc, paged=False,
                                      s_max=fx["max_seq"])
    chunk_paged = S.make_prefill_chunk_step(cfg, qc, paged=True,
                                            page_size=page,
                                            s_max=fx["max_seq"])
    spec_masked = S.make_spec_decode_step(cfg, qc, qc_draft,
                                          fx["spec_lookahead"], masked=True)
    spec_paged_masked = S.make_paged_spec_decode_step(
        cfg, qc, qc_draft, fx["spec_lookahead"], page, masked=True)

    # MoE serving entry (DESIGN.md §15): the masked decode step on the
    # moe_attn smoke arch, serving-contract routing ("token") with the
    # expert-load stats rider on.  Its dot_general ceiling is what pins the
    # grouped series GEMM at O(terms) dispatches per MoE layer.
    import repro.configs.grok_1_314b  # noqa: F401 (registers the arch)
    mcfg = get_arch(fx["moe_arch"], smoke=True)
    mqc = dataclasses.replace(qc, moe_routing="token")
    mparams = PTQ.expand_params(M.init_params(jax.random.PRNGKey(2), mcfg),
                                W8A8)
    _, mcaches = M.prefill(mparams, {"tokens": prompt}, mcfg, mqc,
                           s_max=s_max)
    moe_step = S.make_decode_sample_step(mcfg, mqc, masked=True,
                                         moe_stats=True)

    return {
        "decode": (decode, (params_q, tok, caches, cache_len, key, alive,
                            eos, temp)),
        "decode_masked": (masked, (params_q, tok, caches, cache_len, key,
                                   alive, eos, temp, row_mask)),
        "spec_decode": (spec, (params_q, tok, caches, cache_len)),
        "prefill": (prefill_slot, (params_q, {"tokens": prompt}, lengths)),
        "decode_paged": (paged, (params_q, tok, pcaches, cache_len, bt, key,
                                 alive, eos, temp, row_mask)),
        "spec_decode_paged": (spec_paged, (params_q, tok, pcaches, cache_len,
                                           bt)),
        "prefill_chunk": (chunk, (params_q, chunk_tokens, caches, cache_len,
                                  key, alive, eos, temp, valid, wf, commit,
                                  dec, seed, tok)),
        "prefill_chunk_paged": (chunk_paged, (params_q, chunk_tokens,
                                              pcaches, cache_len, bt, key,
                                              alive, eos, temp, valid, wf,
                                              commit, dec, seed, tok)),
        "spec_decode_masked": (spec_masked, (params_q, tok, caches,
                                             cache_len, row_mask)),
        "spec_decode_paged_masked": (spec_paged_masked,
                                     (params_q, tok, pcaches, cache_len, bt,
                                      row_mask)),
        "decode_moe": (moe_step, (mparams, tok, mcaches, cache_len, key,
                                  alive, eos, temp, row_mask)),
    }


def measure_budgets() -> Dict[str, Dict[str, int]]:
    """Trace every entry point on the committed fixture and return its
    census restricted to :data:`BUDGETED_KEYS` (tracing only — no device
    execution, runs in seconds on CPU)."""
    from repro.analysis.jaxpr_check import dispatch_census

    out: Dict[str, Dict[str, int]] = {}
    for entry, (fn, args) in _fixture_steps().items():
        census = dispatch_census(fn, *args)
        out[entry] = {k: int(census.get(k, 0)) for k in BUDGETED_KEYS}
    return out


def write_budgets(path: str = LEDGER_PATH) -> Dict[str, Dict[str, int]]:
    """Re-measure and commit the ledger (``--update``)."""
    data: Dict[str, Any] = {"_fixture": dict(FIXTURE)}
    data.update(measure_budgets())
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return data


def check_budgets(path: str = LEDGER_PATH, *, strict: bool = True):
    """Measure the fixture and assert every entry stays within its
    committed ceiling.  Returns the violation list (empty == within
    budget); ``strict=True`` raises
    :class:`repro.analysis.jaxpr_check.AnalysisViolation`."""
    from repro.analysis.jaxpr_check import check_budget

    ledger = load_budgets(path)
    measured = measure_budgets()
    violations = []
    for entry, budget in sorted(ledger.items()):
        if entry not in measured:
            continue
        violations.extend(check_budget(measured[entry], budget,
                                       entry=entry, strict=False))
    if violations and strict:
        from repro.analysis.jaxpr_check import AnalysisViolation
        raise AnalysisViolation(violations)
    return violations
