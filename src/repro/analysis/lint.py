"""Repo-specific AST lint (``python -m repro.analysis lint``).

Four rules, each mechanizing a bug class a previous PR found by hand:

* **REPRO101 — bare assert on a runtime path.**  ``assert`` statements are
  compiled out under ``python -O``; on the serving runtime paths
  (``infer/``, ``models/``, ``api/``) a violated precondition must raise a
  typed exception (``ValueError`` / ``SchedulerError``) that survives
  optimization and that callers can catch.  Test files and kernel-launch
  shape checks (``kernels/``, static at trace time) are exempt.

* **REPRO102 — dynamic operand marked static.**  Operand names that vary
  per request (``temperature``, ``eos_id``, ``row_mask``, ...) must never
  appear in a ``static_argnames``/``static_argnums``-annotated jit: each
  distinct value retraces and recompiles (the PR 3 temperature-retrace
  class, one XLA compile per sampled temperature).

* **REPRO103 — duplicated numeric-constant table.**  ``repro/numerics.py``
  is the single source of the series grid constants
  (``plane_limits``/``scale_ratio``); a re-definition elsewhere WILL drift
  (the PR 5 clamp-table skew: four copies, one updated).  Also flags any
  pair of identically-named module-level functions with identical bodies
  in different non-test modules.

* **REPRO104 — jit construction inside a loop.**  ``jax.jit(f)`` inside a
  ``for``/``while`` body creates a fresh cache per iteration — every call
  retraces; hoist the jit out of the loop.

``run_lint(paths)`` returns :class:`LintError` findings formatted as
``path:line:col: REPROxxx message`` — pointed enough to click through.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: directories (repo-relative, under src/repro/) whose asserts are runtime
RUNTIME_DIRS = ("infer", "models", "api")

#: operand names that vary per request/step — never static (REPRO102)
DYNAMIC_OPERANDS = frozenset({
    "temperature", "eos_id", "row_mask", "mask", "cache_len", "alive",
    "key", "tok", "tokens", "logits", "top_p", "top_k",
})

#: the single-source grid-constant names (REPRO103); defined ONLY in
#: repro/numerics.py
NUMERIC_TABLE_NAMES = frozenset({
    "plane_limits", "_plane_limits", "scale_ratio", "_scale_ratio",
})
NUMERICS_MODULE = os.path.join("repro", "numerics.py")


@dataclasses.dataclass(frozen=True)
class LintError:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _is_test_file(path: str) -> bool:
    base = os.path.basename(path)
    return base.startswith("test_") or base.startswith("conftest") \
        or f"{os.sep}tests{os.sep}" in path


def _is_runtime_path(path: str) -> bool:
    norm = path.replace(os.sep, "/")
    return any(f"/repro/{d}/" in norm or norm.endswith(f"/repro/{d}.py")
               for d in RUNTIME_DIRS)


# ---------------------------------------------------------------------------
# per-file visitors
# ---------------------------------------------------------------------------
class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.errors: List[LintError] = []
        self._loop_depth = 0
        self._runtime = _is_runtime_path(path)

    def _err(self, node: ast.AST, rule: str, message: str):
        self.errors.append(LintError(
            self.path, getattr(node, "lineno", 0), getattr(node, "col_offset", 0),
            rule, message))

    # -- REPRO101: bare assert on runtime paths --------------------------
    def visit_Assert(self, node: ast.Assert):
        if self._runtime:
            self._err(node, "REPRO101",
                      "bare assert on a runtime path (compiled out under "
                      "python -O); raise ValueError/SchedulerError instead")
        self.generic_visit(node)

    # -- REPRO102: dynamic operands in static_argnames -------------------
    def visit_Call(self, node: ast.Call):
        fname = self._call_name(node)
        if fname in ("jit", "jax.jit", "functools.partial", "partial") or \
                fname.endswith(".jit"):
            for kw in node.keywords:
                if kw.arg == "static_argnames":
                    for name in self._str_elts(kw.value):
                        if name in DYNAMIC_OPERANDS:
                            self._err(
                                kw.value, "REPRO102",
                                f"dynamic operand {name!r} marked static — "
                                f"every distinct value retraces/recompiles "
                                f"(the temperature-retrace class); pass it "
                                f"as a traced operand")
        # REPRO104: jit constructed inside a loop body
        if self._loop_depth > 0 and \
                (fname in ("jax.jit", "jit") or fname.endswith(".jit")):
            self._err(node, "REPRO104",
                      "jax.jit(...) constructed inside a loop — a fresh "
                      "cache per iteration means every call retraces; "
                      "hoist the jit out of the loop")
        self.generic_visit(node)

    # -- REPRO104: loop tracking -----------------------------------------
    def visit_For(self, node: ast.For):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_While(self, node: ast.While):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    # -- REPRO103 half 1: grid-constant names defined outside numerics ---
    def visit_FunctionDef(self, node: ast.FunctionDef):
        if node.name in NUMERIC_TABLE_NAMES and \
                not self.path.replace(os.sep, "/").endswith("repro/numerics.py"):
            self._err(node, "REPRO103",
                      f"{node.name!r} re-defined outside repro/numerics.py — "
                      f"the series grid-constant table is single-source "
                      f"(duplicates drift: the PR 5 clamp-table skew)")
        self.generic_visit(node)

    # -- helpers ----------------------------------------------------------
    @staticmethod
    def _call_name(node: ast.Call) -> str:
        try:
            return ast.unparse(node.func)
        except Exception:
            return ""

    @staticmethod
    def _str_elts(node: ast.AST) -> List[str]:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return [node.value]
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return [e.value for e in node.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)]
        return []


def _module_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body if isinstance(n, ast.FunctionDef)}


def _body_fingerprint(fn: ast.FunctionDef) -> str:
    """Structural fingerprint of a function body (docstring stripped, source
    locations ignored) — identical fingerprints in two modules mean a
    copy-pasted table."""
    body = fn.body
    if body and isinstance(body[0], ast.Expr) and \
            isinstance(body[0].value, ast.Constant) and \
            isinstance(body[0].value.value, str):
        body = body[1:]
    return ast.dump(ast.Module(body=body, type_ignores=[]),
                    include_attributes=False)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = [d for d in dirs
                           if d not in ("__pycache__", ".git", ".venv")]
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def lint_file(path: str, source: Optional[str] = None) -> List[LintError]:
    """Lint one file; returns findings (empty == clean)."""
    if source is None:
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [LintError(path, e.lineno or 0, e.offset or 0, "REPRO100",
                          f"syntax error: {e.msg}")]
    if _is_test_file(path):
        return []
    v = _Visitor(path)
    v.visit(tree)
    return v.errors


def run_lint(paths: Sequence[str]) -> List[LintError]:
    """Lint every ``.py`` under ``paths``.  Includes the cross-file half of
    REPRO103: identically-named module-level functions with structurally
    identical bodies in two different modules."""
    errors: List[LintError] = []
    # (name, fingerprint) -> first definition site
    seen: Dict[Tuple[str, str], Tuple[str, int]] = {}
    for path in iter_py_files(paths):
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
        errors.extend(lint_file(path, source))
        if _is_test_file(path):
            continue
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError:
            continue
        for name, fn in _module_functions(tree).items():
            if len(fn.body) < 2 and name not in NUMERIC_TABLE_NAMES:
                continue                      # one-liners collide by chance
            key = (name, _body_fingerprint(fn))
            prev = seen.get(key)
            if prev is not None and prev[0] != path:
                errors.append(LintError(
                    path, fn.lineno, fn.col_offset, "REPRO103",
                    f"function {name!r} duplicates {prev[0]}:{prev[1]} "
                    f"(identical body) — extract one shared definition; "
                    f"duplicated tables drift"))
            else:
                seen.setdefault(key, (path, fn.lineno))
    return errors
