"""Pure-jnp oracles for the Pallas kernels.

Two kernel contracts (see DESIGN.md §3):

1. ``residual_quantize``: one pass over a 2-D f32 tensor producing ``terms``
   INT-X planes (int8 container) under the dyadic scale schedule
   ``s_k = scale1 / 2^{X k}`` with sequential (error-feedback) extraction.

2. ``series_matmul``: the fused layer-expansion GEMM
   ``out = sum_{i<ta, j<tw} sa_i * sw_j * (A_i @ W_j)``
   where ``A_i`` are the residual planes of the (pre-centered, pre-clipped)
   activation ``x`` and ``W_j`` are the weight planes.  INT8xINT8->INT32 dot,
   f32 scale-accumulate.  Asymmetric/saturation affine corrections are
   *outside* this contract (added by ``core/linear.py`` identically for both
   the oracle and the kernel path).

These oracles are the semantics; the Pallas kernels must match them exactly
(same rounding, same clamps) — asserted by ``tests/test_kernels.py`` sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# the shared grid-constant table (repro/numerics.py is dependency-free, so
# kernels stay import-cycle-free); lint rule REPRO103 locks re-definitions
from repro.numerics import plane_limits as _plane_limits
from repro.numerics import scale_ratio as _scale_ratio


def residual_quantize_ref(x: jnp.ndarray, scale1: jnp.ndarray, bits: int, terms: int) -> jnp.ndarray:
    """Sequential residual quantization, per-tensor scalar ``scale1``.

    Returns int8 planes of shape (terms, *x.shape)."""
    r = x.astype(jnp.float32)
    planes = []
    for k in range(terms):
        s = scale1 / float(_scale_ratio(bits) ** k)
        lo, hi = _plane_limits(bits, k)
        q = jnp.clip(jnp.round(r / s), lo, hi)
        r = r - s * q
        planes.append(q.astype(jnp.int8))
    return jnp.stack(planes, axis=0)


def series_matmul_ref(
    x: jnp.ndarray,            # (M, K) f32 — already centered & clipped
    a_scale1: jnp.ndarray,     # () f32
    w_planes: jnp.ndarray,     # (tw, K, N) int8
    w_scales: jnp.ndarray,     # (tw,) or (tw, N) f32
    *,
    a_bits: int,
    a_terms: int,
) -> jnp.ndarray:
    """out = sum_{i,j} sa_i * sw_j * (A_i @ W_j), f32 (M, N)."""
    m, k = x.shape
    tw, k2, n = w_planes.shape
    assert k == k2, (x.shape, w_planes.shape)
    a_planes = residual_quantize_ref(x, a_scale1, a_bits, a_terms)  # (ta, M, K)
    out = jnp.zeros((m, n), jnp.float32)
    for i in range(a_terms):
        sa_i = a_scale1 / float(_scale_ratio(a_bits) ** i)
        for j in range(tw):
            acc = jax.lax.dot_general(
                a_planes[i], w_planes[j],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            sw_j = w_scales[j]  # () or (N,) — broadcasts over rows
            out = out + (sa_i * sw_j) * acc.astype(jnp.float32)
    return out


def dequant_matmul_ref(
    x: jnp.ndarray,            # (M, K) f32 or bf16
    w_planes: jnp.ndarray,     # (tw, K, N) int8
    w_scales: jnp.ndarray,     # (tw,) or (tw, N) f32
) -> jnp.ndarray:
    """Weight-only path (W4A16): out = x @ (sum_j sw_j * W_j).  f32 (M, N)."""
    tw, k, n = w_planes.shape
    w = jnp.zeros((k, n), jnp.float32)
    for j in range(tw):
        w = w + w_scales[j] * w_planes[j].astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w)
