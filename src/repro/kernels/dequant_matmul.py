"""Pallas TPU kernel: packed-INT4 weight-only dequant GEMM (W4A16 serving).

The Table-6 deployment mode: activations stay bf16/f32, weights are the
packed INT4 series.  The kernel streams *packed* planes from HBM (0.5
byte/value/term — 4x less weight traffic than bf16), unpacks in VMEM with
the shift sign-extension idiom, folds the per-channel scales, and runs the
GEMM at the activation dtype.  This is the kernel the §Perf C3 iteration
projects onto real TPUs.

out = x @ (sum_j sw_j * unpack(W_packed_j))

Grid: (M/bm, N/bn, K/bk) with K innermost for accumulation; the packed
block is (tw, bk, bn//2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_int4_block(packed: jnp.ndarray) -> jnp.ndarray:
    """(bk, bn//2) int8 -> (bk, bn) int8, sign-extended nibbles."""
    p = packed.astype(jnp.int32)
    lo = (p << 28) >> 28
    hi = (p << 24) >> 28
    bk, half = packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(bk, half * 2).astype(jnp.int8)


def _kernel(x_ref, wp_ref, ws_ref, o_ref, *, tw: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)               # (bm, bk)
    acc = jnp.zeros_like(o_ref)
    for j in range(tw):                              # unpack + scale in VMEM
        w_j = _unpack_int4_block(wp_ref[j]).astype(jnp.float32)   # (bk, bn)
        w_j = w_j * ws_ref[j][None, :]               # per-channel scale fold
        acc = acc + jnp.dot(x, w_j, preferred_element_type=jnp.float32)
    o_ref[...] += acc


def dequant_matmul_pallas(
    x: jnp.ndarray,           # (M, K) f32/bf16
    w_packed: jnp.ndarray,    # (tw, K, N//2) int8 — packed INT4 planes
    w_scales: jnp.ndarray,    # (tw, N) f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    m, k = x.shape
    tw, k2, n_half = w_packed.shape
    n = n_half * 2
    assert k == k2 and w_scales.shape == (tw, n)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, tw=tw),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tw, block_k, block_n // 2), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((tw, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x.astype(jnp.float32), w_packed, w_scales.astype(jnp.float32))
