"""Pallas TPU kernel: packed-INT4 weight-only dequant GEMM (W4A16 serving).

The Table-6 deployment mode: activations stay bf16/f32, weights are the
packed INT4 series.  The kernel streams *packed* planes from HBM (0.5
byte/value/term — 4x less weight traffic than bf16), unpacks in VMEM with
the shift sign-extension idiom, folds the per-channel scales, and runs the
GEMM at the activation dtype.  This is the kernel the §Perf C3 iteration
projects onto real TPUs.

out = x @ (sum_j sw_j * unpack(W_packed_j))

Single-pass pipeline (DESIGN.md §3): the ``tw`` unpacked planes are
scale-summed in VMEM registers first, so each block issues exactly ONE MXU
dot (the seed issued ``tw``); partials accumulate in a VMEM f32 scratch and
the HBM output block is written once, at the last K step (the seed did an
``o_ref[...] +=`` HBM read-modify-write per K step).  Summing the scaled
planes before the dot also reproduces the oracle's association exactly, so
the kernel is bit-exact vs ``kernels/ref.py`` whenever K fits one block.

Grid: (M/bm, N/bn, K/bk) with K innermost for accumulation; the packed
block is (tw, bk, bn//2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unpack_int4_block(packed: jnp.ndarray) -> jnp.ndarray:
    """(bk, bn//2) int8 -> (bk, bn) int8, sign-extended nibbles."""
    p = packed.astype(jnp.int32)
    lo = (p << 28) >> 28
    hi = (p << 24) >> 28
    bk, half = packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(bk, half * 2).astype(jnp.int8)


def _kernel(x_ref, wp_ref, ws_ref, o_ref, acc_ref, *, tw: int):
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)               # (bm, bk)
    # unpack + scale-sum the tw planes in VMEM, then ONE MXU dot per block
    w = jnp.zeros(x_ref.shape[1:] + ws_ref.shape[1:], jnp.float32)  # (bk, bn)
    for j in range(tw):
        w_j = _unpack_int4_block(wp_ref[j]).astype(jnp.float32)
        w = w + ws_ref[j][None, :] * w_j             # per-channel scale fold
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]                    # single HBM write


def dequant_matmul_pallas(
    x: jnp.ndarray,           # (M, K) f32/bf16
    w_packed: jnp.ndarray,    # (tw, K, N//2) int8 — packed INT4 planes
    w_scales: jnp.ndarray,    # (tw, N) f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = True,
    dimension_semantics: tuple = ("parallel", "parallel", "arbitrary"),
) -> jnp.ndarray:
    m, k = x.shape
    tw, k2, n_half = w_packed.shape
    n = n_half * 2
    assert k == k2 and w_scales.shape == (tw, n)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, tw=tw),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tw, block_k, block_n // 2), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((tw, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),   # f32 accumulator
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=dimension_semantics),
        interpret=interpret,
    )(x.astype(jnp.float32), w_packed, w_scales.astype(jnp.float32))
