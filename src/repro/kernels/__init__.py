"""Pallas TPU kernels for the FP=xINT hot loops (+ jnp oracles in ref.py)."""
from repro.kernels.ops import residual_quantize, series_matmul, packed_dequant_matmul, kernels_enabled
from repro.kernels.pack import pack_int4, unpack_int4, packed_bytes
