"""INT4 plane packing: 2 values per int8 byte (§Perf C-series follow-up).

W4 series planes stored unpacked occupy 1 byte/value — the same container
bytes as bf16 weights at 2 terms, wasting the 4-bit logical width.  Packing
two INT4 values per byte halves plane HBM traffic; the unpack is two shifts
(VPU-friendly on TPU, exactly the `(x << 4) >> 4` sign-extension idiom).

Packing applies to bits <= 4 planes (values in [-8, 7]).  The packed layout
pairs adjacent elements of the LAST axis: packed[..., i] holds
(plane[..., 2i] & 0xF) | (plane[..., 2i+1] << 4).  An odd last axis is
padded with one zero nibble; :func:`pack_pad_nibbles` reports the pad so
artifacts can record it and ``unpack_int4(packed, orig_cols=...)`` can strip
it on the way back.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def pack_pad_nibbles(last_dim: int) -> int:
    """Zero nibbles appended to make the last axis even (0 or 1)."""
    return last_dim % 2


def pack_int4(planes: jnp.ndarray) -> jnp.ndarray:
    """int8 planes with values in [-8, 7] -> packed int8 (2 values/byte).

    An odd last axis is zero-padded by one nibble; record
    ``pack_pad_nibbles(planes.shape[-1])`` alongside the packed array (the
    artifact's ``pack_pad``) and pass the original width to
    :func:`unpack_int4` to round-trip exactly."""
    pad = pack_pad_nibbles(planes.shape[-1])
    if pad:
        pads = [(0, 0)] * (planes.ndim - 1) + [(0, pad)]
        planes = jnp.pad(planes, pads)
    lo = planes[..., 0::2].astype(jnp.int32) & 0xF
    hi = (planes[..., 1::2].astype(jnp.int32) & 0xF) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray, orig_cols: Optional[int] = None) -> jnp.ndarray:
    """packed int8 -> int8 planes (sign-extended 4-bit values).

    ``orig_cols`` strips the pad nibble recorded at pack time (odd widths)."""
    p = packed.astype(jnp.int32)
    lo = (p << 28) >> 28                      # sign-extend low nibble
    hi = (p << 24) >> 28                      # sign-extend high nibble
    out_shape = packed.shape[:-1] + (packed.shape[-1] * 2,)
    out = jnp.stack([lo, hi], axis=-1).reshape(out_shape)
    if orig_cols is not None:
        out = out[..., :orig_cols]
    return out.astype(jnp.int8)


def packed_bytes(planes: jnp.ndarray, bits: int) -> int:
    """Storage bytes with packing (vs planes.size unpacked)."""
    if bits <= 4:
        cols = planes.shape[-1]
        rows = planes.size // max(cols, 1)
        return rows * ((cols + 1) // 2)
    return planes.size
