"""INT4 plane packing: 2 values per int8 byte (§Perf C-series follow-up).

W4 series planes stored unpacked occupy 1 byte/value — the same container
bytes as bf16 weights at 2 terms, wasting the 4-bit logical width.  Packing
two INT4 values per byte halves plane HBM traffic; the unpack is two shifts
(VPU-friendly on TPU, exactly the `(x << 4) >> 4` sign-extension idiom).

Packing applies to bits <= 4 planes (values in [-8, 7]).  The packed layout
pairs adjacent elements of the LAST axis: packed[..., i] holds
(plane[..., 2i] & 0xF) | (plane[..., 2i+1] << 4).
"""
from __future__ import annotations

import jax.numpy as jnp


def pack_int4(planes: jnp.ndarray) -> jnp.ndarray:
    """int8 planes with values in [-8, 7], even last axis -> packed int8."""
    assert planes.shape[-1] % 2 == 0, planes.shape
    lo = planes[..., 0::2].astype(jnp.int32) & 0xF
    hi = (planes[..., 1::2].astype(jnp.int32) & 0xF) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """packed int8 -> int8 planes (sign-extended 4-bit values)."""
    p = packed.astype(jnp.int32)
    lo = (p << 28) >> 28                      # sign-extend low nibble
    hi = (p << 24) >> 28                      # sign-extend high nibble
    out_shape = packed.shape[:-1] + (packed.shape[-1] * 2,)
    out = jnp.stack([lo, hi], axis=-1).reshape(out_shape)
    return out.astype(jnp.int8)


def packed_bytes(planes: jnp.ndarray, bits: int) -> int:
    """Storage bytes with packing (vs planes.size unpacked)."""
    if bits <= 4:
        return planes.size // 2
    return planes.size
