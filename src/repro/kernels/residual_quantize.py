"""Pallas TPU kernel: fused residual series quantization (Theorem 1 extraction).

One HBM read of the f32 tensor produces all ``terms`` INT-X planes (int8
container) — the TPU-native form of the paper's "Parallelization of Computing
M~_i" (§4): extraction is elementwise across the tile, the term loop runs in
VMEM registers, so HBM traffic is ``4 + terms`` bytes/element instead of
``terms * 8`` for a naive per-term implementation.

Grid: (M/bm, N/bn) independent tiles.  scale1 is a per-tensor scalar passed
as a (1, 1) f32 operand (index-mapped to every tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the shared grid-constant table (repro/numerics.py is dependency-free, so
# kernels stay import-cycle-free); lint rule REPRO103 locks re-definitions
from repro.numerics import plane_limits as _plane_limits
from repro.numerics import scale_ratio as _scale_ratio


def _kernel(x_ref, s_ref, o_ref, *, bits: int, terms: int):
    r = x_ref[...].astype(jnp.float32)
    s1 = s_ref[0, 0]
    for k in range(terms):                       # static unroll, runs in VREGs
        s = s1 / float(_scale_ratio(bits) ** k)
        lo, hi = _plane_limits(bits, k)
        q = jnp.clip(jnp.round(r / s), lo, hi)
        r = r - s * q
        o_ref[k, :, :] = q.astype(jnp.int8)


def residual_quantize_pallas(
    x: jnp.ndarray,
    scale1: jnp.ndarray,
    *,
    bits: int,
    terms: int,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: (M, N) f32; scale1: () f32  ->  planes (terms, M, N) int8.

    M, N must be multiples of the block sizes (ops.py pads)."""
    m, n = x.shape
    assert m % block_m == 0 and n % block_n == 0, (x.shape, block_m, block_n)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, terms=terms),
        out_shape=jax.ShapeDtypeStruct((terms, m, n), jnp.int8),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((terms, block_m, block_n), lambda i, j: (0, i, j)),
        interpret=interpret,
    )(x.astype(jnp.float32), scale1.reshape(1, 1).astype(jnp.float32))
