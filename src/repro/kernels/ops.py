"""jit'd public wrappers around the Pallas kernels, with padding + dispatch.

On this CPU container the kernels run under ``interpret=True`` (the kernel
body executes in Python on CPU — bit-exact vs. the TPU lowering contract);
on a real TPU the same calls compile to Mosaic.  Set ``REPRO_NO_PALLAS=1``
to force the pure-jnp reference path (used to cross-check, and in
distributed dry-runs where interpret-mode callbacks cannot be partitioned).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.pack import pack_int4, unpack_int4
from repro.kernels.residual_quantize import residual_quantize_pallas
from repro.kernels.series_matmul import series_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernels_enabled() -> bool:
    return os.environ.get("REPRO_NO_PALLAS", "0") != "1"


def _pad_to(x: jnp.ndarray, mults, axes):
    pads = [(0, 0)] * x.ndim
    needs = False
    for ax, mult in zip(axes, mults):
        rem = (-x.shape[ax]) % mult
        if rem:
            pads[ax] = (0, rem)
            needs = True
    return jnp.pad(x, pads) if needs else x


def _pick_block(dim: int, pref: int, align: int = 8) -> int:
    """Largest block <= pref that keeps padding overhead small; fall back to
    the padded-to-align dim itself for small inputs."""
    if dim >= pref:
        return pref
    return max(align, ((dim + align - 1) // align) * align)


@partial(jax.jit, static_argnames=("bits", "terms", "use_kernel", "block_m", "block_n"))
def residual_quantize(
    x: jnp.ndarray,
    scale1: jnp.ndarray,
    *,
    bits: int,
    terms: int,
    use_kernel: bool = True,
    block_m: int = 256,
    block_n: int = 256,
) -> jnp.ndarray:
    """(M, N) f32, () scale -> (terms, M, N) int8 planes."""
    if not (use_kernel and kernels_enabled()):
        return ref.residual_quantize_ref(x, scale1, bits, terms)
    m, n = x.shape
    bm, bn = _pick_block(m, block_m), _pick_block(n, block_n)
    xp = _pad_to(x, (bm, bn), (0, 1))
    planes = residual_quantize_pallas(
        xp, scale1, bits=bits, terms=terms, block_m=bm, block_n=bn,
        interpret=not _on_tpu(),
    )
    return planes[:, :m, :n]


@partial(jax.jit, static_argnames=("a_bits", "a_terms", "use_kernel", "block_m", "block_n", "block_k"))
def series_matmul(
    x: jnp.ndarray,
    a_scale1: jnp.ndarray,
    w_planes: jnp.ndarray,
    w_scales: jnp.ndarray,
    *,
    a_bits: int,
    a_terms: int,
    use_kernel: bool = True,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
) -> jnp.ndarray:
    """Fused sum_{i,j} sa_i sw_j (A_i @ W_j).  x (M,K); w_planes (tw,K,N)."""
    tw, k, n = w_planes.shape
    if w_scales.ndim == 1:  # canonicalize to per-channel
        w_scales = jnp.broadcast_to(w_scales[:, None], (tw, n))
    if not (use_kernel and kernels_enabled()):
        return ref.series_matmul_ref(x, a_scale1, w_planes, w_scales, a_bits=a_bits, a_terms=a_terms)
    m = x.shape[0]
    bm, bn, bk = _pick_block(m, block_m), _pick_block(n, block_n), _pick_block(k, block_k)
    xp = _pad_to(x, (bm, bk), (0, 1))
    wp = _pad_to(w_planes, (bk, bn), (1, 2))
    wsp = _pad_to(w_scales, (bn,), (1,))
    out = series_matmul_pallas(
        xp, a_scale1, wp, wsp, a_bits=a_bits, a_terms=a_terms,
        block_m=bm, block_n=bn, block_k=bk, interpret=not _on_tpu(),
    )
    return out[:m, :n]


@partial(jax.jit, static_argnames=("use_kernel", "block_m", "block_n", "block_k"))
def packed_dequant_matmul(
    x: jnp.ndarray,
    w_packed: jnp.ndarray,
    w_scales: jnp.ndarray,
    *,
    use_kernel: bool = True,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
) -> jnp.ndarray:
    """Weight-only W4A16 GEMM over packed INT4 planes (kernels/dequant_matmul).

    x (M, K); w_packed (tw, K, N//2) int8; w_scales (tw, N) -> (M, N) f32."""
    tw, k, n_half = w_packed.shape
    n = n_half * 2
    if w_scales.ndim == 1:
        w_scales = jnp.broadcast_to(w_scales[:, None], (tw, n))
    if not (use_kernel and kernels_enabled()):
        return ref.dequant_matmul_ref(x, unpack_int4(w_packed), w_scales)
    m = x.shape[0]
    bm, bk = _pick_block(m, block_m), _pick_block(k, block_k)
    bn = _pick_block(n, block_n, align=16)  # even halves after packing
    xp = _pad_to(x, (bm, bk), (0, 1))
    wp = _pad_to(w_packed, (bk, bn // 2), (1, 2))
    wsp = _pad_to(w_scales, (bn,), (1,))
    out = dequant_matmul_pallas(xp, wp, wsp, block_m=bm, block_n=bn, block_k=bk)
    return out[:m, :n]
