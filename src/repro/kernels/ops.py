"""jit'd public wrappers around the Pallas kernels: padding, autotuned block
dispatch, and kernel-structure introspection.

On this CPU container the kernels run under ``interpret=True`` (the kernel
body executes in Python on CPU — bit-exact vs. the TPU lowering contract);
on a real TPU the same calls compile to Mosaic.  Set ``REPRO_NO_PALLAS=1``
to force the pure-jnp reference path (used to cross-check, and in
distributed dry-runs where interpret-mode callbacks cannot be partitioned).

Block sizes are selected by a shape-keyed autotune layer
(:func:`select_block_config`): a table of known-good configurations for
canonical shapes, falling back to a deterministic search over candidate
tiles under a VMEM budget model (double-buffered input blocks + the series
kernel's quantize-once plane scratch + the f32 accumulator).  Decisions are
cached per ``(kind, M, K, N, ta, tw, backend)``; explicit ``block_*``
arguments and ``REPRO_BLOCK_{M,N,K}`` env vars override it.
"""
from __future__ import annotations

import dataclasses
import os
from functools import lru_cache, partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.pack import pack_int4, unpack_int4
from repro.kernels.residual_quantize import residual_quantize_pallas
from repro.kernels.series_matmul import (
    grouped_series_matmul_pallas,
    series_matmul_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernels_enabled() -> bool:
    return os.environ.get("REPRO_NO_PALLAS", "0") != "1"


def _pad_to(x: jnp.ndarray, mults, axes):
    pads = [(0, 0)] * x.ndim
    needs = False
    for ax, mult in zip(axes, mults):
        rem = (-x.shape[ax]) % mult
        if rem:
            pads[ax] = (0, rem)
            needs = True
    return jnp.pad(x, pads) if needs else x


def _pick_block(dim: int, pref: int, align: int = 8) -> int:
    """Clamp an explicitly-requested block to the (padded) dim for small
    inputs; explicit block_* args bypass the autotuner through this."""
    if dim >= pref:
        return pref
    return max(align, ((dim + align - 1) // align) * align)


# ---------------------------------------------------------------------------
# autotune / dispatch layer
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One dispatch decision: tile sizes + Mosaic grid-dimension hints."""
    block_m: int
    block_n: int
    block_k: int
    dimension_semantics: Tuple[str, ...]

    @property
    def blocks(self) -> Tuple[int, int, int]:
        return (self.block_m, self.block_n, self.block_k)


# ~16 MB VMEM/core on v4/v5; leave headroom for Mosaic-internal buffers.
VMEM_BUDGET_BYTES = int(os.environ.get("REPRO_VMEM_BUDGET", 12 << 20))

# The quantize-once guard needs the N grid dim executed in order; K carries
# the accumulator.  M tiles are independent.
_SEMANTICS = {
    "series": ("parallel", "arbitrary", "arbitrary"),
    # grouped (stacked-expert) series GEMM: leading expert grid dim is
    # independent; per-expert the semantics match "series"
    "grouped_series": ("parallel", "parallel", "arbitrary", "arbitrary"),
    "dequant": ("parallel", "parallel", "arbitrary"),
    "quant": ("parallel", "parallel"),
    # paged flash attention: slots are independent; the page axis carries
    # the online-softmax (acc, m, l) accumulator
    "flash": ("parallel", "arbitrary"),
}

# Known-good tiles for canonical (kind, M, K, N) shapes — checked before the
# budget search.  Entries come from BENCH_kernels.json sweeps; extend freely.
_TUNE_TABLE: Dict[Tuple[str, int, int, int], Tuple[int, int, int]] = {
    ("series", 1024, 4096, 4096): (256, 512, 1024),
    ("series", 2048, 4096, 11008): (256, 512, 1024),
    ("series", 256, 2048, 2048): (256, 256, 1024),
    ("dequant", 1024, 4096, 4096): (256, 512, 2048),
    ("dequant", 8, 4096, 4096): (8, 1024, 2048),
}

_PREFS_M = (512, 256, 128, 64, 32, 16, 8)
_PREFS_N = (1024, 512, 256, 128, 64, 32, 16, 8)
_PREFS_K = (2048, 1024, 512, 256, 128, 64, 32, 16, 8)


def _align_up(v: int, a: int) -> int:
    return -(-v // a) * a


def _blk_options(dim: int, prefs: Tuple[int, ...], align: int = 8) -> List[int]:
    """Candidate tile sizes for one dim: the preference ladder below the
    padded dim, plus the padded dim itself (single-tile, zero grid overhead)
    when it is not absurdly large."""
    padded = max(align, _align_up(dim, align))
    opts = {p for p in prefs if p < padded}
    opts.add(min(padded, max(prefs)))
    if padded <= 2 * max(prefs):
        opts.add(padded)
    return sorted(opts, reverse=True)


def _vmem_bytes(kind: str, bm: int, bn: int, bk: int, k: int,
                a_terms: int, w_terms: int) -> int:
    """VMEM footprint model: x2 on streamed blocks for double buffering."""
    if kind == "quant":
        return 2 * bm * bn * 4 + 2 * a_terms * bm * bn
    kpad = _align_up(max(k, 1), bk)
    total = 2 * bm * bk * 4                      # activation block, f32
    total += 2 * bm * bn * 4                     # output block, f32
    total += bm * bn * 4                         # f32 accumulator scratch
    if kind == "series":
        total += 2 * w_terms * bk * bn           # int8 weight-plane block
        total += 2 * w_terms * bn * 4            # per-channel scales
        total += a_terms * bm * kpad             # quantize-once plane cache
    else:  # dequant: packed int4 planes, half-width N
        total += 2 * w_terms * bk * (bn // 2)
        total += 2 * w_terms * bn * 4
    return total


@lru_cache(maxsize=4096)
def select_block_config(kind: str, m: int, k: int, n: int,
                        a_terms: int = 0, w_terms: int = 1,
                        backend: str = "interpret") -> BlockConfig:
    """Shape-keyed block-size selection, cached per (kind, M, K, N, ta, tw).

    Order of precedence: ``REPRO_BLOCK_{M,N,K}`` env overrides, the
    known-good table, then a deterministic search minimizing padding waste
    and maximizing MXU tile fill under the VMEM budget."""
    sem = _SEMANTICS[kind]
    n_align = 16 if kind == "dequant" else 8     # even halves after packing
    hit = _TUNE_TABLE.get((kind, m, k, n))
    if hit is not None:
        return BlockConfig(*hit, dimension_semantics=sem)

    opts_m = _blk_options(m, _PREFS_M)
    opts_n = _blk_options(n, _PREFS_N, n_align)
    opts_k = _blk_options(k, _PREFS_K) if kind != "quant" else [1]
    best, best_score = None, None
    for bm in opts_m:
        for bn in opts_n:
            for bk in opts_k:
                fits = _vmem_bytes(kind, bm, bn, bk, k, a_terms, w_terms) \
                    <= VMEM_BUDGET_BYTES
                waste = (_align_up(m, bm) * _align_up(n, bn)
                         * (_align_up(k, bk) if kind != "quant" else 1)) \
                    / max(m * n * (k if kind != "quant" else 1), 1)
                fill = (min(bm, 128) * min(bn, 128)
                        * (min(bk, 128) if kind != "quant" else 128))
                # lexicographic: fit in VMEM, low padding waste, full MXU
                # tiles, deep K blocks (fewer accumulator steps), big tiles
                score = (not fits, round(waste, 2), -fill, -bk, -(bm * bn))
                if best_score is None or score < best_score:
                    best, best_score = (bm, bn, bk), score
    bm, bn, bk = best
    if kind == "quant":
        return BlockConfig(bm, bn, 1, sem)
    return BlockConfig(bm, bn, bk, sem)


def _resolve_blocks(kind: str, m: int, k: int, n: int, a_terms: int,
                    w_terms: int, block_m: Optional[int],
                    block_n: Optional[int], block_k: Optional[int]) -> BlockConfig:
    """Per-dim precedence: explicit argument > REPRO_BLOCK_{M,N,K} env var >
    autotuned.  Env vars are read here (outside the block-config cache) so
    each dim can be overridden independently; set them before the first call
    for a given shape — jit traces are cached per shape."""
    cfg = select_block_config(
        kind, m, k, n, a_terms, w_terms,
        backend="tpu" if _on_tpu() else "interpret")
    n_align = 16 if kind == "dequant" else 8

    def pick(dim, explicit, env_name, auto, align=8):
        if explicit:
            return _pick_block(dim, explicit, align)
        env = os.environ.get(env_name)
        if env:
            return _pick_block(dim, int(env), align)
        return auto

    return BlockConfig(
        pick(m, block_m, "REPRO_BLOCK_M", cfg.block_m),
        pick(n, block_n, "REPRO_BLOCK_N", cfg.block_n, n_align),
        pick(k, block_k, "REPRO_BLOCK_K", cfg.block_k),
        cfg.dimension_semantics,
    )


# ---------------------------------------------------------------------------
# public kernels
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("bits", "terms", "use_kernel", "block_m", "block_n"))
def residual_quantize(
    x: jnp.ndarray,
    scale1: jnp.ndarray,
    *,
    bits: int,
    terms: int,
    use_kernel: bool = True,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
) -> jnp.ndarray:
    """(M, N) f32, () scale -> (terms, M, N) int8 planes."""
    if not (use_kernel and kernels_enabled()):
        return ref.residual_quantize_ref(x, scale1, bits, terms)
    m, n = x.shape
    cfg = _resolve_blocks("quant", m, 0, n, terms, 0, block_m, block_n, None)
    bm, bn = cfg.block_m, cfg.block_n
    xp = _pad_to(x, (bm, bn), (0, 1))
    planes = residual_quantize_pallas(
        xp, scale1, bits=bits, terms=terms, block_m=bm, block_n=bn,
        interpret=not _on_tpu(),
    )
    return planes[:, :m, :n]


@partial(jax.jit, static_argnames=("a_bits", "a_terms", "use_kernel", "block_m", "block_n", "block_k"))
def series_matmul(
    x: jnp.ndarray,
    a_scale1: jnp.ndarray,
    w_planes: jnp.ndarray,
    w_scales: jnp.ndarray,
    *,
    a_bits: int,
    a_terms: int,
    use_kernel: bool = True,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jnp.ndarray:
    """Fused sum_{i,j} sa_i sw_j (A_i @ W_j).  x (M,K); w_planes (tw,K,N).

    Single-pass pipeline: VMEM scratch accumulation (one HBM output write),
    quantize-once activation-plane reuse across N blocks, and ta (not ta*tw)
    MXU dispatches per block.  Blocks are autotuned unless given."""
    tw, k, n = w_planes.shape
    if w_scales.ndim == 1:  # canonicalize to per-channel
        w_scales = jnp.broadcast_to(w_scales[:, None], (tw, n))
    if not (use_kernel and kernels_enabled()):
        return ref.series_matmul_ref(x, a_scale1, w_planes, w_scales, a_bits=a_bits, a_terms=a_terms)
    m = x.shape[0]
    cfg = _resolve_blocks("series", m, k, n, a_terms, tw, block_m, block_n, block_k)
    bm, bn, bk = cfg.blocks
    xp = _pad_to(x, (bm, bk), (0, 1))
    wp = _pad_to(w_planes, (bk, bn), (1, 2))
    wsp = _pad_to(w_scales, (bn,), (1,))
    out = series_matmul_pallas(
        xp, a_scale1, wp, wsp, a_bits=a_bits, a_terms=a_terms,
        block_m=bm, block_n=bn, block_k=bk, interpret=not _on_tpu(),
        dimension_semantics=cfg.dimension_semantics,
    )
    return out[:m, :n]


@partial(jax.jit, static_argnames=("a_bits", "a_terms", "use_kernel", "block_m", "block_n", "block_k"))
def grouped_series_matmul(
    x: jnp.ndarray,
    a_scale1: jnp.ndarray,
    w_planes: jnp.ndarray,
    w_scales: jnp.ndarray,
    *,
    a_bits: int,
    a_terms: int,
    use_kernel: bool = True,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jnp.ndarray:
    """Grouped (stacked-expert) series GEMM: x (E, M, K); a_scale1 (E,);
    w_planes (E, tw, K, N); w_scales (E, tw) or (E, tw, N) -> (E, M, N) f32.

    ONE dispatch covers the whole expert axis — a Pallas call whose grid
    leads with E (per-expert tiles autotuned like "series"), or a batched
    jnp fallback whose every dot_general carries E on the batch axis — so
    the expert GEMM count stays O(terms), not O(E * terms)
    (``dispatch_census`` budget entries ``moe_*``)."""
    e, tw, k, n = w_planes.shape
    if w_scales.ndim == 2:  # canonicalize to per-channel
        w_scales = jnp.broadcast_to(w_scales[..., None], (e, tw, n))
    if not (use_kernel and kernels_enabled()):
        fn = partial(ref.series_matmul_ref, a_bits=a_bits, a_terms=a_terms)
        return jax.vmap(fn)(x, a_scale1, w_planes, w_scales)
    m = x.shape[1]
    cfg = _resolve_blocks("series", m, k, n, a_terms, tw,
                          block_m, block_n, block_k)
    bm, bn, bk = cfg.blocks
    xp = _pad_to(x, (bm, bk), (1, 2))
    wp = _pad_to(w_planes, (bk, bn), (2, 3))
    wsp = _pad_to(w_scales, (bn,), (2,))
    out = grouped_series_matmul_pallas(
        xp, a_scale1, wp, wsp, a_bits=a_bits, a_terms=a_terms,
        block_m=bm, block_n=bn, block_k=bk, interpret=not _on_tpu(),
        dimension_semantics=_SEMANTICS["grouped_series"],
    )
    return out[:, :m, :n]


@partial(jax.jit, static_argnames=("use_kernel", "block_m", "block_n", "block_k"))
def packed_dequant_matmul(
    x: jnp.ndarray,
    w_packed: jnp.ndarray,
    w_scales: jnp.ndarray,
    *,
    use_kernel: bool = True,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jnp.ndarray:
    """Weight-only W4A16 GEMM over packed INT4 planes (kernels/dequant_matmul).

    x (M, K); w_packed (tw, K, N//2) int8; w_scales (tw, N) -> (M, N) f32."""
    tw, k, n_half = w_packed.shape
    n = n_half * 2
    if w_scales.ndim == 1:
        w_scales = jnp.broadcast_to(w_scales[:, None], (tw, n))
    if not (use_kernel and kernels_enabled()):
        return ref.dequant_matmul_ref(x, unpack_int4(w_packed), w_scales)
    m = x.shape[0]
    cfg = _resolve_blocks("dequant", m, k, n, 0, tw, block_m, block_n, block_k)
    bm, bn, bk = cfg.blocks
    xp = _pad_to(x, (bm, bk), (0, 1))
    wp = _pad_to(w_packed, (bk, bn // 2), (1, 2))
    wsp = _pad_to(w_scales, (bn,), (1,))
    out = dequant_matmul_pallas(
        xp, wp, wsp, block_m=bm, block_n=bn, block_k=bk,
        interpret=not _on_tpu(),
        dimension_semantics=cfg.dimension_semantics,
    )
    return out[:m, :n]


def dequant_matmul(x: jnp.ndarray, w_planes: jnp.ndarray,
                   w_scales: jnp.ndarray) -> jnp.ndarray:
    """Weight-only GEMM over UNPACKED int8 planes: out = x @ sum_j sw_j W_j.

    The single dispatch point for the weight-only path (core/linear.py);
    planes of arbitrary bit-width live in the int8 container, so this stays
    on the jnp reference path (XLA fuses the plane sum into the GEMM).  The
    packed-INT4 serving path is :func:`packed_dequant_matmul`."""
    tw, k, n = w_planes.shape
    if w_scales.ndim == 1:
        w_scales = jnp.broadcast_to(w_scales[:, None], (tw, n))
    return ref.dequant_matmul_ref(x, w_planes, w_scales)


def paged_flash_partial(q, k_pool, v_pool, block_tables, cache_len, *,
                        softcap: float = 0.0):
    """Paged flash-attention partial (kernels/flash_attention.py): q
    (B, T, G, R, D) f32 pre-scaled by ``D**-0.5``; pools (P, page, G, D)
    with the last row the sentinel page; block_tables (B, MP) int32;
    cache_len (B,) int32.  Returns un-normalized (acc, m, l) over the
    paged cache prefix — the caller merges the chunk's own KV.

    The page tile is fixed by the pool layout (one page per grid step), so
    this bypasses the block autotuner; it shares the dimension-semantics
    registry (``_SEMANTICS["flash"]``) and the interpret/TPU switch.  No
    jnp fallback here: ref dispatch happens one level up, in
    ``models.attention.paged_*`` (``use_kernel`` / ``REPRO_NO_PALLAS``),
    because the reference needs the dense gather the kernel exists to
    avoid."""
    from repro.kernels import flash_attention as _fa
    return _fa.paged_flash_partial_pallas(
        q, k_pool, v_pool, block_tables, cache_len, softcap=softcap,
        interpret=not _on_tpu(), dimension_semantics=_SEMANTICS["flash"])


def paged_flash_partial_int8(q_i8, q_s, kq_pool, ks_pool, vq_pool, vs_pool,
                             block_tables, cache_len, *, softcap: float = 0.0):
    """int8 twin of :func:`paged_flash_partial` — in-kernel dequant via the
    factored-scale identity keeps QK^T and PV on the int8 MXU path."""
    from repro.kernels import flash_attention as _fa
    return _fa.paged_flash_partial_int8_pallas(
        q_i8, q_s, kq_pool, ks_pool, vq_pool, vs_pool, block_tables,
        cache_len, softcap=softcap,
        interpret=not _on_tpu(), dimension_semantics=_SEMANTICS["flash"])


# ---------------------------------------------------------------------------
# kernel-structure introspection — the implementation moved to
# repro.analysis.jaxpr_check (the generic jaxpr walker grew out of it);
# re-exported here because tests and BENCH_kernels.json call it as ops.*
# ---------------------------------------------------------------------------
from repro.analysis.jaxpr_check import (  # noqa: E402
    gemm_dispatch_count,
    kernel_structure,
)
