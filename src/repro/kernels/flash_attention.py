"""Paged flash-attention Pallas kernels (decode + chunk-verify).

One kernel family computes the *partial* online-softmax attention of T query
tokens per slot against that slot's paged KV cache: grid ``(B, MP)`` walks
each slot's block table (scalar-prefetched, so the kv ``index_map`` streams
exactly the slot's own pages through VMEM), carrying the running
``(acc, max, denom)`` in the revisited output blocks.  The jnp wrapper
(:mod:`repro.models.attention`) merges the chunk's own causal KV — decode is
T=1, speculative verify is T=γ+1 — by the exact two-way online-softmax
merge, so the cache buffer is never gathered to a dense ``(B, S)`` layout.

The int8 variant keeps BOTH GEMMs on the int8 MXU path via the factored-
scale identity (DESIGN.md §10): K's per-(position, kv-head) scales multiply
the int32 QK^T products per column; V's scales fold into the softmax
weights *before* the PV dot, with the folded weights re-quantized per row
per page.  Per-page weight quantization reassociates differently from the
reference's whole-row quantization, so the int8 kernel is tolerance-tested
(few %), while the fp kernel matches the gather reference to ~1e-6.

Block tables use a *sentinel* page id (``pool_pages - 1``, the last pool
row): unused table slots point at it, its reads are always masked by
``pos < cache_len``, and masked-row QoS dispatches substitute all-sentinel
tables so dropped rows write only garbage into the sentinel page.

On this CPU container the kernels run under ``interpret=True``;
``REPRO_NO_PALLAS=1`` (or ``use_kernel=False`` contexts) selects the
gather-based jnp reference, which is the token-identity oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_partial_kernel(bt_ref, clen_ref, q_ref, k_ref, v_ref,
                          acc_ref, m_ref, l_ref, *, page: int, softcap: float):
    """Grid (B, MP): block j of slot b streams page ``bt[b, j]`` through
    VMEM and folds it into the slot's running (acc, m, l)."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref[...])
        m_ref[...] = jnp.full_like(m_ref[...], NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref[...])

    clen = clen_ref[b]
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    valid = pos < clen

    @pl.when(jnp.any(valid))
    def _step():
        q = q_ref[0]                     # (T, G, R, D) f32, pre-scaled
        k = k_ref[0]                     # (page, G, D)
        v = v_ref[0]
        sc = jnp.einsum("tgrd,pgd->tgrp", q, k.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        if softcap > 0.0:
            sc = softcap * jnp.tanh(sc / softcap)
        sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("tgrp,pgd->tgrd", p, v.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc_ref[0] = acc_ref[0] * alpha[..., None] + pv
        m_ref[0] = m_new


def _quantize_rows(x):
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _paged_partial_int8_kernel(bt_ref, clen_ref, q_ref, qs_ref, k_ref, ks_ref,
                               v_ref, vs_ref, acc_ref, m_ref, l_ref,
                               *, page: int, softcap: float):
    """int8 twin: QK^T runs int8 x int8 -> int32 with K scales applied per
    column; V scales fold into the weights which are re-quantized per row
    (per page) so PV is an int8 dot too — in-kernel dequantization via the
    factored-scale identity, never a dequantized KV materialization."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref[...])
        m_ref[...] = jnp.full_like(m_ref[...], NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref[...])

    clen = clen_ref[b]
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    valid = pos < clen

    @pl.when(jnp.any(valid))
    def _step():
        q = q_ref[0]                     # (T, G, R, D) int8
        qs = qs_ref[0]                   # (T, G, R) f32
        k = k_ref[0]                     # (page, G, D) int8
        ks = ks_ref[0]                   # (page, G) f32
        sc_i = jnp.einsum("tgrd,pgd->tgrp", q, k,
                          preferred_element_type=jnp.int32)
        sc = sc_i.astype(jnp.float32) * qs[..., None] \
            * jnp.moveaxis(ks, 0, 1)[None, :, None, :]
        if softcap > 0.0:
            sc = softcap * jnp.tanh(sc / softcap)
        sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p, axis=-1)
        # fold V's per-position scales, re-quantize the folded weights per
        # row, and keep the PV dot on the int8 MXU
        p_fold = p * jnp.moveaxis(vs_ref[0], 0, 1)[None, :, None, :]
        p_i8, p_s = _quantize_rows(p_fold)
        pv = jnp.einsum("tgrp,pgd->tgrd", p_i8, v_ref[0],
                        preferred_element_type=jnp.int32)
        acc_ref[0] = acc_ref[0] * alpha[..., None] \
            + pv.astype(jnp.float32) * p_s[..., None]
        m_ref[0] = m_new


def _out_shapes(b, t, g, r, d):
    return [jax.ShapeDtypeStruct((b, t, g, r, d), jnp.float32),
            jax.ShapeDtypeStruct((b, t, g, r), jnp.float32),
            jax.ShapeDtypeStruct((b, t, g, r), jnp.float32)]


def _q_spec(t, g, r, d):
    return pl.BlockSpec((1, t, g, r, d), lambda i, j, *_: (i, 0, 0, 0, 0))


def _kv_map(i, j, bt_s, cl_s):
    # scalar-prefetched block table drives the page stream: block j of slot
    # i is physical pool row bt[i, j] (the sentinel row when unallocated)
    return (bt_s[i, j], 0, 0, 0)


def _scale_map(i, j, bt_s, cl_s):
    return (bt_s[i, j], 0, 0)


def _carry_specs(t, g, r, d):
    return [pl.BlockSpec((1, t, g, r, d), lambda i, j, *_: (i, 0, 0, 0, 0)),
            pl.BlockSpec((1, t, g, r), lambda i, j, *_: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, g, r), lambda i, j, *_: (i, 0, 0, 0))]


def paged_flash_partial_pallas(q, k_pool, v_pool, block_tables, cache_len, *,
                               softcap: float = 0.0, interpret: bool = True,
                               dimension_semantics=("parallel", "arbitrary")):
    """Partial paged attention of q (B, T, G, R, D) f32 (pre-scaled by
    ``D**-0.5``) against pools (P, page, G, D); block_tables (B, MP) int32,
    cache_len (B,) int32.  Returns ``(acc, m, l)`` — un-normalized output,
    running max, running denominator — over cache positions ``[0, clen)``."""
    b, t, g, r, d = q.shape
    page = k_pool.shape[1]
    mp = block_tables.shape[1]
    kernel = functools.partial(_paged_partial_kernel, page=page,
                               softcap=float(softcap))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, mp),
            in_specs=[_q_spec(t, g, r, d),
                      pl.BlockSpec((1, page, g, d), _kv_map),
                      pl.BlockSpec((1, page, g, d), _kv_map)],
            out_specs=_carry_specs(t, g, r, d),
        ),
        out_shape=_out_shapes(b, t, g, r, d),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=dimension_semantics),
        interpret=interpret,
    )(block_tables, cache_len, q, k_pool, v_pool)


def paged_flash_partial_int8_pallas(q_i8, q_s, kq_pool, ks_pool, vq_pool,
                                    vs_pool, block_tables, cache_len, *,
                                    softcap: float = 0.0,
                                    interpret: bool = True,
                                    dimension_semantics=("parallel",
                                                         "arbitrary")):
    """int8 twin of :func:`paged_flash_partial_pallas`: q_i8 (B, T, G, R, D)
    int8 with per-row scales q_s (B, T, G, R) (scale the D**-0.5 into q_s);
    pools int8 with per-(page-slot, kv-head) scale pools (P, page, G)."""
    b, t, g, r, d = q_i8.shape
    page = kq_pool.shape[1]
    mp = block_tables.shape[1]
    kernel = functools.partial(_paged_partial_int8_kernel, page=page,
                               softcap=float(softcap))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, mp),
            in_specs=[_q_spec(t, g, r, d),
                      pl.BlockSpec((1, t, g, r), lambda i, j, *_: (i, 0, 0, 0)),
                      pl.BlockSpec((1, page, g, d), _kv_map),
                      pl.BlockSpec((1, page, g), _scale_map),
                      pl.BlockSpec((1, page, g, d), _kv_map),
                      pl.BlockSpec((1, page, g), _scale_map)],
            out_specs=_carry_specs(t, g, r, d),
        ),
        out_shape=_out_shapes(b, t, g, r, d),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=dimension_semantics),
        interpret=interpret,
    )(block_tables, cache_len, q_i8, q_s, kq_pool, ks_pool, vq_pool, vs_pool)
