"""Pallas TPU kernel: fused series-expansion GEMM (FP=xINT layer expansion, Eq. 3).

Computes  out = sum_{i<ta, j<tw}  sa_i * sw_j[n] * (A_i @ W_j)

where A_i are the residual INT-X planes of the activation tile — quantized
*inside the kernel in VMEM*, never materialized to HBM — and W_j are the
pre-expanded weight planes.  Each int8 x int8 dot hits the MXU with int32
accumulation (v5e: 394 TOPS int8 = 2x bf16 peak); per-(i,j) partials are
scale-folded into a single f32 accumulator held in the revisited output
block.

This fusion is the TPU-native adaptation of the paper's "parallel term
computation": a naive implementation reads A from HBM ta times (once per
term GEMM); here the activation tile is read once and re-quantized in
registers, so the memory roofline term scales with 1 activation read + tw
weight-plane reads instead of ta*(activation+weight) reads.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary") for accumulation.
Weight scales are canonicalized to per-channel (tw, N) by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scale_ratio(bits: int) -> int:
    # mirrors repro.core.expansion.scale_ratio (no import cycle in kernels)
    return 2 ** bits if bits < 8 else 2 ** (bits - 1)


def _plane_limits(bits: int, k: int):
    if k == 0:
        hi = 2 ** (bits - 1) - 1
    else:
        hi = min(2 ** (bits - 1), 127)
    return -hi, hi


def _kernel(x_ref, s_ref, w_ref, ws_ref, o_ref, *, a_bits: int, a_terms: int, tw: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    sa1 = s_ref[0, 0]
    r = x_ref[...].astype(jnp.float32)           # (bm, bk) activation tile
    acc = jnp.zeros_like(o_ref)
    for i in range(a_terms):                     # sequential residual planes in VREGs
        sa_i = sa1 / float(_scale_ratio(a_bits) ** i)
        lo, hi = _plane_limits(a_bits, i)
        q = jnp.clip(jnp.round(r / sa_i), lo, hi)
        r = r - sa_i * q
        a_i = q.astype(jnp.int8)
        for j in range(tw):                      # int8 MXU GEMM per weight plane
            p = jax.lax.dot_general(
                a_i, w_ref[j],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            acc = acc + (sa_i * ws_ref[j]) * p.astype(jnp.float32)
    o_ref[...] += acc


def series_matmul_pallas(
    x: jnp.ndarray,           # (M, K) f32 — centered & clipped activations
    a_scale1: jnp.ndarray,    # () f32
    w_planes: jnp.ndarray,    # (tw, K, N) int8
    w_scales: jnp.ndarray,    # (tw, N) f32
    *,
    a_bits: int,
    a_terms: int,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    m, k = x.shape
    tw, k2, n = w_planes.shape
    assert k == k2 and w_scales.shape == (tw, n)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, k, n), (block_m, block_k, block_n))
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, a_bits=a_bits, a_terms=a_terms, tw=tw),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((tw, block_k, block_n), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((tw, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        a_scale1.reshape(1, 1).astype(jnp.float32),
        w_planes,
        w_scales.astype(jnp.float32),
    )
