"""Pallas TPU kernel: fused series-expansion GEMM (FP=xINT layer expansion, Eq. 3).

Computes  out = sum_{i<ta, j<tw}  sa_i * sw_j[n] * (A_i @ W_j)

where A_i are the residual INT-X planes of the activation tile — quantized
*inside the kernel in VMEM*, never materialized to HBM — and W_j are the
pre-expanded weight planes.  Each int8 x int8 dot hits the MXU with int32
accumulation (v5e: 394 TOPS int8 = 2x bf16 peak).

Single-pass pipeline (DESIGN.md §3):

* **Scratch accumulation.**  Partials accumulate in a VMEM f32 scratch
  (``acc_ref``); the HBM output block is written exactly once, at the last
  K step.  The seed kernel instead did ``o_ref[...] +=`` every K step — an
  HBM read-modify-write of the f32 output block per (i, j, kk) grid cell,
  2*nk*4*bm*bn bytes of avoidable traffic per output block.

* **Quantize-once plane reuse.**  The residual planes of each (m, k)
  activation tile are extracted exactly once — on the first N-grid step
  (j == 0) — into an int8 VMEM scratch holding the full K strip
  (``ta x bm x K`` bytes), then reused by every subsequent weight-column
  block.  The seed kernel re-ran the round/clip residual chain for every
  (j, kk) pair, multiplying the VPU quantization work by N/bn.

* **Stacked-plane GEMM.**  The ``ta * tw`` tiny MXU GEMMs per block are
  collapsed to ``ta`` dispatches: the ``tw`` weight planes ride along the
  batch axis of a single ``dot_general`` (one MXU pass per plane, one
  dispatch per activation plane), and the per-plane int32 partials are
  scale-folded into the f32 accumulator in the same order as the oracle —
  so results stay bit-exact vs ``kernels/ref.py`` whenever K fits one block.

Grid: (M/bm, N/bn, K/bk) — K innermost ("arbitrary") for accumulation, N
middle ("arbitrary": the quantize-once guard requires j in order), M
parallel.  Weight scales are canonicalized to per-channel (tw, N) by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the shared grid-constant table (repro/numerics.py is dependency-free, so
# kernels stay import-cycle-free); lint rule REPRO103 locks re-definitions
from repro.numerics import plane_limits as _plane_limits
from repro.numerics import scale_ratio as _scale_ratio


def _kernel(x_ref, s_ref, w_ref, ws_ref, o_ref, qa_ref, acc_ref,
            *, a_bits: int, a_terms: int, tw: int, block_k: int):
    j = pl.program_id(1)
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j == 0)
    def _extract():
        # quantize this (m, k) activation tile exactly once; every other
        # N-grid step reads the cached int8 planes from VMEM scratch
        sa1 = s_ref[0, 0]
        r = x_ref[...].astype(jnp.float32)
        for i in range(a_terms):             # static unroll, runs in VREGs
            sa_i = sa1 / float(_scale_ratio(a_bits) ** i)
            lo, hi = _plane_limits(a_bits, i)
            q = jnp.clip(jnp.round(r / sa_i), lo, hi)
            r = r - sa_i * q
            qa_ref[i, :, pl.ds(kk * block_k, block_k)] = q.astype(jnp.int8)

    sa1 = s_ref[0, 0]
    a = qa_ref[:, :, pl.ds(kk * block_k, block_k)]   # (ta, bm, bk) int8
    w = w_ref[...]                                   # (tw, bk, bn) int8
    ws = ws_ref[...]                                 # (tw, bn) f32
    acc = acc_ref[...]
    for i in range(a_terms):
        sa_i = sa1 / float(_scale_ratio(a_bits) ** i)
        # one MXU dispatch per activation plane: the tw weight planes are
        # stacked along the batch axis of a single dot_general
        p = jax.lax.dot_general(
            jnp.broadcast_to(a[i][None], w.shape[:1] + a[i].shape), w,
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )                                            # (tw, bm, bn) int32
        for jj in range(tw):                         # per-plane scale fold of
            acc = acc + (sa_i * ws[jj]) * p[jj].astype(jnp.float32)
    acc_ref[...] = acc

    @pl.when(kk == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]                    # single HBM write


def _grouped_kernel(x_ref, s_ref, w_ref, ws_ref, o_ref, qa_ref, acc_ref,
                    *, a_bits: int, a_terms: int, tw: int, block_k: int):
    # grid (E, M/bm, N/bn, K/bk): the expert axis rides a leading grid dim;
    # every ref carries a singleton expert-block axis.  The quantize-once
    # scratch caches the (e, m) strip's planes — (e, i) are outer grid dims,
    # so the j == 0 guard re-extracts exactly when the strip changes.
    j = pl.program_id(2)
    kk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kk == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sa1 = s_ref[0, 0]

    @pl.when(j == 0)
    def _extract():
        r = x_ref[0].astype(jnp.float32)
        for i in range(a_terms):             # static unroll, runs in VREGs
            sa_i = sa1 / float(_scale_ratio(a_bits) ** i)
            lo, hi = _plane_limits(a_bits, i)
            q = jnp.clip(jnp.round(r / sa_i), lo, hi)
            r = r - sa_i * q
            qa_ref[i, :, pl.ds(kk * block_k, block_k)] = q.astype(jnp.int8)

    a = qa_ref[:, :, pl.ds(kk * block_k, block_k)]   # (ta, bm, bk) int8
    w = w_ref[0]                                     # (tw, bk, bn) int8
    ws = ws_ref[0]                                   # (tw, bn) f32
    acc = acc_ref[...]
    for i in range(a_terms):
        sa_i = sa1 / float(_scale_ratio(a_bits) ** i)
        p = jax.lax.dot_general(
            jnp.broadcast_to(a[i][None], w.shape[:1] + a[i].shape), w,
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )                                            # (tw, bm, bn) int32
        for jj in range(tw):
            acc = acc + (sa_i * ws[jj]) * p[jj].astype(jnp.float32)
    acc_ref[...] = acc

    @pl.when(kk == nk - 1)
    def _flush():
        o_ref[0] = acc_ref[...]                      # single HBM write


def grouped_series_matmul_pallas(
    x: jnp.ndarray,           # (E, M, K) f32 — centered & clipped per expert
    a_scale1: jnp.ndarray,    # (E,) f32 — independent per-expert quantizers
    w_planes: jnp.ndarray,    # (E, tw, K, N) int8
    w_scales: jnp.ndarray,    # (E, tw, N) f32
    *,
    a_bits: int,
    a_terms: int,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = True,
    dimension_semantics: tuple = ("parallel", "parallel", "arbitrary",
                                  "arbitrary"),
) -> jnp.ndarray:
    """Grouped (stacked-expert) twin of :func:`series_matmul_pallas`: ONE
    autotuned Pallas dispatch whose grid covers the expert axis, instead of
    E per-expert kernel launches — the MoE expert GEMM stays O(terms) in
    dispatch count regardless of E."""
    e, m, k = x.shape
    e2, tw, k2, n = w_planes.shape
    assert e == e2 and k == k2 and w_scales.shape == (e, tw, n), (
        x.shape, w_planes.shape, w_scales.shape)
    assert a_scale1.shape == (e,), a_scale1.shape
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, k, n), (block_m, block_k, block_n))
    grid = (e, m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_grouped_kernel, a_bits=a_bits, a_terms=a_terms,
                          tw=tw, block_k=block_k),
        out_shape=jax.ShapeDtypeStruct((e, m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k), lambda g, i, j, kk: (g, i, kk)),
            pl.BlockSpec((1, 1), lambda g, i, j, kk: (g, 0)),
            pl.BlockSpec((1, tw, block_k, block_n),
                         lambda g, i, j, kk: (g, 0, kk, j)),
            pl.BlockSpec((1, tw, block_n), lambda g, i, j, kk: (g, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda g, i, j, kk: (g, i, j)),
        scratch_shapes=[
            pltpu.VMEM((a_terms, block_m, k), jnp.int8),   # cached act planes
            pltpu.VMEM((block_m, block_n), jnp.float32),   # f32 accumulator
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=dimension_semantics),
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        a_scale1.reshape(e, 1).astype(jnp.float32),
        w_planes,
        w_scales.astype(jnp.float32),
    )


def series_matmul_pallas(
    x: jnp.ndarray,           # (M, K) f32 — centered & clipped activations
    a_scale1: jnp.ndarray,    # () f32
    w_planes: jnp.ndarray,    # (tw, K, N) int8
    w_scales: jnp.ndarray,    # (tw, N) f32
    *,
    a_bits: int,
    a_terms: int,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = True,
    dimension_semantics: tuple = ("parallel", "arbitrary", "arbitrary"),
) -> jnp.ndarray:
    m, k = x.shape
    tw, k2, n = w_planes.shape
    assert k == k2 and w_scales.shape == (tw, n)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, k, n), (block_m, block_k, block_n))
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, a_bits=a_bits, a_terms=a_terms, tw=tw,
                          block_k=block_k),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((tw, block_k, block_n), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((tw, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((a_terms, block_m, k), jnp.int8),   # cached act planes
            pltpu.VMEM((block_m, block_n), jnp.float32),   # f32 accumulator
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=dimension_semantics),
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        a_scale1.reshape(1, 1).astype(jnp.float32),
        w_planes,
        w_scales.astype(jnp.float32),
    )
