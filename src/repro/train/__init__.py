"""Training substrate: optimizers, microbatched/remat train step, and
the synthetic-LM data pipeline (calibration + smoke-training source)."""
from repro.train.optimizer import adamw, adafactor, sgd, OptState
from repro.train.train_step import TrainConfig, make_train_step, loss_fn
from repro.train.data import SyntheticLM, make_host_loader
