"""Deterministic synthetic data pipeline: host-sharded, resumable.

No dataset ships in the container, so the pipeline synthesizes structured
token streams (a learnable order-k Markov language — losses genuinely
decrease, so convergence tests/examples are meaningful, unlike uniform
noise).  Batches are a pure function of (seed, step, host_id): any host can
reconstruct any step — that is what makes checkpoint-restart and elastic
rescaling exact (tests assert bitwise identity across a simulated failure).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Order-1 Markov token source with a deterministic transition table."""
    vocab_size: int
    seq_len: int
    seed: int = 0
    order_temperature: float = 4.0

    def _transition_logits(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 128)  # active vocabulary (rest unused)
        logits = rng.normal(size=(v, v)) * self.order_temperature
        return logits

    def batch(self, step: int, batch_size: int, host_id: int = 0) -> Dict[str, np.ndarray]:
        logits = self._transition_logits()
        v = logits.shape[0]
        probs = np.exp(logits - logits.max(-1, keepdims=True))
        probs /= probs.sum(-1, keepdims=True)
        rng = np.random.default_rng((self.seed, step, host_id))
        toks = np.empty((batch_size, self.seq_len), np.int32)
        toks[:, 0] = rng.integers(0, v, batch_size)
        # vectorized Markov walk via inverse-CDF sampling
        cdf = probs.cumsum(-1)
        u = rng.random((batch_size, self.seq_len - 1))
        for t in range(1, self.seq_len):
            toks[:, t] = (u[:, t - 1, None] < cdf[toks[:, t - 1]]).argmax(-1)
        return {"tokens": toks, "labels": toks.copy()}


def make_batch(cfg: ArchConfig, seq_len: int, batch_size: int, step: int,
               *, seed: int = 0, host_id: int = 0) -> Dict[str, np.ndarray]:
    """Arch-aware batch synthesis (adds modality-stub inputs)."""
    src = SyntheticLM(cfg.vocab_size, seq_len, seed)
    rng = np.random.default_rng((seed + 1, step, host_id))
    if cfg.frame_dim:  # audio: frames + frame labels
        frames = rng.normal(size=(batch_size, seq_len, cfg.frame_dim)).astype(np.float32)
        labels = rng.integers(0, cfg.vocab_size, (batch_size, seq_len)).astype(np.int32)
        return {"frames": frames, "labels": labels}
    b = src.batch(step, batch_size, host_id)
    if cfg.num_image_tokens:
        b["image_emb"] = rng.normal(
            size=(batch_size, cfg.num_image_tokens, cfg.image_embed_dim)).astype(np.float32)
    return b


def make_host_loader(cfg: ArchConfig, seq_len: int, global_batch: int,
                     *, num_hosts: int = 1, host_id: int = 0, seed: int = 0,
                     start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Host-sharded loader: each host yields its slice of the global batch.
    Resume by passing ``start_step`` (from the checkpoint) — deterministic."""
    assert global_batch % num_hosts == 0
    per_host = global_batch // num_hosts
    step = start_step
    while True:
        yield make_batch(cfg, seq_len, per_host, step, seed=seed, host_id=host_id)
        step += 1
