"""Sharded-state optimizers, hand-rolled (no optax in the container).

* ``adamw`` — AdamW with optionally bf16 first/second moments (halves
  optimizer HBM — the default for the >100B dry-run cells) and an fp32
  update path (moments are upcast per step).
* ``adafactor`` — factored second moment (row/col statistics) for the
  340B-class cells where even bf16 Adam moments don't fit.
* ``sgd`` — momentum SGD (baseline/debug).

All follow the same functional contract:

    opt = adamw(lr=..., ...)
    state = opt.init(params)
    params, state = opt.update(grads, params, state)

States are pytrees mirroring the param tree — they shard with the same
PartitionSpec rules as their parameters (dist/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple]


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: PyTree


def _tmap(f, *trees, **kw):
    return jax.tree_util.tree_map(f, *trees, **kw)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return _tmap(lambda x: x * scale, grads)


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=jnp.float32,
          schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return OptState(jnp.zeros((), jnp.int32), {"m": _tmap(zeros, params), "v": _tmap(zeros, params)})

    def update(grads, params, state):
        step = state.step + 1
        lr_t = lr * (schedule(step) if schedule else 1.0)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, p, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            return p_new, m32.astype(moment_dtype), v32.astype(moment_dtype)

        out = _tmap(upd, grads, params, state.inner["m"], state.inner["v"])
        params_new = _tmap(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m_new = _tmap(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v_new = _tmap(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return params_new, OptState(step, {"m": m_new, "v": v_new})

    return Optimizer(init, update)


def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018) — O(row+col)
    state for matrices; full state for vectors."""
    def init(params):
        def zero(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return OptState(jnp.zeros((), jnp.int32),
                        _tmap(zero, params))

    def update(grads, params, state):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, p, s):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if g.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
                u = g32 * jax.lax.rsqrt(vhat + eps)
                s_new = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v + eps)
                s_new = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), s_new

        # params first: its leaves are arrays, so the factored-stat dicts in
        # state.inner are passed whole to upd (never mistaken for subtrees)
        out = _tmap(lambda p, g, s: upd(g, p, s), params, grads, state.inner)
        params_new = _tmap(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        s_new = _tmap(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return params_new, OptState(step, s_new)

    return Optimizer(init, update)


def sgd(lr: float = 1e-2, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(grads, params, state):
        m = _tmap(lambda mo, g: momentum * mo + g.astype(jnp.float32), state.inner, grads)
        params_new = _tmap(lambda p, mo: (p.astype(jnp.float32) - lr * mo).astype(p.dtype), params, m)
        return params_new, OptState(state.step + 1, m)

    return Optimizer(init, update)


def warmup_cosine(warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(1.0, float(warmup))
        frac = (s - warmup) / jnp.maximum(1.0, float(total - warmup))
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(frac, 0, 1)))
        return jnp.where(s < warmup, warm, cos)
    return sched


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}[name](**kw)
