"""Training step: microbatched grad accumulation + remat + clip + optimizer.

The canonical step (used by the dry-run and launch/train.py):

  * split the global batch into ``grad_accum`` microbatches (scan),
  * per-microbatch forward/backward with per-stage remat
    (``forward(..., remat=True)`` checkpoints each scanned stage),
  * mean-accumulate grads in fp32,
  * optional residual-series gradient compression (dist/compression.py)
    applied to the accumulated grads before the optimizer — the paper's own
    Theorem 1 reused as a comms compressor (beyond-paper),
  * global-norm clip + optimizer update.

Under pjit the whole step is one XLA program: FSDP all-gathers, reduce-
scatters, and the microbatch scan schedule all show up in the dry-run HLO
that §Roofline parses.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models.layers import FP, QuantContext
from repro.train import optimizer as OPT

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    lr: float = 3e-4
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    grad_accum: int = 1              # microbatches per step
    remat: bool = True
    moment_dtype: str = "bfloat16"   # adam moments (HBM saver at scale)
    compress_grads: bool = False     # residual-series int8 all-reduce
    compress_bits: int = 8
    compress_terms: int = 1
    z_loss: float = 0.0


def make_optimizer(tc: TrainConfig):
    if tc.optimizer == "adamw":
        return OPT.adamw(lr=tc.lr, weight_decay=tc.weight_decay,
                         moment_dtype=jnp.bfloat16 if tc.moment_dtype == "bfloat16" else jnp.float32)
    if tc.optimizer == "adafactor":
        return OPT.adafactor(lr=tc.lr, weight_decay=tc.weight_decay)
    return OPT.sgd(lr=tc.lr)


def loss_fn(params: PyTree, batch: Dict, cfg: ArchConfig, qc: QuantContext = FP,
            *, remat: bool = False, z_loss: float = 0.0,
            act_constraint=None) -> Tuple[jnp.ndarray, Dict]:
    """Next-token (decoder) or frame-label (encoder) cross entropy."""
    logits = M.forward(params, batch, cfg, qc, remat=remat,
                       act_constraint=act_constraint)            # (B, S, V)
    labels = batch["labels"]
    if not cfg.is_encoder:
        logits = logits[:, :-1, :]
        labels = labels[:, 1:]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # vocab-sharding-safe label pick: fused compare-select-reduce instead of
    # take_along_axis (which would all-gather a model-sharded vocab axis)
    v = logits.shape[-1]
    onehot = (labels[..., None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, v), 2))
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    ll = picked - logz
    loss = -jnp.mean(ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(logz))
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "accuracy": acc}


def _microbatch(batch: Dict, n: int) -> Dict:
    """(B, ...) -> (n, B//n, ...) for every leaf."""
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def make_train_step(cfg: ArchConfig, tc: TrainConfig, qc: QuantContext = FP,
                    compressor: Optional[Callable[[PyTree], PyTree]] = None,
                    act_constraint=None):
    """Returns (opt, train_step) with
    train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``tc.compress_grads`` the error-feedback buffer is carried *inside*
    the optimizer state (functional — safe under jit/donation); ``opt.init``
    is wrapped accordingly."""
    opt = make_optimizer(tc)

    def grad_one(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, mb, cfg, qc, remat=tc.remat, z_loss=tc.z_loss,
                              act_constraint=act_constraint),
            has_aux=True)(params)
        return grads, metrics

    def accumulate_grads(params, batch):
        if tc.grad_accum > 1:
            mbs = _microbatch(batch, tc.grad_accum)

            def body(acc, mb):
                grads, metrics = grad_one(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / tc.grad_accum, acc, grads)
                return acc, metrics

            zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics = jax.lax.scan(body, zeros, mbs)
            metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m), metrics)
        else:
            grads, metrics = grad_one(params, batch)
        return grads, metrics

    def finish(params, opt_state, grads, metrics):
        if tc.grad_clip:
            grads = OPT.clip_by_global_norm(grads, tc.grad_clip)
        metrics = dict(metrics, grad_norm=OPT.global_norm(grads))
        params, opt_state = opt.update(grads, params, opt_state)
        return params, opt_state, metrics

    if tc.compress_grads and compressor is None:
        from repro.dist.compression import CompressionConfig, make_compressor
        cc = CompressionConfig(bits=tc.compress_bits, terms=tc.compress_terms)

        def opt_init_with_err(params):
            init_err, _ = make_compressor(params, cc)
            return {"opt": opt.init(params), "err": init_err()}

        def train_step_c(params, state, batch):
            _, compress = make_compressor(params, cc)
            grads, metrics = accumulate_grads(params, batch)
            grads, err_new = compress(grads, state["err"])
            params2, opt_state2, metrics = finish(params, state["opt"], grads, metrics)
            return params2, {"opt": opt_state2, "err": err_new}, metrics

        return opt._replace(init=opt_init_with_err), train_step_c

    def train_step(params, opt_state, batch):
        grads, metrics = accumulate_grads(params, batch)
        if compressor is not None:
            grads = compressor(grads)
        return finish(params, opt_state, grads, metrics)

    return opt, train_step
