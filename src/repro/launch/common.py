"""Shared serving-launcher plumbing.

``launch/serve.py`` and the doc examples (``examples/serve_expanded.py``)
previously each hand-rolled the same argparse → :class:`ServeConfig` →
mesh wiring; this module is the single builder both use (and the one place
the flags are defined — documented in ``docs/api.md``):

* :func:`add_serve_args` — the scheduler/capacity/mesh/QoS/chaos flag set;
* :func:`serve_config_from_args` — flags → ``ServeConfig``;
* :func:`mesh_from_args` — ``--mesh``/``--placement`` → a 1-D serving mesh
  (or ``(None, "replicated")``), validating fake-device counts early with
  an actionable ``XLA_FLAGS`` hint;
* :func:`submit_with_backoff` — the client half of typed backpressure:
  retries retryable :class:`~repro.infer.qos.Rejection` results with
  bounded exponential backoff.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Tuple


def add_serve_args(ap: argparse.ArgumentParser, *,
                   max_batch_default: int = 8) -> argparse.ArgumentParser:
    """Register the shared serving flags on ``ap`` (see docs/api.md)."""
    ap.add_argument("--max-new", type=int, default=16,
                    help="run-level generation budget per request")
    ap.add_argument("--max-seq", type=int, default=64,
                    help="decode capacity (KV cache length)")
    ap.add_argument("--scheduler", default="slots", choices=("slots", "grouped"),
                    help="slots = continuous batching (per-slot cache lengths, "
                         "prefill-into-slot); grouped = legacy group-drain")
    ap.add_argument("--max-batch", type=int, default=max_batch_default,
                    help="grouped batch size / default slot-pool size")
    ap.add_argument("--max-slots", type=int, default=0,
                    help="decode slot pool size (0 = --max-batch), capped by "
                         "--hbm-budget admission control")
    ap.add_argument("--hbm-budget", type=float, default=0.0,
                    help="per-device HBM bytes for params + KV caches; >0 "
                         "caps the slot pool via kvcache.max_batch_for_hbm")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (a dynamic operand: changing it never "
                         "retraces the decode step)")
    ap.add_argument("--spec-terms", type=int, default=0,
                    help="self-speculative decoding (DESIGN.md §10): draft "
                         "with the first K series terms of the expanded "
                         "weights, verify with the full series (greedy "
                         "output stays token-identical). 0 = off; needs "
                         "--scheduler slots and an expanded (fpxint) model")
    ap.add_argument("--spec-lookahead", type=int, default=4,
                    help="draft tokens per speculative round (gamma)")
    ap.add_argument("--term-budget", type=int, default=0,
                    help="statically truncate the served series to the "
                         "first K terms (Theorem 1 prefix coherence); "
                         "0 = the artifact's full series")
    ap.add_argument("--tiers", default="",
                    help="QoS tier ladder 'name:budget,...' (e.g. "
                         "'k2:2,k1:1'); '' = the engine's default ladder "
                         "(expanded slot engines), 'none' = quality='full' "
                         "only (DESIGN.md §11)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue: a full queue returns a "
                         "retryable CAPACITY Rejection instead of growing "
                         "without bound (0 = unbounded)")
    ap.add_argument("--no-degrade", action="store_true",
                    help="disable load-adaptive term-budget degradation "
                         "(degradable tiers then always run their nominal "
                         "budget)")
    ap.add_argument("--chaos", action="store_true",
                    help="enable the seeded fault-injection harness "
                         "(deterministic latency spikes / transient dispatch "
                         "failures / HBM squeezes; see --chaos-*)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="chaos RNG seed (same seed = same fault schedule)")
    ap.add_argument("--chaos-latency-p", type=float, default=0.0,
                    help="per-dispatch probability of an injected latency "
                         "spike")
    ap.add_argument("--chaos-latency-s", type=float, default=0.02,
                    help="injected latency spike duration (seconds)")
    ap.add_argument("--chaos-fail-p", type=float, default=0.0,
                    help="per-dispatch probability of a transient "
                         "ChaosFailure (retried up to --chaos-max-retries)")
    ap.add_argument("--chaos-max-retries", type=int, default=3,
                    help="dispatch retries before a ChaosFailure is fatal")
    ap.add_argument("--chaos-squeeze", default="",
                    help="artificial HBM-budget squeeze 'start:steps:frac' "
                         "in scheduler rounds (e.g. '4:6:0.5' halves the "
                         "effective budget for rounds 4..9)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="serve over the first N local devices (0 = single "
                         "device unless --placement is sharded, then all)")
    ap.add_argument("--placement", default="replicated",
                    choices=("replicated", "term", "tensor", "expert"),
                    help="multi-device placement (DESIGN.md §9/§15): term = "
                         "Theorem-2 series-term scattering (shard_map + one "
                         "psum per expanded GEMM); tensor = column-parallel; "
                         "expert = MoE expert parallelism (stacked expert "
                         "expansions sharded, int32 psum; moe_attn archs); "
                         "replicated = single-device behavior")
    return ap


def _parse_tiers(spec: str):
    """``--tiers`` → ``ServeConfig.tier_budgets``: ``''`` = None (engine
    default ladder), ``'none'`` = () (full only), else ``'name:budget,...'``."""
    s = spec.strip()
    if not s:
        return None
    if s.lower() == "none":
        return ()
    out = []
    for part in s.split(","):
        try:
            name, budget = part.split(":")
            out.append((name.strip(), int(budget)))
        except ValueError:
            raise SystemExit(
                f"--tiers expects 'name:budget,...' (e.g. 'k2:2,k1:1'); "
                f"could not parse {part!r}") from None
    return tuple(out)


def _chaos_from_args(args):
    """``--chaos*`` flags → :class:`repro.infer.qos.ChaosConfig` (or None)."""
    if not getattr(args, "chaos", False):
        return None
    from repro.infer.qos import ChaosConfig

    start, steps, frac = -1, 0, 0.5
    if args.chaos_squeeze:
        try:
            s_start, s_steps, s_frac = args.chaos_squeeze.split(":")
            start, steps, frac = int(s_start), int(s_steps), float(s_frac)
        except ValueError:
            raise SystemExit(
                f"--chaos-squeeze expects 'start:steps:frac' (e.g. "
                f"'4:6:0.5'); got {args.chaos_squeeze!r}") from None
    return ChaosConfig(seed=args.chaos_seed,
                       latency_p=args.chaos_latency_p,
                       latency_s=args.chaos_latency_s,
                       fail_p=args.chaos_fail_p,
                       max_retries=args.chaos_max_retries,
                       hbm_squeeze_start=start,
                       hbm_squeeze_steps=steps,
                       hbm_squeeze_frac=frac)


def serve_config_from_args(args):
    """Build the :class:`repro.infer.serve.ServeConfig` the shared flags
    describe (capacity knobs are fixed at engine construction)."""
    from repro.infer.qos import DegradeConfig
    from repro.infer.serve import ServeConfig

    return ServeConfig(
        max_seq=args.max_seq,
        max_batch=args.max_batch,
        temperature=args.temperature,
        scheduler=args.scheduler,
        max_slots=args.max_slots,
        hbm_budget_bytes=args.hbm_budget,
        spec_terms=args.spec_terms,
        spec_lookahead=args.spec_lookahead,
        term_budget=args.term_budget or None,
        tier_budgets=_parse_tiers(args.tiers),
        max_queue=args.max_queue,
        degrade=DegradeConfig(enabled=not args.no_degrade),
        chaos=_chaos_from_args(args),
    )


def submit_with_backoff(engine, tokens, *, max_attempts: int = 5,
                        max_delay_s: float = 1.0, sleep=time.sleep,
                        **request_kw):
    """Client half of the typed backpressure contract: submit a request,
    retrying retryable :class:`~repro.infer.qos.Rejection` results
    (CAPACITY / HBM) with bounded exponential backoff.

    Returns the request id on success, or the last ``Rejection`` once
    attempts are exhausted / the rejection is non-retryable
    (DEADLINE_INFEASIBLE) — callers branch on ``isinstance(..., Rejection)``
    exactly as for a plain ``add_request``.  ``sleep`` is injectable so
    tests (and the chaos harness) run without wall-clock waits."""
    from repro.infer.qos import Rejection

    res = None
    for attempt in range(max(1, int(max_attempts))):
        res = engine.add_request(tokens, **request_kw)
        if not isinstance(res, Rejection) or not res.retryable:
            return res
        if attempt + 1 < max_attempts:
            sleep(min(max(res.retry_after_s, 0.0) * (2 ** attempt),
                      max_delay_s))
    return res


def mesh_from_args(args) -> Tuple[Optional[object], str]:
    """``(mesh, placement)`` from ``--mesh``/``--placement``.

    Replicated with ``--mesh 0`` stays mesh-less (today's single-device
    path); a sharded placement builds the 1-D mesh with the axis name its
    collectives expect (``"expand"`` for term, ``"model"`` for tensor,
    ``"expert"`` for MoE expert parallelism)."""
    from repro.dist.placement import make_serve_mesh

    if args.placement == "replicated" and not args.mesh:
        return None, "replicated"
    return make_serve_mesh(args.mesh, args.placement), args.placement
