"""Shared serving-launcher plumbing.

``launch/serve.py`` and the doc examples (``examples/serve_expanded.py``)
previously each hand-rolled the same argparse → :class:`ServeConfig` →
mesh wiring; this module is the single builder both use (and the one place
the flags are defined — documented in ``docs/api.md``):

* :func:`add_serve_args` — the scheduler/capacity/mesh flag set;
* :func:`serve_config_from_args` — flags → ``ServeConfig``;
* :func:`mesh_from_args` — ``--mesh``/``--placement`` → a 1-D serving mesh
  (or ``(None, "replicated")``), validating fake-device counts early with
  an actionable ``XLA_FLAGS`` hint.
"""
from __future__ import annotations

import argparse
from typing import Optional, Tuple


def add_serve_args(ap: argparse.ArgumentParser, *,
                   max_batch_default: int = 8) -> argparse.ArgumentParser:
    """Register the shared serving flags on ``ap`` (see docs/api.md)."""
    ap.add_argument("--max-new", type=int, default=16,
                    help="run-level generation budget per request")
    ap.add_argument("--max-seq", type=int, default=64,
                    help="decode capacity (KV cache length)")
    ap.add_argument("--scheduler", default="slots", choices=("slots", "grouped"),
                    help="slots = continuous batching (per-slot cache lengths, "
                         "prefill-into-slot); grouped = legacy group-drain")
    ap.add_argument("--max-batch", type=int, default=max_batch_default,
                    help="grouped batch size / default slot-pool size")
    ap.add_argument("--max-slots", type=int, default=0,
                    help="decode slot pool size (0 = --max-batch), capped by "
                         "--hbm-budget admission control")
    ap.add_argument("--hbm-budget", type=float, default=0.0,
                    help="per-device HBM bytes for params + KV caches; >0 "
                         "caps the slot pool via kvcache.max_batch_for_hbm")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (a dynamic operand: changing it never "
                         "retraces the decode step)")
    ap.add_argument("--spec-terms", type=int, default=0,
                    help="self-speculative decoding (DESIGN.md §10): draft "
                         "with the first K series terms of the expanded "
                         "weights, verify with the full series (greedy "
                         "output stays token-identical). 0 = off; needs "
                         "--scheduler slots and an expanded (fpxint) model")
    ap.add_argument("--spec-lookahead", type=int, default=4,
                    help="draft tokens per speculative round (gamma)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="serve over the first N local devices (0 = single "
                         "device unless --placement is sharded, then all)")
    ap.add_argument("--placement", default="replicated",
                    choices=("replicated", "term", "tensor"),
                    help="multi-device placement (DESIGN.md §9): term = "
                         "Theorem-2 series-term scattering (shard_map + one "
                         "psum per expanded GEMM); tensor = column-parallel; "
                         "replicated = single-device behavior")
    return ap


def serve_config_from_args(args):
    """Build the :class:`repro.infer.serve.ServeConfig` the shared flags
    describe (capacity knobs are fixed at engine construction)."""
    from repro.infer.serve import ServeConfig

    return ServeConfig(
        max_seq=args.max_seq,
        max_batch=args.max_batch,
        temperature=args.temperature,
        scheduler=args.scheduler,
        max_slots=args.max_slots,
        hbm_budget_bytes=args.hbm_budget,
        spec_terms=args.spec_terms,
        spec_lookahead=args.spec_lookahead,
    )


def mesh_from_args(args) -> Tuple[Optional[object], str]:
    """``(mesh, placement)`` from ``--mesh``/``--placement``.

    Replicated with ``--mesh 0`` stays mesh-less (today's single-device
    path); a sharded placement builds the 1-D mesh with the axis name its
    collectives expect (``"expand"`` for term, ``"model"`` for tensor)."""
    from repro.dist.placement import make_serve_mesh

    if args.placement == "replicated" and not args.mesh:
        return None, "replicated"
    return make_serve_mesh(args.mesh, args.placement), args.placement
