"""Loop-aware cost model over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — useless for
scan-based models (a 96-layer stack scans one stage 96x, a train step scans
grad_accum microbatches).  This module re-derives per-device totals by
walking the computation call graph and multiplying by known trip counts
(``backend_config={"known_trip_count":{"n":...}}``, present for lax.scan):

  * flops        — 2 * prod(dot output dims) * prod(contracted dims) per
                   ``dot`` (GEMMs dominate; elementwise flops are not
                   counted — noted in EXPERIMENTS.md);
  * bytes        — per top-level instruction: output bytes + operand bytes
                   (post-fusion buffer traffic ≈ HBM bytes); control-flow
                   plumbing (tuples, parameters, bitcasts) excluded;
  * collectives  — output bytes per op kind, trip-multiplied, with replica
                   group sizes for ring-factor adjustment;
  * int_dot_flops — the subset of flops whose operands are integer (the
                   MXU int8 path: credited at 2x peak in the dtype-aware
                   roofline);
  * Pallas/Mosaic custom-calls — on a real TPU the fused kernels appear as
                   opaque ``custom-call`` instructions whose internal dots
                   XLA cannot see.  Their GEMM flops are re-derived from the
                   operand shapes (the series kernel runs ta*tw int8 plane
                   GEMMs internally; the W4A16 kernel one f32 GEMM over the
                   scale-summed planes).  Their HBM bytes need no special
                   casing: operand + output bytes IS the single-pass traffic
                   (VMEM scratch accumulation, one output write — see
                   kernels/series_matmul.py and DESIGN.md §3).

Cross-checked against analytic FLOPs in benchmarks/roofline.py (which also
carries the matching analytic traffic model for the kernels themselves).
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Any, Dict, List, Optional, Tuple

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}
_INT_TYPES = {"s8", "u8", "s16", "u16", "s32", "u32"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_MOSAIC_TARGETS = ("tpu_custom_call", "mosaic", "Mosaic")
# The series kernel quantizes activations *inside* the kernel, so the term
# count ta is invisible in HLO operand shapes; default matches the W4A4 /
# Fig-4b operating point and is overridable for other policies.
A_TERMS_HINT = int(os.environ.get("REPRO_A_TERMS_HINT", "3"))
_SKIP_BYTES_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota", "while", "conditional", "call",
                   # pure dtype converts: CPU-backend artifacts (no native
                   # bf16 GEMM); on the TPU target these do not exist —
                   # operand lookups resolve THROUGH converts to the source
                   # dtype instead (TPU-faithful accounting)
                   "convert"}


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _pallas_kernel_flops(operand_dims: List[Tuple[str, List[int]]],
                         a_terms_hint: int = A_TERMS_HINT) -> Tuple[float, float]:
    """(flops, int_dot_flops) for one Mosaic custom-call, from operand shapes.

    Shape signatures (see kernels/*.py):
      series_matmul   x f32(M,K), scale f32(1,1), planes s8(tw,K,N),
                      scales f32(tw,N)            -> ta*tw int8 plane GEMMs
      dequant_matmul  x f32(M,K), packed s8(tw,K,N/2), scales f32(tw,N)
                      (N == 2 * packed N)         -> one f32 GEMM per block
      residual_quantize  x f32(M,N), scale f32(1,1) -> elementwise, no dots
    """
    f32_2d = [d for t, d in operand_dims if t in ("f32", "bf16") and len(d) == 2]
    s8_3d = [d for t, d in operand_dims if t == "s8" and len(d) == 3]
    if not s8_3d:
        return 0.0, 0.0                      # residual_quantize / unknown
    planes = s8_3d[0]
    tw, k_w, n_w = planes
    acts = [d for d in f32_2d if d[1] == k_w and d != [1, 1]]
    if not acts:
        return 0.0, 0.0
    m = acts[0][0]
    scales = [d for d in f32_2d if d[0] == tw]
    if scales and scales[0][1] == 2 * n_w:   # packed INT4 weight-only path
        return 2.0 * m * (2 * n_w) * k_w, 0.0
    f = 2.0 * m * n_w * k_w * tw * a_terms_hint
    return f, f                              # int8 plane GEMMs on the MXU


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    int_dot_flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    calls: List[Tuple[str, float, str]] = dataclasses.field(default_factory=list)  # (callee, trips, kind)


# out-type is either a tuple "(...)" (may contain /*index=N*/ comments but
# never parens) or a single shape token
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)(?:\.\d+)?\(")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)(?:\.\d+)?\s*\(.*\)\s*->.*{")


def parse_hlo(text: str) -> Tuple[Dict[str, CompStats], Dict[str, str], str]:
    """Returns (computations, symbol->type map per comp merged, entry name)."""
    comps: Dict[str, CompStats] = {}
    entry = ""
    cur: Optional[str] = None
    cur_stats: Optional[CompStats] = None
    symbols: Dict[str, str] = {}
    convert_src: Dict[str, str] = {}  # convert output name -> source operand

    def _resolve_type(name: str, depth: int = 0) -> str:
        while name in convert_src and depth < 8:
            name = convert_src[name]
            depth += 1
        return symbols.get(name, "")

    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                if line.lstrip().startswith("ENTRY"):
                    entry = cur
                cur_stats = comps.setdefault(cur, CompStats())
                # parameters declared in the signature: name: type
                for pm in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^)]*\))|(?:\S+?))(?:,|\)\s*->)", line):
                    symbols[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            cur = None
            cur_stats = None
            continue

        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, out_type, op = m.group(1), m.group(2), m.group(3)
        symbols[name] = out_type
        s = cur_stats
        assert s is not None
        if op == "convert":
            # first %name after the op's paren is the source operand (inline
            # operand types carry no %; metadata parens like op_name="jit(f)"
            # must not match)
            om = re.search(r"%([\w.\-]+)", line[line.index("("):])
            if om:
                convert_src[name] = om.group(1)

        # --- call edges ---
        if op == "while":
            body = re.search(r"body=%?([\w.\-]+)", line)
            trips = 1.0
            tm = re.search(r'"known_trip_count":\{"n":"?(\d+)"?\}', line)
            if tm:
                trips = float(tm.group(1))
            if body:
                s.calls.append((body.group(1), trips, "while"))
            cond = re.search(r"condition=%?([\w.\-]+)", line)
            if cond:
                s.calls.append((cond.group(1), trips, "while"))
            continue
        if op == "fusion":
            cm = re.search(r"calls=%?([\w.\-]+)", line)
            if cm:
                # fusion internals are registers, not HBM: flops-only edge
                s.calls.append((cm.group(1), 1.0, "fusion"))
        if op == "conditional":
            for cm in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)([^,}]+)", line):
                for name2 in re.findall(r"%?([\w.\-]+)", cm.group(1)):
                    s.calls.append((name2, 1.0, "cond"))
        if op == "call":
            cm = re.search(r"to_apply=%?([\w.\-]+)", line)
            if cm:
                s.calls.append((cm.group(1), 1.0, "call"))

        # --- collectives (by op name) ---
        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in _COLLECTIVES:
            b = _shape_bytes(out_type)
            g = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            group = int(g.group(2)) if g else 0
            d = s.collectives.setdefault(base_op, {"bytes": 0.0, "count": 0.0, "group": 0.0})
            d["bytes"] += b
            d["count"] += 1
            d["group"] = max(d["group"], group)

        # --- dot flops ---
        if op == "dot":
            out = _shape_dims(out_type)
            # operands may print bare (%x) or with inline types
            # (f32[..]{1,0} %x): take the first %name that is a known symbol
            opnds = [om.group(1) for om in
                     re.finditer(r"%([\w.\-]+)", line[line.index("("):])
                     if om.group(1) in symbols]
            lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            if out and opnds and lc:
                lhs_type = _resolve_type(opnds[0])
                lhs = _shape_dims(lhs_type)
                if lhs:
                    contract = 1
                    for i in [int(x) for x in lc.group(1).split(",") if x]:
                        if i < len(lhs[1]):
                            contract *= lhs[1][i]
                    n_out = 1
                    for d_ in out[1]:
                        n_out *= d_
                    f = 2.0 * n_out * contract
                    s.flops += f
                    if lhs[0] in _INT_TYPES:
                        s.int_dot_flops += f
        if op == "custom-call" and any(t in line for t in _MOSAIC_TARGETS):
            operand_dims = []
            for om in re.finditer(r"%([\w.\-]+)", line[line.index("("):]):
                if om.group(1) in symbols:
                    d = _shape_dims(_resolve_type(om.group(1)))
                    if d:
                        operand_dims.append((d[0], d[1]))
            f, fi = _pallas_kernel_flops(operand_dims)
            s.flops += f
            s.int_dot_flops += fi
        if op in ("exponential", "tanh", "log", "rsqrt", "power", "logistic"):
            out = _shape_dims(out_type)
            if out:
                n_out = 1
                for d_ in out[1]:
                    n_out *= d_
                s.transcendentals += n_out

        # --- bytes ---
        if op not in _SKIP_BYTES_OPS:
            operands = [om.group(1) for om in
                        re.finditer(r"%([\w.\-]+)", line[line.index("("):])
                        if om.group(1) in symbols]
            if op == "dynamic-update-slice" or (
                    op == "fusion" and "dynamic_update_slice" in line):
                # in-place buffer update (aliased): traffic = the update slice
                # (read + write), NOT the whole cache buffer.  Ignore index
                # scalars when picking the update operand.
                op_bytes = [b_ for o in operands
                            if (b_ := _shape_bytes(_resolve_type(o))) >= 256]
                b = 2.0 * (min(op_bytes) if op_bytes else _shape_bytes(out_type))
            else:
                b = _shape_bytes(out_type)
                for o in operands:
                    b += _shape_bytes(_resolve_type(o))
            s.bytes += b
    return comps, symbols, entry


def top_contributors(text: str, k: int = 20) -> List[Tuple[float, str, str]]:
    """(bytes*trips, computation, op-metadata) for the k heaviest instruction
    groups — the hillclimb's 'profile'.  Trips are accumulated down the call
    graph; instructions are grouped by (computation, op, out_type)."""
    comps, symbols, entry = parse_hlo(text)
    # effective trip multiplier per computation
    mult: Dict[str, float] = {entry: 1.0}
    changed = True
    guard = 0
    while changed and guard < 64:
        changed = False
        guard += 1
        for name, s in comps.items():
            m = mult.get(name)
            if m is None:
                continue
            for callee, trips, kind in s.calls:
                new = m * trips
                if mult.get(callee, 0.0) < new:
                    mult[callee] = new
                    changed = True
    groups: Dict[Tuple[str, str, str], float] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m or cur not in mult:
            continue
        name, out_type, op = m.group(1), m.group(2), m.group(3)
        if op in _SKIP_BYTES_OPS:
            continue
        b = _shape_bytes(out_type)
        for om in re.finditer(r"%([\w.\-]+)", line[line.index("("):]):
            if om.group(1) in symbols:
                b += _shape_bytes(symbols[om.group(1)])
        meta = ""
        mm = re.search(r'op_name="([^"]+)"', line)
        if mm:
            meta = mm.group(1)[-80:]
        key = (cur, f"{op} {out_type[:48]}", meta)
        groups[key] = groups.get(key, 0.0) + b * mult[cur]
    ranked = sorted(((v, f"{c} x{mult[c]:.0f}", f"{o} | {meta}")
                     for (c, o, meta), v in groups.items()), reverse=True)
    return ranked[:k]


def total_costs(text: str) -> Dict[str, Any]:
    """Walk the call graph from ENTRY with trip multiplication."""
    comps, _, entry = parse_hlo(text)
    memo: Dict[str, Dict[str, Any]] = {}

    def walk(name: str, depth: int = 0) -> Dict[str, Any]:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return {"flops": 0.0, "int_dot_flops": 0.0, "bytes": 0.0,
                    "transcendentals": 0.0, "collectives": {}}
        s = comps[name]
        out = {"flops": s.flops, "int_dot_flops": s.int_dot_flops,
               "bytes": s.bytes, "transcendentals": s.transcendentals,
               "collectives": {k: dict(v) for k, v in s.collectives.items()}}
        for callee, trips, kind in s.calls:
            sub = walk(callee, depth + 1)
            out["flops"] += trips * sub["flops"]
            out["int_dot_flops"] += trips * sub["int_dot_flops"]
            out["transcendentals"] += trips * sub["transcendentals"]
            if kind != "fusion":  # fusion internals never touch HBM
                out["bytes"] += trips * sub["bytes"]
            for k, v in sub["collectives"].items():
                d = out["collectives"].setdefault(k, {"bytes": 0.0, "count": 0.0, "group": 0.0})
                d["bytes"] += trips * v["bytes"]
                d["count"] += trips * v["count"]
                d["group"] = max(d["group"], v["group"])
        memo[name] = out
        return out

    return walk(entry)


def collective_dtype_census(text: str) -> List[Dict[str, str]]:
    """Every collective instruction in the HLO with its element dtype:
    ``[{"op", "dtype", "computation", "line"}, ...]``.

    The HLO-side cross-check of the integer-domain psum rule: the jaxpr
    walker (:func:`repro.analysis.check_integer_psum`) polices what was
    *written*; this sees what XLA actually *lowered* — SPMD partitioning can
    introduce collectives no jaxpr equation shows."""
    out: List[Dict[str, str]] = []
    cur = "?"
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.strip()
        m = _COMP_START_RE.match(line)
        if m and raw.rstrip().endswith("{"):
            cur = m.group(1)
            continue
        for coll in _COLLECTIVES:
            if re.search(rf"=\s*(?:\([^)]*\)|\S+)\s+{coll}\(", line):
                sd = _shape_dims(line.split("=", 1)[1])
                out.append({"op": coll, "dtype": sd[0] if sd else "?",
                            "computation": cur, "line": str(lineno)})
                break
    return out


def check_integer_collectives(text: str, *,
                              kinds: Tuple[str, ...] = ("all-reduce",)
                              ) -> List[Dict[str, str]]:
    """The collectives of ``kinds`` whose element type is NOT integer —
    empty on a computation honoring the integer-domain reduction contract.
    Returns the offending census rows (op/dtype/computation/line)."""
    return [row for row in collective_dtype_census(text)
            if row["op"] in kinds and row["dtype"] not in _INT_TYPES]
