"""Serving launcher: quantize per recipe (or load a saved artifact) and
serve batched requests through the unified Recipe -> Artifact -> Runtime API.

``python -m repro.launch.serve --arch qwen2_1_5b --smoke --policy w4a4``

Artifact round-trip (expand once, serve forever):

``... --save-artifact /tmp/qwen_w4a4``   quantize, save, then serve
``... --artifact /tmp/qwen_w4a4``        load a pre-built artifact; no
                                         re-expansion at admission

Prints quantization time (the paper's Table 2/3 metric), per-request
generations for a synthetic batch, and decode throughput.

Scheduling: ``--scheduler slots`` (default) serves with slot-based
continuous batching — ``--max-slots`` sizes the decode pool and
``--hbm-budget`` caps it by per-device admission control; ``--scheduler
grouped`` keeps the legacy equal-length group-drain path.
``--mixed-lengths`` draws variable prompt lengths to exercise
prefill-into-slot.

Multi-device (DESIGN.md §9): ``--placement term --mesh 4`` serves with the
series terms scattered over 4 devices (Theorem-2 expansion parallelism,
one psum per expanded GEMM); ``--placement tensor`` is column-parallel.
On this CPU container prefix the run with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for fake devices.

The flag set is shared with the examples via ``launch/common.py`` and
documented in ``docs/api.md``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import QuantArtifact, QuantRecipe, Runtime, list_methods
from repro.configs.base import ARCH_IDS, get_arch
from repro.core.policy import get_policy
from repro.infer.serve import Engine
from repro.infer.qos import Rejection
from repro.launch.common import (add_serve_args, mesh_from_args,
                                 serve_config_from_args, submit_with_backoff)
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="w4a4")
    ap.add_argument("--method", default="fpxint", choices=list_methods())
    ap.add_argument("--backend", default="ref",
                    choices=("ref", "pallas", "pallas-packed"))
    ap.add_argument("--pack", action="store_true",
                    help="INT4-pack weight planes (w_bits <= 4)")
    ap.add_argument("--fp", action="store_true", help="serve unquantized")
    ap.add_argument("--artifact", default=None,
                    help="load a saved artifact instead of quantizing")
    ap.add_argument("--save-artifact", default=None,
                    help="save the quantized artifact here before serving")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="draw prompt lengths in [4, --prompt-len] instead of "
                         "a fixed length (exercises continuous batching)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quality", default="full",
                    help="QoS tier for the synthetic requests; 'mix' "
                         "round-robins the engine's tier table (DESIGN.md "
                         "§11)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline in seconds (0 = none); "
                         "expired requests are cancelled and their slots "
                         "recycled mid-run")
    add_serve_args(ap, max_batch_default=0)   # 0 -> --requests below
    args = ap.parse_args(argv)
    args.max_batch = args.max_batch or args.requests

    cfg = get_arch(args.arch, smoke=args.smoke)
    assert not cfg.is_encoder, "encoder-only archs have no decode path"
    serve_cfg = serve_config_from_args(args)
    mesh, placement = mesh_from_args(args)

    if args.fp:
        params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
        eng = Engine(cfg, params, serve_cfg=serve_cfg, mesh=mesh,
                     placement=placement)
        print("serving FP (no quantization)")
    else:
        if args.artifact:
            if args.save_artifact or args.pack:
                raise SystemExit(
                    "--artifact loads a pre-built artifact; it cannot be "
                    "combined with --save-artifact or --pack (re-quantize "
                    "from params to produce a new artifact)")
            art = QuantArtifact.load(args.artifact)
            if art.arch is not None and art.arch != args.arch:
                raise SystemExit(
                    f"artifact was built for arch={art.arch!r} "
                    f"(smoke={art.recipe.smoke}); got --arch {args.arch!r}")
            if art.arch is not None and art.recipe.smoke != args.smoke:
                raise SystemExit(
                    f"artifact was built with smoke={art.recipe.smoke}; "
                    f"pass {'--smoke' if art.recipe.smoke else 'no --smoke'}")
            print(f"loaded artifact: method={art.method} "
                  f"policy=w{art.policy.w_bits}a{art.policy.a_bits} "
                  f"packed={art.packed} (admission does NOT re-expand)")
        else:
            params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
            recipe = QuantRecipe(method=args.method,
                                 policy=get_policy(args.policy),
                                 pack=args.pack, arch=args.arch,
                                 smoke=args.smoke)
            art = quantize_and_report(params, recipe)
            if args.save_artifact:
                art.save(args.save_artifact)
                print(f"artifact saved to {args.save_artifact}")
        rt = Runtime(art, backend=args.backend, cfg=cfg, mesh=mesh,
                     placement=placement)
        eng = rt.serve(serve_cfg)
        print(f"quantization time: {eng.quant_seconds:.3f}s "
              f"(method={art.method}, "
              f"policy=w{art.policy.w_bits}a{art.policy.a_bits}, "
              f"backend={args.backend}, placement={placement})")

    rng = np.random.default_rng(args.seed)
    qualities = (list(eng.tiers) if args.quality == "mix"
                 else [args.quality])
    for i in range(args.requests):
        length = (int(rng.integers(4, args.prompt_len + 1))
                  if args.mixed_lengths else args.prompt_len)
        res = submit_with_backoff(
            eng, rng.integers(0, cfg.vocab_size, length).tolist(),
            quality=qualities[i % len(qualities)],
            deadline_s=args.deadline_s or None)
        if isinstance(res, Rejection):
            print(f"req {i} rejected: {res.reason.name} {res.detail}")
    t0 = time.perf_counter()
    out = eng.run(max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in out.values())
    for rid, toks in sorted(out.items()):
        print(f"req {rid}: {toks[:12]}{'...' if len(toks) > 12 else ''}")
    print(f"{n_tok} tokens in {dt:.2f}s = {n_tok/dt:.1f} tok/s (batched, incl. prefill)")
    st = eng.last_run_stats
    if st:
        print(f"scheduler={st['scheduler']} placement={st['placement']} "
              f"devices={st['mesh_devices']} slots={st['n_slots']} "
              f"occupancy={st['occupancy']:.2f} "
              f"decode={st['decode_tokens_per_sec']:.1f} tok/s")
        if "acceptance_rate" in st:
            print(f"speculative: k={st['spec_terms']} "
                  f"lookahead={st['spec_lookahead']} "
                  f"acceptance={st['acceptance_rate']:.2f} "
                  f"tokens/round={st['tokens_per_round']:.2f} "
                  f"({st['spec_rounds']} rounds)")
        for tier, ts in sorted(st.get("tiers", {}).items()):
            print(f"tier {tier}: {ts['requests']} reqs "
                  f"{ts['served_tokens']} tok "
                  f"terms={ts['mean_effective_terms']:.2f}"
                  f"/{ts['nominal_terms']} "
                  f"degraded={ts['degraded_step_fraction']:.2f} "
                  f"deadline_hit={ts['deadline_hit_rate']:.2f}")
        if st.get("qos", {}).get("degrade_transitions", 0):
            q = st["qos"]
            print(f"degradation: {q['degraded_rounds']} rounds over "
                  f"{q['degrade_transitions']} transitions "
                  f"(reasons={q['degrade_reasons']})")
        if "chaos" in st:
            print(f"chaos: {st['chaos']} retries={st['dispatch_retries']}")
        ttfts = [m["ttft_s"] for m in eng.last_request_metrics.values()]
        if ttfts:
            print(f"ttft mean={np.mean(ttfts)*1e3:.1f}ms "
                  f"p max={np.max(ttfts)*1e3:.1f}ms")
    return out


def quantize_and_report(params, recipe: QuantRecipe):
    from repro.api import quantize
    art = quantize(params, recipe)
    st = art.meta["expansion_stats"]
    calib = art.meta.get("calib_batch")
    data = (f"{calib} synthetic calibration samples" if calib
            else "zero calibration data")
    print(f"quantized: {int(st['expanded_leaves'])} leaves, "
          f"{st['compression']:.2f}x compression, {art.quant_seconds:.2f}s, "
          f"{data}")
    return art


if __name__ == "__main__":
    main()
