"""Serving launcher: expand a model per FP=xINT and serve batched requests.

``python -m repro.launch.serve --arch qwen2_1_5b --smoke --policy w4a4``

Prints quantization time (the paper's Table 2/3 metric), per-request
generations for a synthetic batch, and decode throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_arch
from repro.core.policy import get_policy
from repro.infer.serve import Engine, ServeConfig
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="w4a4")
    ap.add_argument("--fp", action="store_true", help="serve unquantized")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, smoke=args.smoke)
    assert not cfg.is_encoder, "encoder-only archs have no decode path"
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    policy = None if args.fp else get_policy(args.policy)
    eng = Engine(cfg, params, policy=policy,
                 serve_cfg=ServeConfig(max_seq=args.max_seq, max_batch=args.requests))
    print(f"quantization time: {eng.quant_seconds:.3f}s "
          f"(policy={'fp' if args.fp else args.policy})")

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        eng.add_request(rng.integers(0, cfg.vocab_size, args.prompt_len).tolist())
    t0 = time.perf_counter()
    out = eng.run(max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in out.values())
    for rid, toks in sorted(out.items()):
        print(f"req {rid}: {toks[:12]}{'...' if len(toks) > 12 else ''}")
    print(f"{n_tok} tokens in {dt:.2f}s = {n_tok/dt:.1f} tok/s (batched, incl. prefill)")
    return out


if __name__ == "__main__":
    main()
