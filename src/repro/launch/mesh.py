"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state (the dry-run sets the fake-device
XLA flag before anything jax-related runs)."""
from __future__ import annotations

from typing import Tuple

import jax


def make_mesh_compat(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """jax.make_mesh across jax versions: ``axis_types`` (Auto) exists only
    on newer releases; older ones default to the same behavior."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a production mesh (everything but model)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_host_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Small mesh over the host's visible devices (tests/examples)."""
    return make_mesh_compat(shape, axes)
