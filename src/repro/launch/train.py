"""Training launcher: ``python -m repro.launch.train --arch qwen2_1_5b ...``

Runs a real (CPU-scaled or TPU) training loop with the full production
substrate: sharded params/optimizer, microbatched remat train step,
deterministic resumable data, async checkpointing, preemption handling,
straggler bookkeeping, optional residual-series gradient compression.

On this CPU container use ``--smoke`` (reduced config) or small
--seq/--batch overrides; on a TPU pod the same entrypoint runs the full
assigned config under make_production_mesh().
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_arch
from repro.dist import checkpoint as CKPT
from repro.dist.compression import CompressionConfig, make_compressor
from repro.dist.fault import TrainSupervisor
from repro.dist.sharding import ShardingRules
from repro.models import model as M
from repro.train.data import make_batch
from repro.train.optimizer import OptState
from repro.train.train_step import TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=("adamw", "adafactor", "sgd"))
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="", help="e.g. '2x4' -> (data=2, model=4)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--max-steps-this-life", type=int, default=0,
                    help="simulate a failure after N steps (tests)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, smoke=args.smoke)
    tc = TrainConfig(optimizer=args.optimizer, lr=args.lr,
                     grad_accum=args.grad_accum, remat=args.remat,
                     compress_grads=args.compress_grads)

    # gradient compression (if on) threads its error-feedback buffer through
    # the optimizer state — fully functional, jit/donation-safe
    opt, train_step = make_train_step(cfg, tc)

    mesh = None
    shardings = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((d, m), ("data", "model"))
        rules = ShardingRules(mesh, ("data",))
        params_struct = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(args.seed), cfg))
        p_specs = rules.param_specs(params_struct)
        o_specs = rules.opt_state_specs(args.optimizer, params_struct, p_specs)
        shardings = (p_specs, o_specs)

    def init_state():
        params = M.init_params(jax.random.PRNGKey(args.seed), cfg, dtype=jnp.float32)
        return {"params": params, "opt": opt.init(params)}

    sup = TrainSupervisor(
        args.ckpt_dir or f"/tmp/repro_ckpt_{args.arch}", init_state,
        ckpt_every=args.ckpt_every,
        shardings={"params": shardings[0], "opt": shardings[1]} if shardings else None)
    state, start = sup.restore_or_init()

    step_fn = jax.jit(train_step, donate_argnums=(0, 1))
    stop_at = args.steps
    if args.max_steps_this_life:
        stop_at = min(args.steps, start + args.max_steps_this_life)

    ctx = mesh or _nullcontext()
    with ctx:
        for step in range(start, stop_at):
            batch = {k: jnp.asarray(v) for k, v in
                     make_batch(cfg, args.seq, args.batch, step, seed=args.seed).items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(state["params"], state["opt"], batch)
            state = {"params": params, "opt": opt_state}
            metrics = jax.device_get(metrics)
            if step % args.log_every == 0:
                print(f"step {step}: loss={float(metrics['loss']):.4f} "
                      f"acc={float(metrics['accuracy']):.3f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={time.perf_counter()-t0:.2f}s", flush=True)
            sup.after_step(step, state)
    sup.finalize(stop_at - 1, state)
    print(f"done at step {stop_at - 1}; stragglers: {sup.straggler.slow_steps}")
    return state


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
