import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (MUST be the first two lines: jax locks the device count on first init.)
os.environ.setdefault("REPRO_NO_PALLAS", "1")  # SPMD partitions the jnp series
                                               # path; Mosaic kernels swap in on
                                               # real TPUs (kernels/ops.py).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, constructs ShapeDtypeStruct
stand-ins for params / optimizer state / inputs (zero allocation), applies
the sharding rules, then ``jax.jit(step).lower(...).compile()``.  Success
proves the distribution config is coherent (shardings legal, collectives
supported, memory model known); the compiled artifact yields

  * memory_analysis()  -> bytes per device (fits/doesn't),
  * cost_analysis()    -> HLO FLOPs & bytes for §Roofline,
  * as_text()          -> the collective schedule (parsed into per-op bytes).

Results are cached as JSON under benchmarks/results/dryrun/ so the roofline
pass and EXPERIMENTS.md tables read from one source of truth.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_7b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, SHAPES, applicable_shapes, get_arch
from repro.core import ptq as PTQ
from repro.core.policy import ExpansionPolicy
from repro.dist.sharding import ShardingRules
from repro.infer.serve import make_serve_step
from repro.launch.hlo_cost import total_costs
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import model as M
from repro.models.layers import FP, QuantContext
from repro.train.train_step import TrainConfig, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

# serving policy for prefill/decode cells: W4A4 series without dense sat
# tensors (deploy form — the sparse correction is dropped per paper §4)
SERVE_POLICY = ExpansionPolicy(w_bits=4, a_bits=4, w_terms=2, a_terms=3,
                               keep_w_sat=False, keep_a_sat=False,
                               a_saturating=False,
                               first_last_bits=8, first_last_terms=1)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s16": 2, "u16": 2, "s64": 8, "u64": 8}


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes of every collective op in post-SPMD HLO, keyed by
    op kind; also records group sizes for ring-factor adjustment."""
    out: Dict[str, Any] = {k: {"bytes": 0.0, "count": 0, "ops": []} for k in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?\S+ = (.+?) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start)?\(", stripped)
        if not m:
            continue
        outshape, kind = m.group(1), m.group(2)
        nbytes = 0.0
        for dt, dims in shape_re.findall(outshape):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        g = re.search(r"replica_groups=\[(\d+),(\d+)\]", stripped)
        group = int(g.group(2)) if g else 0
        out[kind]["bytes"] += nbytes
        out[kind]["count"] += 1
        out[kind]["ops"].append({"bytes": nbytes, "group": group})
    for k in out:
        del out[k]["ops"][64:]  # cap the per-op detail
    return out


def pick_grad_accum(global_batch: int, dp_size: int, target_micro_rows: int = 16) -> int:
    """Largest accumulation count whose microbatch still divides the dp axes."""
    best = 1
    for ga in range(1, global_batch + 1):
        if global_batch % ga:
            continue
        micro = global_batch // ga
        if micro % dp_size == 0 and micro >= dp_size:
            if micro <= max(target_micro_rows, dp_size):
                return ga
            best = ga
    return best


def build_cell(arch: str, shape_name: str, mesh, *, serve_policy=SERVE_POLICY,
               use_sp: bool = True, fsdp: bool = True, donate: bool = True,
               remat: bool = True, moe_ep: bool = True,
               grad_accum: int = 0, int8_kv: bool = False,
               attn_chunks: str = "", fp_serve: bool = False,
               capacity_factor: float = 0.0, smoke: bool = False):
    """Returns (fn, example_args_structs, in_shardings, donate_argnums)."""
    import dataclasses as _dc
    cfg = get_arch(arch, smoke=smoke)
    if attn_chunks:
        qc_, kc_ = (int(x) for x in attn_chunks.split(","))
        cfg = _dc.replace(cfg, attn_q_chunk=qc_, attn_kv_chunk=kc_)
    if capacity_factor:
        cfg = _dc.replace(cfg, capacity_factor=capacity_factor)
    sh = SHAPES[shape_name]
    if smoke:
        # CI-shrunk cell: smoke arch dims + a shape small enough to lower
        # and compile in seconds — exercises the same sharding rules,
        # collectives, and cost-analysis plumbing as the production cell
        sh = _dc.replace(sh, seq_len=min(sh.seq_len, 128),
                         global_batch=min(sh.global_batch, 16))
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    shard_batch = sh.global_batch % dp_size == 0 and sh.global_batch >= dp_size
    rules = ShardingRules(mesh, dp, fsdp=fsdp, shard_batch=shard_batch)

    # sequence-parallel residual-stream constraint (train/prefill only)
    act_constraint = None
    if use_sp and sh.kind in ("train", "prefill"):
        seq = sh.seq_len
        tp = mesh.shape["model"]
        if seq % tp == 0:
            dp_spec = tuple(dp) if len(dp) > 1 else dp[0]
            sp_sharding = NamedSharding(
                mesh, P(dp_spec if shard_batch else None, "model", None))
            act_constraint = lambda x: jax.lax.with_sharding_constraint(x, sp_sharding)

    params_struct = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))

    if sh.kind == "train":
        tc = TrainConfig(
            optimizer="adafactor" if cfg.param_count() > 1e11 else "adamw",
            grad_accum=grad_accum or pick_grad_accum(sh.global_batch, dp_size),
            remat=remat)
        opt, train_step = make_train_step(cfg, tc, FP, act_constraint=act_constraint)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        batch_struct = M.input_specs(cfg, sh)["batch"]
        p_specs = rules.param_specs(params_struct)
        o_specs = rules.opt_state_specs(tc.optimizer, params_struct, p_specs)
        b_specs = rules.batch_specs(batch_struct)
        in_sh = (p_specs, o_specs, b_specs)
        args = (params_struct, opt_struct, batch_struct)
        out_sh = (p_specs, o_specs, None)
        return train_step, args, in_sh, out_sh, ((0, 1) if donate else ()), tc

    # serving cells: expand the params per the deploy policy
    # (--fp-serve keeps FP params: the paper-faithful unquantized baseline)
    if fp_serve:
        qc = QuantContext(policy=None, int8_kv=int8_kv)
        q_struct = params_struct
    else:
        qc = QuantContext(policy=serve_policy, int8_kv=int8_kv)
        q_struct = jax.eval_shape(lambda p: PTQ.expand_params(p, serve_policy), params_struct)
    qp_specs = rules.param_specs(q_struct)

    if sh.kind == "prefill":
        def prefill_step(params, batch):
            return M.prefill(params, batch, cfg, qc, act_constraint=act_constraint)
        batch_struct = M.input_specs(cfg, sh)["batch"]
        b_specs = rules.batch_specs(batch_struct)
        return prefill_step, (q_struct, batch_struct), (qp_specs, b_specs), None, (), None

    # decode
    serve_step = make_serve_step(cfg, qc)
    specs = M.input_specs(cfg, sh, int8_kv=int8_kv)
    cache_specs = rules.cache_specs(specs["caches"])
    tok_specs = rules.batch_specs({"tokens": specs["tokens"]})["tokens"]
    in_sh = (qp_specs, tok_specs, cache_specs, rules.replicated())
    args = (q_struct, specs["tokens"], specs["caches"], specs["cache_len"])
    return serve_step, args, in_sh, None, ((2,) if donate else ()), None


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, save: bool = True,
             tag: str = "", **build_kw) -> Dict[str, Any]:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                           "mesh_shape": dict(mesh.shape), "tag": tag}
    try:
        fn, args, in_sh, out_sh, donate, tc = build_cell(arch, shape_name, mesh, **build_kw)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(mem)                      # proves it fits (bytes per device)
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax: one dict per program
            ca = ca[0] if ca else {}
        print({k: ca.get(k) for k in ("flops", "bytes accessed")})
        hlo_text = compiled.as_text()
        coll = parse_collectives(hlo_text)
        loop_aware = total_costs(hlo_text)
        cfg = get_arch(arch)
        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            "collectives": coll,
            "loop_aware": loop_aware,
            "grad_accum": getattr(tc, "grad_accum", None) if tc else None,
            "param_count": cfg.param_count(),
            "active_param_count": cfg.active_param_count(),
        })
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"FAILED {arch} {shape_name} {mesh_kind}: {e}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true", help="every live cell")
    ap.add_argument("--tag", default="", help="variant tag for perf iterations")
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=0)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--attn-chunks", default="", help="e.g. 2048,4096")
    ap.add_argument("--fp-serve", action="store_true", help="unquantized serving baseline")
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-shrunk cell: smoke arch dims + tiny shape")
    ap.add_argument("--no-save", action="store_true",
                    help="don't write the result JSON (CI smoke checks)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.smoke and not args.tag:
        args.tag = "smoke"   # keep CI-shrunk results off the production cells
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in applicable_shapes(get_arch(a))]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    build_kw = dict(use_sp=not args.no_sp, fsdp=not args.no_fsdp,
                    remat=not args.no_remat, grad_accum=args.grad_accum,
                    int8_kv=args.int8_kv, attn_chunks=args.attn_chunks,
                    fp_serve=args.fp_serve, capacity_factor=args.capacity_factor,
                    smoke=args.smoke)
    n_ok = 0
    for arch, shape in cells:
        for mk in meshes:
            suffix = f"_{args.tag}" if args.tag else ""
            path = os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mk}{suffix}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        n_ok += 1
                        print(f"skip (cached ok): {arch} {shape} {mk}")
                        continue
            print(f"=== {arch} {shape} {mk} ===", flush=True)
            rec = run_cell(arch, shape, mk, tag=args.tag,
                           save=not args.no_save, **build_kw)
            n_ok += bool(rec.get("ok"))
    total = len(cells) * len(meshes)
    print(f"\n{n_ok}/{total} cells compiled OK")
    raise SystemExit(0 if n_ok == total else 1)


if __name__ == "__main__":
    main()
