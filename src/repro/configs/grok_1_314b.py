"""grok-1 314B [moe] — 64L d6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="grok_1_314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072,
    stage_pattern=("moe_attn",),
    num_experts=8, experts_per_token=2,
    mlp_act="gelu", mlp_gated=True,
    attn_softcap=30.0, logit_softcap=30.0,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="grok_1_314b", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    stage_pattern=("moe_attn",),
    num_experts=4, experts_per_token=2,
    capacity_factor=8.0,  # dropless for exact prefill/decode consistency tests
    mlp_act="gelu", mlp_gated=True,
    attn_softcap=30.0, logit_softcap=30.0,
    dtype="float32",
)

register(FULL, SMOKE)
