"""llama4-scout 17B-active [moe] — 48L d5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert; early-fusion frontend is
out of scope (backbone only).  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="llama4_scout_17b_a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    stage_pattern=("moe_attn",),
    num_experts=16, experts_per_token=1, shared_expert=True,
    mlp_act="silu", mlp_gated=True,
    rope_theta=5e5,
)

SMOKE = ArchConfig(
    name="llama4_scout_17b_a16e", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    stage_pattern=("moe_attn",),
    num_experts=4, experts_per_token=1, shared_expert=True,
    capacity_factor=8.0,  # dropless for exact prefill/decode consistency tests
    mlp_act="silu", mlp_gated=True,
    dtype="float32",
)

register(FULL, SMOKE)
