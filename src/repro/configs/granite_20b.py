"""granite-20b [dense] — 52L d6144 48H (MQA kv=1) d_ff=24576 vocab=49152,
llama-arch code model (gpt-bigcode heritage: MQA, GELU, LayerNorm).
[arXiv:2405.04324; hf]"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="granite_20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    stage_pattern=("attn",),
    mlp_act="gelu", mlp_gated=False,
    norm="layernorm",
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="granite_20b", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=256, vocab_size=256,
    stage_pattern=("attn",),
    mlp_act="gelu", mlp_gated=False,
    norm="layernorm",
    dtype="float32",
)

register(FULL, SMOKE)
