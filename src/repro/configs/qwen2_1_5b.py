"""qwen2-1.5b [dense] — 28L d1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
QKV bias, tied embeddings.  [arXiv:2407.10671; hf]"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen2_1_5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    stage_pattern=("attn",),
    mlp_act="silu", mlp_gated=True,
    qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen2_1_5b", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    stage_pattern=("attn",),
    mlp_act="silu", mlp_gated=True,
    qkv_bias=True, tie_embeddings=True,
    dtype="float32",
)

register(FULL, SMOKE)
