"""mamba2-780m [ssm] — 48L d1536 (attention-free) ssm_state=128, SSD
(state-space duality) mixer.  O(1) decode state -> runs long_500k.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="mamba2_780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    stage_pattern=("ssm",),
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="mamba2_780m", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=256,
    stage_pattern=("ssm",),
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=16,
    tie_embeddings=True,
    dtype="float32",
)

register(FULL, SMOKE)
