"""hubert-xlarge [audio] — 48L d1280 16H (MHA kv=16) d_ff=5120 vocab=504,
encoder-only (same transformer as wav2vec2).  Conv feature extractor is a
STUB per assignment: input_specs provides precomputed frame embeddings
(B, T, 1280).  Encoder-only -> no decode shapes.  [arXiv:2106.07447; unverified]"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="hubert_xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    stage_pattern=("attn",),
    mlp_act="gelu", mlp_gated=False,
    norm="layernorm",
    frame_dim=1280, is_encoder=True,
)

SMOKE = ArchConfig(
    name="hubert_xlarge", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=32,
    stage_pattern=("attn",),
    mlp_act="gelu", mlp_gated=False,
    norm="layernorm",
    frame_dim=24, is_encoder=True,
    dtype="float32",
)

register(FULL, SMOKE)
