"""deepseek-7b [dense] — 30L d4096 32H (MHA kv=32) d_ff=11008 vocab=102400,
llama-arch.  [arXiv:2401.02954; hf]"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="deepseek_7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=102400,
    stage_pattern=("attn",),
    mlp_act="silu", mlp_gated=True,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="deepseek_7b", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    stage_pattern=("attn",),
    mlp_act="silu", mlp_gated=True,
    dtype="float32",
)

register(FULL, SMOKE)
