"""recurrentgemma-9b [hybrid] — 38L d4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention in a 2:1 pattern (window 2048).
Sub-quadratic -> runs the long_500k cell.  [arXiv:2402.19427; unverified]"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="recurrentgemma_9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000,
    stage_pattern=("rglru", "rglru", "local"),
    tail_pattern=("rglru", "rglru"),
    window=2048, rnn_width=4096,
    mlp_act="gelu", mlp_gated=True,
    logit_softcap=30.0,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="recurrentgemma_9b", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=128, vocab_size=256,
    stage_pattern=("rglru", "rglru", "local"),
    tail_pattern=("rglru", "rglru"),
    window=16, rnn_width=64,
    mlp_act="gelu", mlp_gated=True,
    logit_softcap=30.0,
    dtype="float32",
)

register(FULL, SMOKE)
