"""nemotron-4-340b [dense] — 96L d18432 96H (GQA kv=8, head_dim 192)
d_ff=73728 vocab=256000, squared-ReLU MLP (non-gated), LayerNorm.
[arXiv:2402.16819; unverified]"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="nemotron_4_340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    d_ff=73728, vocab_size=256000,
    stage_pattern=("attn",),
    mlp_act="relu2", mlp_gated=False,
    norm="layernorm",
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="nemotron_4_340b", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256,
    stage_pattern=("attn",),
    mlp_act="relu2", mlp_gated=False,
    norm="layernorm",
    dtype="float32",
)

register(FULL, SMOKE)
