"""llama-3.2-vision-90b [vlm] — 100L d8192 64H (GQA kv=8) d_ff=28672
vocab=128256; gated cross-attention image layers every 5th layer.
Vision frontend is a STUB per assignment: input_specs provides precomputed
patch embeddings (B, 1600, 1280).  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="llama_3_2_vision_90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    stage_pattern=("attn", "attn", "attn", "attn", "cross"),
    num_image_tokens=1600, image_embed_dim=1280,
    mlp_act="silu", mlp_gated=True,
    rope_theta=5e5,
)

SMOKE = ArchConfig(
    name="llama_3_2_vision_90b", family="vlm",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    stage_pattern=("attn", "attn", "attn", "attn", "cross"),
    num_image_tokens=8, image_embed_dim=32,
    mlp_act="silu", mlp_gated=True,
    dtype="float32",
)

register(FULL, SMOKE)
