"""ArchConfig: one dataclass drives the whole zoo; per-arch modules register
their exact assigned config plus a reduced smoke variant.

Shapes (assigned): train_4k / prefill_32k / decode_32k / long_500k.
``decode_*``/``long_*`` lower ``serve_step``; long_500k only runs for
sub-quadratic archs (ssm/hybrid); encoder-only archs have no decode shapes.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                    # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # block layout: the layer stack is num_stages x stage_pattern + tail_pattern
    stage_pattern: Tuple[str, ...] = ("attn",)   # attn | local | cross | rglru | ssm | moe_attn
    tail_pattern: Tuple[str, ...] = ()
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    shared_expert: bool = False
    # capacity factor: 1.25 = standard GShard dropping; smoke configs use a
    # dropless value so prefill/decode/forward agree exactly (capacity
    # dropping is batch-composition-dependent by construction)
    capacity_factor: float = 1.25
    # MLP / misc
    mlp_act: str = "silu"
    mlp_gated: bool = True
    qkv_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    norm: str = "rmsnorm"
    # attention
    window: int = 0                   # sliding window for "local" blocks
    rope_theta: float = 1e4
    attn_q_chunk: int = 0             # flash chunking (0 -> 1024 default)
    attn_kv_chunk: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # RG-LRU
    rnn_width: int = 0                # 0 -> d_model
    # multimodal stubs
    num_image_tokens: int = 0         # vlm: precomputed patch embeddings
    image_embed_dim: int = 0          # raw patch-embedding dim (stub frontend)
    frame_dim: int = 0                # audio: precomputed frame-embedding dim
    is_encoder: bool = False          # encoder-only (no causal mask, no decode)
    # numerics
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.rnn_width:
            object.__setattr__(self, "rnn_width", self.d_model)
        pat = len(self.stage_pattern)
        assert (self.num_layers - len(self.tail_pattern)) % pat == 0, (
            self.name, self.num_layers, self.stage_pattern, self.tail_pattern)

    @property
    def num_stages(self) -> int:
        return (self.num_layers - len(self.tail_pattern)) // len(self.stage_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run long_500k (no full-attention block)."""
        blocks = set(self.stage_pattern) | set(self.tail_pattern)
        return "attn" not in blocks and "cross" not in blocks and "moe_attn" not in blocks

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n_attn_q = self.num_heads * hd
        n_attn_kv = self.num_kv_heads * hd
        per_block = {
            "attn": d * (n_attn_q + 2 * n_attn_kv) + n_attn_q * d
                    + (3 if self.mlp_gated else 2) * d * f,
            "local": d * (n_attn_q + 2 * n_attn_kv) + n_attn_q * d
                     + (3 if self.mlp_gated else 2) * d * f,
            "cross": d * (n_attn_q + 2 * n_attn_kv) + n_attn_q * d
                     + (3 if self.mlp_gated else 2) * d * f,
            "moe_attn": d * (n_attn_q + 2 * n_attn_kv) + n_attn_q * d
                        + self.num_experts * 3 * d * f + d * self.num_experts
                        + (3 * d * f if self.shared_expert else 0),
            "rglru": 2 * d * self.rnn_width + 2 * self.rnn_width ** 2
                     + self.rnn_width * d + (3 if self.mlp_gated else 2) * d * f,
            "ssm": d * (2 * self.ssm_expand * d + 2 * self.ssm_state
                        + (self.ssm_expand * d) // self.ssm_head_dim)
                   + self.ssm_expand * d * d,
        }
        total = v * d + (0 if self.tie_embeddings else d * v)
        for blk in tuple(self.stage_pattern) * self.num_stages + self.tail_pattern:
            total += per_block[blk]
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6*N_active*D)."""
        if self.num_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_total = self.param_count()
        moe_blocks = sum(1 for b in tuple(self.stage_pattern) * self.num_stages
                         + self.tail_pattern if b == "moe_attn")
        inactive = moe_blocks * (self.num_experts - self.experts_per_token) * 3 * d * f
        return dense_total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "grok_1_314b",
    "llama4_scout_17b_a16e",
    "recurrentgemma_9b",
    "deepseek_7b",
    "granite_20b",
    "qwen2_1_5b",
    "nemotron_4_340b",
    "mamba2_780m",
    "llama_3_2_vision_90b",
    "hubert_xlarge",
)

_REGISTRY: Dict[str, "ArchConfig"] = {}
_SMOKE: Dict[str, "ArchConfig"] = {}


def register(full: ArchConfig, smoke: ArchConfig):
    _REGISTRY[full.name] = full
    _SMOKE[full.name] = smoke


def get_arch(name: str, *, smoke: bool = False) -> ArchConfig:
    name = name.replace("-", "_").replace(".", "_")
    if name not in _REGISTRY:
        if name not in ARCH_IDS:
            raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
        importlib.import_module(f"repro.configs.{name}")
    return (_SMOKE if smoke else _REGISTRY)[name]


def applicable_shapes(cfg: ArchConfig) -> Tuple[str, ...]:
    """Assignment-sanctioned shape cells for this arch (skips recorded in
    EXPERIMENTS.md)."""
    shapes = ["train_4k", "prefill_32k"]
    if not cfg.is_encoder:
        shapes.append("decode_32k")
        if cfg.sub_quadratic:
            shapes.append("long_500k")
    return tuple(shapes)


def all_cells():
    """Every live (arch, shape) cell."""
    for a in ARCH_IDS:
        cfg = get_arch(a)
        for s in applicable_shapes(cfg):
            yield a, s
