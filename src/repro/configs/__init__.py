"""Assigned-architecture configs.  Importing a module registers (full, smoke)."""
from repro.configs.base import ARCH_IDS, SHAPES, ArchConfig, ShapeConfig, get_arch, applicable_shapes, all_cells
