"""Single source of truth for the series-grid constants (DESIGN.md §7).

The dyadic scale schedule and the per-plane clamp bounds define the FP=xINT
number system: every extraction site — the reference oracle, both Pallas
kernels, and the tensor-level expansion — must agree on them EXACTLY or the
exactness guarantees of Theorem 1 silently break (PR 5 found the four
hand-copied tables drifting apart in their stated bounds).  This module is
the one place they are defined; ``repro.analysis`` lint rule REPRO103 locks
any re-definition of these functions outside this file.

Dependency-free by construction (stdlib only): both ``repro.core`` and
``repro.kernels`` import it, and neither may import the other
(``core.linear`` -> ``kernels.ops`` is the one allowed direction).
"""
from __future__ import annotations

from typing import Tuple


def scale_ratio(bits: int) -> int:
    """Inter-term scale ratio.  The paper's dyadic schedule is 2^X; a
    residual in [-s/2, s/2] then needs the grid value ±2^{X-1}, which the
    int8 container holds for X < 8 but not for X = 8 (+128 overflows) —
    there the clamp *stalls* convergence at ~s_2/2 on half-tie elements.
    We therefore use ratio 2^{X-1} for X = 8 (|q| <= 64, clamp-free, still
    geometric).  Documented deviation, see DESIGN.md §7."""
    return 2 ** bits if bits < 8 else 2 ** (bits - 1)


def plane_limits(bits: int, k: int, pack_safe: bool = False) -> Tuple[int, int]:
    """Clamp bounds of plane ``k`` of an INT-``bits`` series (int8 container).

    Plane 0 uses the symmetric grid [-(2^{X-1}-1), 2^{X-1}-1] so
    ``scale_1 = absmax / (2^{X-1}-1)`` maps the extremes exactly;
    ``pack_safe`` keeps EVERY plane on that grid so INT4 planes pack two
    per byte (kernels/pack.py) — the rare half-tie clamp error is absorbed
    by the next plane (sequential extraction) at the cost of a 3x slack on
    the final-term bound.

    Residual planes (k >= 1) use the proof bound |q| <= 2^{X-1} in an int8
    container — asymmetric at X=8, where lo reaches the container floor
    -128 while hi clamps +128 -> +127.  Both bounds are unreachable at X=8
    by construction (scale_ratio halves to 2^{X-1}, so |round(r/s)| <= 64);
    they are stated exactly so every extraction site provably agrees
    (tests/test_kernels.py bits=8 parity property)."""
    if k == 0 or pack_safe:
        hi = 2 ** (bits - 1) - 1
        return -hi, hi
    return -(2 ** (bits - 1)), min(2 ** (bits - 1), 127)
