"""Model zoo: the 10 assigned architectures as one config-driven family."""
from repro.models.model import Model, init_params, input_specs
