"""Mamba-2 mixer via the SSD (state-space duality) chunked algorithm
(Dao & Gu, 2024 — arXiv:2405.21060).

The chunked form recasts the selective-scan as GEMMs (MXU-friendly):
within-chunk attention-like einsums + an inter-chunk state recurrence of
length L/Q.  Decode is an O(1) state update — this is why mamba2 runs the
``long_500k`` cell that full-attention archs must skip.

Only the *parameter* GEMMs (in_proj / out_proj) carry FP=xINT expanded
weights; the SSD data-data products (C·B^T, decays) have no static weight
to expand (DESIGN.md §5 arch-applicability note).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import QuantContext


def ssm_dims(cfg) -> Dict[str, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return {
        "d_inner": d_inner,
        "heads": heads,
        "p": cfg.ssm_head_dim,
        "n": cfg.ssm_state,
        "conv_ch": d_inner + 2 * cfg.ssm_state,
        "in_dim": 2 * d_inner + 2 * cfg.ssm_state + heads,
    }


def ssm_init(key, cfg, dtype=jnp.float32) -> Dict:
    d = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": L.dense_init(ks[0], cfg.d_model, d["in_dim"], dtype=dtype),
        "conv": L.conv1d_init(ks[1], d["conv_ch"], cfg.ssm_conv, dtype=dtype),
        "a_log": jnp.zeros((d["heads"],), dtype),        # A = -exp(a_log) = -1
        "d_skip": jnp.ones((d["heads"],), dtype),
        "dt_bias": jnp.full((d["heads"],), -2.0, dtype), # softplus(-2) ~= 0.13
        "norm": L.norm_init(d["d_inner"], dtype),
        "out_proj": L.dense_init(ks[2], d["d_inner"], cfg.d_model, dtype=dtype),
    }


def _split_zxbcdt(zxbcdt, d):
    z = zxbcdt[..., : d["d_inner"]]
    xbc = zxbcdt[..., d["d_inner"] : d["d_inner"] + d["conv_ch"]]
    dt = zxbcdt[..., d["d_inner"] + d["conv_ch"] :]
    return z, xbc, dt


def _split_xbc(xbc, d):
    x = xbc[..., : d["d_inner"]]
    bv = xbc[..., d["d_inner"] : d["d_inner"] + d["n"]]
    cv = xbc[..., d["d_inner"] + d["n"] :]
    return x, bv, cv


def ssd_chunked(x, dt, a, bv, cv, *, chunk: int):
    """SSD core.  x: (B,L,H,P); dt: (B,L,H); a: (H,) (negative);
    bv, cv: (B,L,N).  Returns y: (B,L,H,P) and final state (B,H,P,N)."""
    b, l, h, p = x.shape
    n = bv.shape[-1]
    chunk = min(chunk, l)
    if l % chunk != 0:
        raise ValueError(f"sequence length {l} not divisible by SSD chunk {chunk}")
    nc = l // chunk

    da = dt * a                                             # (B,L,H)  <= 0
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    dac = da.reshape(b, nc, chunk, h)
    bc = bv.reshape(b, nc, chunk, n)
    cc = cv.reshape(b, nc, chunk, n)

    cum = jnp.cumsum(dac, axis=2)                           # (B,nc,Q,H)
    # --- intra-chunk (attention-like GEMMs) ---
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)              # (B,nc,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,Q,Q,H) i,j
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    scores = jnp.where(tri[None, None, :, :, None], cb[..., None] * decay, 0.0)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", scores, dtc, xc)
    # --- per-chunk end states ---
    state_decay = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", state_decay * dtc, bc, xc)
    # --- inter-chunk recurrence ---
    total_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,H)

    def body(s_prev, inp):
        td, sc = inp                                        # (B,H), (B,H,P,N)
        s_new = td[:, :, None, None] * s_prev + sc
        return s_new, s_prev                                # emit state *entering* the chunk

    s0 = jnp.zeros((b, h, p, n), x.dtype)
    s_final, s_prevs = jax.lax.scan(
        body, s0, (jnp.moveaxis(total_decay, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                   # (B,nc,H,P,N)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", cc, s_prevs, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, s_final


def ssm_apply(qc: QuantContext, params: Dict, x_in: jnp.ndarray, cfg,
              *, chunk: int = 256, lengths=None) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence mixer.  x_in: (B,L,D).  Returns (out, final_cache).

    ``lengths`` (B,) marks right-padded rows: padded positions get dt=0,
    which zeroes their state contribution AND their decay (exp(0)=1), so the
    final SSD state equals the state at each row's true length; the conv
    cache is gathered from the last valid inputs per row."""
    d = ssm_dims(cfg)
    zxbcdt = L.dense(qc, x_in, params["in_proj"])
    z, xbc, dt = _split_zxbcdt(zxbcdt, d)
    xbc = jax.nn.silu(L.causal_conv1d(params["conv"], xbc))
    xs, bv, cv = _split_xbc(xbc, d)
    dt = jax.nn.softplus(dt + params["dt_bias"])            # (B,L,H)
    if lengths is not None:
        valid = jnp.arange(x_in.shape[1])[None, :] < lengths[:, None]
        dt = jnp.where(valid[..., None], dt, 0.0)
    a = -jnp.exp(params["a_log"])
    b_, l_ = x_in.shape[0], x_in.shape[1]
    xh = xs.reshape(b_, l_, d["heads"], d["p"])
    if lengths is not None:
        # serving prefill-into-slot: sequential left fold in exactly
        # ssm_verify / ssm_decode_step's per-token form.  A left fold splits
        # exactly at any chunk boundary, so chunked prefill reproduces the
        # state trajectory bit-for-bit (DESIGN.md §14); ssd_chunked's
        # GEMM-recast reassociates sums at the ulp level, which per-batch
        # quantization amplifies into token flips.
        da = jnp.exp(dt * a)                                # (B,L,H)

        def step(s_c, inp):
            dt_j, da_j, bv_j, cv_j, xh_j = inp
            s_n = s_c * da_j[:, :, None, None] + jnp.einsum(
                "bh,bn,bhp->bhpn", dt_j, bv_j, xh_j)
            y_j = (jnp.einsum("bn,bhpn->bhp", cv_j, s_n)
                   + params["d_skip"][None, :, None] * xh_j)
            return s_n, y_j

        s0 = jnp.zeros((b_, d["heads"], d["p"], d["n"]), xh.dtype)
        s_final, y = jax.lax.scan(
            step, s0,
            (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(da, 1, 0),
             jnp.moveaxis(bv, 1, 0), jnp.moveaxis(cv, 1, 0),
             jnp.moveaxis(xh, 1, 0)))
        y = jnp.moveaxis(y, 0, 1)                           # (B,L,H,P)
    else:
        y, s_final = ssd_chunked(xh, dt, a, bv, cv, chunk=chunk)
        y = y + params["d_skip"][None, None, :, None] * xh
    y = y.reshape(b_, l_, d["d_inner"])
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = L.dense(qc, y, params["out_proj"])
    # conv cache = last K-1 pre-activation conv inputs
    k = cfg.ssm_conv
    xbc_raw = _split_zxbcdt(zxbcdt, d)[1]
    if lengths is not None:
        conv_state = L.gather_tail(xbc_raw, lengths, k - 1)
    else:
        conv_state = xbc_raw[:, -(k - 1):, :] if l_ >= k - 1 else jnp.pad(
            xbc_raw, ((0, 0), (k - 1 - l_, 0), (0, 0)))
    return out, {"conv": conv_state, "ssm": s_final}


def ssm_verify(qc: QuantContext, params: Dict, x: jnp.ndarray, cache: Dict,
               cfg) -> Tuple[jnp.ndarray, Dict]:
    """Multi-token decode continuation (speculative verify, DESIGN.md §10).

    x: (B, T, D); cache: {'conv': (B, K-1, C), 'ssm': (B, H, P, N)} — the
    state entering the chunk.  Returns (out (B, T, D), per-step states
    {'conv': (B, T, K-1, C), 'ssm': (B, T, H, P, N)}): entry ``t`` is the
    state after chunk tokens 0..t (accept/rollback gathers the accepted
    index).  The projection GEMMs run chunked; the conv and the SSD state
    recurrence are unrolled in exactly :func:`ssm_decode_step`'s per-token
    form."""
    d = ssm_dims(cfg)
    t = x.shape[1]
    zxbcdt = L.dense(qc, x, params["in_proj"])                # (B,T,in_dim)
    z, xbc_raw, dt = _split_zxbcdt(zxbcdt, d)
    w, bias = params["conv"]["w"], params["conv"]["b"]
    k = w.shape[0]
    xp = jnp.concatenate([cache["conv"].astype(xbc_raw.dtype), xbc_raw], axis=1)
    conv_out = jnp.stack([jnp.einsum("bkc,kc->bc", xp[:, j:j + k, :], w) + bias
                          for j in range(t)], axis=1)         # (B,T,C)
    xbc = jax.nn.silu(conv_out)
    xs, bv, cv = _split_xbc(xbc, d)
    dt = jax.nn.softplus(dt + params["dt_bias"])              # (B,T,H)
    a = -jnp.exp(params["a_log"])
    xh = xs.reshape(x.shape[0], t, d["heads"], d["p"])
    da = jnp.exp(dt * a)                                      # (B,T,H)
    s = cache["ssm"]
    ss, ys = [], []
    for j in range(t):                                        # static unroll
        s = s * da[:, j, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, j], bv[:, j], xh[:, j])
        ss.append(s)
        ys.append(jnp.einsum("bn,bhpn->bhp", cv[:, j], s)
                  + params["d_skip"][None, :, None] * xh[:, j])
    y = jnp.stack(ys, axis=1).reshape(x.shape[0], t, d["d_inner"])
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = L.dense(qc, y, params["out_proj"])
    convs = jnp.stack([xp[:, j + 1:j + k, :] for j in range(t)], axis=1)
    return out, {"conv": convs, "ssm": jnp.stack(ss, axis=1)}


def ssm_decode_step(qc: QuantContext, params: Dict, x_t: jnp.ndarray, cache: Dict,
                    cfg) -> Tuple[jnp.ndarray, Dict]:
    """Single-token state update.  x_t: (B,1,D)."""
    d = ssm_dims(cfg)
    zxbcdt = L.dense(qc, x_t[:, 0, :], params["in_proj"])   # (B, in_dim)
    z, xbc, dt = _split_zxbcdt(zxbcdt, d)
    conv_out, conv_state = L.causal_conv1d_step(params["conv"], cache["conv"], xbc)
    xbc = jax.nn.silu(conv_out)
    xs, bv, cv = _split_xbc(xbc, d)
    dt = jax.nn.softplus(dt + params["dt_bias"])            # (B,H)
    a = -jnp.exp(params["a_log"])
    xh = xs.reshape(xs.shape[0], d["heads"], d["p"])
    da = jnp.exp(dt * a)                                    # (B,H)
    s = cache["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, bv, xh)
    y = jnp.einsum("bn,bhpn->bhp", cv, s) + params["d_skip"][None, :, None] * xh
    y = y.reshape(y.shape[0], d["d_inner"])
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = L.dense(qc, y, params["out_proj"])
    return out[:, None, :], {"conv": conv_state, "ssm": s}
