"""Mixture-of-Experts FFN with capacity-based einsum dispatch (MaxText-style).

Tokens are routed top-k with a per-group capacity ``C = ceil(group * k / E *
capacity_factor)``; overflow tokens are dropped (standard Switch/GShard
semantics).  Dispatch/combine are one-hot einsums — fully SPMD-shardable:
the expert axis maps to the ``model`` mesh axis (expert parallelism), the
group axis follows the batch sharding.

Expert GEMM weights are stacked ``(E, D, F)`` kernels; under FP=xINT they
are expanded per-expert (``expand_batched``: independent quantizers per
expert) and applied through a vmap of the expanded matmul.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.expansion import ExpandedTensor
from repro.core.linear import expanded_apply
from repro.models import layers as L
from repro.models.layers import QuantContext


def moe_init(key, cfg, dtype=jnp.float32) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    std_in, std_out = d ** -0.5, f ** -0.5
    p = {
        "router": L.dense_init(ks[0], d, e, dtype=dtype),
        "wi": {"kernel": jax.random.normal(ks[1], (e, d, f), dtype) * std_in},
        "wg": {"kernel": jax.random.normal(ks[2], (e, d, f), dtype) * std_in},
        "wo": {"kernel": jax.random.normal(ks[3], (e, f, d), dtype) * std_out},
    }
    if cfg.shared_expert:
        p["shared"] = L.mlp_init(ks[4], d, f, gated=True, dtype=dtype)
    return p


def _expert_mm(qc: QuantContext, x_e: jnp.ndarray, w, act=None) -> jnp.ndarray:
    """x_e: (E, C', D) @ stacked kernels (E, D, F) -> (E, C', F)."""
    if isinstance(w["kernel"], ExpandedTensor):
        et = w["kernel"]
        if et.batch_dims != 1:
            raise ValueError(f"stacked expert kernel must have batch_dims=1, got {et}")
        out = jax.vmap(lambda xe, we: expanded_apply(xe, we, qc.policy, use_kernel=qc.use_kernel))(
            x_e, et.unbatched_view())
    else:
        out = jnp.einsum("ecd,edf->ecf", x_e, w["kernel"])
    return out


def moe_apply(qc: QuantContext, params: Dict, x: jnp.ndarray, cfg,
              *, group_size: int = 4096) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tokens = b * s
    g_sz = min(group_size, tokens)
    if tokens % g_sz != 0:
        raise ValueError(
            f"token count {tokens} not divisible by MoE group size {g_sz}")
    g = tokens // g_sz
    cap = min(g_sz, max(k, math.ceil(g_sz * k / e * cfg.capacity_factor)))

    xg = x.reshape(g, g_sz, d)
    logits = L.dense(qc, xg, params["router"])               # (G, S', E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (G, S', k)
    if k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)    # (G, S', k, E)
    flat = onehot.reshape(g, g_sz * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                       # arrival order per expert
    pos = pos.reshape(g, g_sz, k, e)
    keep = (pos < cap) & (onehot > 0)                        # (G, S', k, E)
    # disp (G, S', k, E, C): token s's k-th choice occupies slot c of expert e
    pos_cap = jnp.clip(pos, 0, cap - 1)
    disp = keep[..., None] & (jax.nn.one_hot(pos_cap, cap, dtype=jnp.int32) > 0)
    dispatch = jnp.any(disp, axis=2).astype(x.dtype)         # (G, S', E, C) 0/1
    combine = jnp.einsum("gsk,gskec->gsec", gate_vals, disp.astype(jnp.float32))

    x_e = jnp.einsum("gsec,gsd->gecd", dispatch, xg)         # (G, E, C, D)
    x_e = x_e.transpose(1, 0, 2, 3).reshape(e, g * cap, d)
    h = _expert_mm(qc, x_e, params["wi"])
    hg = _expert_mm(qc, x_e, params["wg"])
    h = jax.nn.silu(hg) * h
    y_e = _expert_mm(qc, h, params["wo"])                    # (E, G*C, D)
    y_e = y_e.reshape(e, g, cap, d).transpose(1, 0, 2, 3)    # (G, E, C, D)
    y = jnp.einsum("gsec,gecd->gsd", combine, y_e)
    y = y.reshape(b, s, d)

    if "shared" in params:
        y = y + L.mlp_apply(qc, params["shared"], x, "silu")
    return y.astype(x.dtype)


def load_balance_loss(logits: jnp.ndarray, gate_idx: jnp.ndarray, e: int) -> jnp.ndarray:
    """Switch-style auxiliary loss (exposed for the training loop)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e), axis=tuple(range(gate_idx.ndim - 1)))
    frac_probs = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return e * jnp.sum(frac_tokens * frac_probs)
