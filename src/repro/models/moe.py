"""Mixture-of-Experts FFN: capacity einsum dispatch + the serving contract.

Two routing rules (DESIGN.md §15):

* ``routing="group"`` — MaxText-style capacity dispatch: tokens are routed
  top-k with a per-group capacity ``C = ceil(group * k / E *
  capacity_factor)``; overflow tokens are dropped (standard Switch/GShard
  semantics).  Throughput/training semantics; token counts that do not
  divide the group size are right-padded with zero-gate rows (an exact
  no-op: pad rows claim no capacity slots and contribute nothing).
* ``routing="token"`` — the serving contract: dropless per-token dispatch.
  Every token reaches each of its top-k experts unconditionally (dispatch
  is the membership one-hot, combine the renormalized gates), so there is
  no cross-token capacity cumsum: a row's routing is a function of that
  row alone — bit-frozen for non-participant rows under serving row masks
  (the PR 9 role-mask discipline), invariant to slot order, and drop
  fraction is structurally zero.  Decode/verify/chunk rounds run under
  this rule (``QuantContext.moe_routing``, set by the Engine).

Expert GEMM weights are stacked ``(E, D, F)`` kernels; under FP=xINT they
are expanded per-expert (``expand_batched``: independent quantizers per
expert) and applied through the grouped series GEMM
(``core.linear.grouped_expanded_apply`` -> ``ops.grouped_series_matmul``:
one dispatch over the expert axis, O(terms) not O(E*terms)).  Under
``placement="expert"`` the stacked GEMM runs through
``dist.expert_parallel.grouped_parallel_apply`` — experts sharded over the
``"expert"`` mesh axis, int32-psum reduction per the Abelian contract.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.expansion import ExpandedTensor
from repro.models import layers as L
from repro.models.layers import QuantContext


def moe_init(key, cfg, dtype=jnp.float32) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    std_in, std_out = d ** -0.5, f ** -0.5
    p = {
        "router": L.dense_init(ks[0], d, e, dtype=dtype),
        "wi": {"kernel": jax.random.normal(ks[1], (e, d, f), dtype) * std_in},
        "wg": {"kernel": jax.random.normal(ks[2], (e, d, f), dtype) * std_in},
        "wo": {"kernel": jax.random.normal(ks[3], (e, f, d), dtype) * std_out},
    }
    if cfg.shared_expert:
        p["shared"] = L.mlp_init(ks[4], d, f, gated=True, dtype=dtype)
    return p


def _expert_mm(qc: QuantContext, x_e: jnp.ndarray, w) -> jnp.ndarray:
    """x_e: (E, C', D) @ stacked kernels (E, D, F) -> (E, C', F)."""
    kern = w["kernel"]
    if isinstance(kern, ExpandedTensor):
        if kern.batch_dims != 1:
            raise ValueError(
                f"stacked expert kernel must have batch_dims=1, got {kern}")
        if getattr(qc, "expert_parallel", False):
            from repro.dist.expert_parallel import grouped_parallel_apply
            return grouped_parallel_apply(x_e, kern, qc.policy, qc.mesh,
                                          term_budget=qc.term_budget)
        from repro.core.linear import grouped_expanded_apply
        return grouped_expanded_apply(x_e, kern, qc.policy,
                                      use_kernel=qc.use_kernel,
                                      term_budget=qc.term_budget)
    return jnp.einsum("ecd,edf->ecf", x_e, kern)


def _router_gates(qc: QuantContext, params: Dict, x: jnp.ndarray, k: int):
    """Top-k router: renormalized gate values + chosen expert indices."""
    logits = L.dense(qc, x, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    if k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    return gate_vals, gate_idx


def _combine_einsum(qc: QuantContext, spec: str, a: jnp.ndarray,
                    b: jnp.ndarray) -> jnp.ndarray:
    """Dispatch/combine contraction over the expert axis.  Under
    ``placement="expert"`` it is pinned replicated (a shard_map manual
    region): left free, GSPMD may partition the contraction over the mesh
    and reassociate the f32 sum — an ulp seed the next activation
    requantization amplifies (DESIGN.md §15)."""
    if getattr(qc, "expert_parallel", False):
        from repro.dist.expert_parallel import replicated_einsum
        return replicated_einsum(spec, a, b, qc.mesh)
    return jnp.einsum(spec, a, b)


def _experts_ffn(qc: QuantContext, params: Dict, x_e: jnp.ndarray) -> jnp.ndarray:
    """The gated expert FFN over stacked per-expert token buffers."""
    h = _expert_mm(qc, x_e, params["wi"])
    hg = _expert_mm(qc, x_e, params["wg"])
    h = jax.nn.silu(hg) * h
    return _expert_mm(qc, h, params["wo"])


def _route_group(qc: QuantContext, params: Dict, x: jnp.ndarray, cfg,
                 group_size: int):
    """Capacity-based grouped dispatch; returns (y (B,S,D) f32, stats)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tokens = b * s
    g_sz = min(group_size, tokens)
    pad = (-tokens) % g_sz
    xf = x.reshape(tokens, d)
    if pad:
        # right-pad into the last group with zero-gate rows: their routing
        # one-hot is zeroed below, so they claim no capacity slots (the
        # cumsum never sees them) and contribute/receive exactly nothing
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    g = (tokens + pad) // g_sz
    cap = min(g_sz, max(k, math.ceil(g_sz * k / e * cfg.capacity_factor)))

    xg = xf.reshape(g, g_sz, d)
    gate_vals, gate_idx = _router_gates(qc, params, xg, k)   # (G, S', k)

    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)    # (G, S', k, E)
    if pad:
        valid = (jnp.arange(tokens + pad) < tokens).reshape(g, g_sz)
        onehot = onehot * valid[:, :, None, None]
        gate_vals = gate_vals * valid[:, :, None]
    flat = onehot.reshape(g, g_sz * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                       # arrival order per expert
    pos = pos.reshape(g, g_sz, k, e)
    keep = (pos < cap) & (onehot > 0)                        # (G, S', k, E)
    # disp (G, S', k, E, C): token s's k-th choice occupies slot c of expert e
    pos_cap = jnp.clip(pos, 0, cap - 1)
    disp = keep[..., None] & (jax.nn.one_hot(pos_cap, cap, dtype=jnp.int32) > 0)
    dispatch = jnp.any(disp, axis=2).astype(x.dtype)         # (G, S', E, C) 0/1
    combine = jnp.einsum("gsk,gskec->gsec", gate_vals, disp.astype(jnp.float32))

    x_e = _combine_einsum(qc, "gsec,gsd->gecd", dispatch, xg)  # (G, E, C, D)
    x_e = x_e.transpose(1, 0, 2, 3).reshape(e, g * cap, d)
    y_e = _experts_ffn(qc, params, x_e)                      # (E, G*C, D)
    y_e = y_e.reshape(e, g, cap, d).transpose(1, 0, 2, 3)    # (G, E, C, D)
    y = _combine_einsum(qc, "gsec,gecd->gsd", combine, y_e)
    y = y.reshape(tokens + pad, d)[:tokens].reshape(b, s, d)

    kept = jnp.sum(keep.astype(jnp.int32), axis=(0, 1, 2))   # (E,) tokens/expert
    assigned = jnp.asarray(tokens * k, jnp.int32)
    stats = {"load": kept,
             "dropped": assigned - jnp.sum(kept),
             "assigned": assigned}
    return y, stats


def _route_token(qc: QuantContext, params: Dict, x: jnp.ndarray, cfg):
    """Dropless per-token dispatch (the serving rule); (y, stats)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xt = x.reshape(t, d)
    gate_vals, gate_idx = _router_gates(qc, params, xt, k)   # (T, k)

    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (T, k, E)
    member = jnp.max(onehot, axis=1)                         # (T, E) 0/1
    gates = jnp.einsum("tk,tke->te", gate_vals, onehot)      # (T, E)

    x_e = jnp.einsum("te,td->etd", member.astype(x.dtype), xt)  # (E, T, D)
    y_e = _experts_ffn(qc, params, x_e)                      # (E, T, D) f32
    y = _combine_einsum(qc, "te,etd->td", gates, y_e.astype(jnp.float32))
    y = y.reshape(b, s, d)

    # load counts every batch row (masked serving rows included): it
    # measures the compute each expert performs this round, which is what
    # the imbalance signal is for
    load = jnp.sum(member, axis=0).astype(jnp.int32)         # (E,)
    stats = {"load": load,
             "dropped": jnp.asarray(0, jnp.int32),
             "assigned": jnp.asarray(t * k, jnp.int32)}
    return y, stats


def moe_apply(qc: QuantContext, params: Dict, x: jnp.ndarray, cfg,
              *, group_size: int = 4096, routing: str = None,
              return_stats: bool = False):
    """x: (B, S, D) -> (B, S, D)  [, routing stats].

    ``routing`` defaults to the context's ``moe_routing`` ("group" unless a
    serving engine switched the contract to "token").  ``return_stats``
    additionally returns ``{"load": (E,) int32 tokens-per-expert,
    "dropped": () int32, "assigned": () int32}`` for the scheduler's
    expert-imbalance telemetry."""
    if routing is None:
        routing = getattr(qc, "moe_routing", "group")
    if routing == "token":
        y, stats = _route_token(qc, params, x, cfg)
    elif routing == "group":
        y, stats = _route_group(qc, params, x, cfg, group_size)
    else:
        raise ValueError(f"unknown MoE routing {routing!r}; "
                         f"one of ('group', 'token')")

    if "shared" in params:
        y = y + L.mlp_apply(qc, params["shared"], x, "silu")
    y = y.astype(x.dtype)
    return (y, stats) if return_stats else y


def zero_stats(cfg) -> Dict:
    """The identity element of the per-round stats accumulation — blocks
    without a MoE FFN contribute this so heterogeneous stage patterns sum
    to a fixed-structure stats pytree."""
    return {"load": jnp.zeros((cfg.num_experts,), jnp.int32),
            "dropped": jnp.asarray(0, jnp.int32),
            "assigned": jnp.asarray(0, jnp.int32)}


def add_stats(a: Dict, b: Dict) -> Dict:
    return jax.tree_util.tree_map(lambda u, v: u + v, a, b)


def load_balance_loss(logits: jnp.ndarray, gate_idx: jnp.ndarray, e: int) -> jnp.ndarray:
    """Switch-style auxiliary loss (exposed for the training loop)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e), axis=tuple(range(gate_idx.ndim - 1)))
    frac_probs = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return e * jnp.sum(frac_tokens * frac_probs)
