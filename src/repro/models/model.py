"""Config-driven model assembly: init / forward / prefill / decode / specs.

The layer stack is ``num_stages x stage_pattern + tail_pattern``; stage
parameters are *stacked* (leading ``num_stages`` axis) and run under
``jax.lax.scan`` — HLO stays one-stage-sized regardless of depth, which
keeps the 96-layer/340B dry-run compile tractable.  Expanded
(:class:`ExpandedTensor`) weights ride through the same scan; their static
``batch_dims`` metadata is peeled inside the scan body.

Modality frontends are stubs per the assignment: VLM cells take precomputed
patch embeddings (``image_emb``), audio cells take precomputed frame
embeddings (``frames``); each gets a projection GEMM into d_model.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig
from repro.core.expansion import ExpandedTensor
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.layers import FP, QuantContext

PyTree = Any


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _stage_block_names(cfg: ArchConfig):
    return [f"b{i}_{kind}" for i, kind in enumerate(cfg.stage_pattern)]


def peel_expanded(tree: PyTree) -> PyTree:
    """After lax.scan slices the stage axis off every leaf, fix the static
    batch_dims metadata of ExpandedTensor leaves to match."""
    def fix(leaf):
        if isinstance(leaf, ExpandedTensor) and leaf.batch_dims > 0:
            return leaf.unbatched_view()
        return leaf
    return jax.tree_util.tree_map(fix, tree, is_leaf=lambda l: isinstance(l, ExpandedTensor))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ArchConfig, dtype=None) -> PyTree:
    dtype = dtype or _dtype(cfg)
    keys = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    if not cfg.frame_dim:
        p["embed"] = L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)
    if cfg.frame_dim:
        p["frame_proj"] = L.dense_init(keys[1], cfg.frame_dim, cfg.d_model, dtype=dtype)
    if cfg.num_image_tokens:
        p["image_proj"] = L.dense_init(keys[2], cfg.image_embed_dim, cfg.d_model, dtype=dtype)

    stage_keys = jax.random.split(keys[3], cfg.num_stages)
    stages: Dict[str, Any] = {}
    for i, kind in enumerate(cfg.stage_pattern):
        init_one = lambda k, kind=kind, i=i: B.block_init(
            jax.random.fold_in(k, i), kind, cfg, dtype)
        stages[f"b{i}_{kind}"] = jax.vmap(init_one)(stage_keys)
    p["stages"] = stages

    if cfg.tail_pattern:
        tail_keys = jax.random.split(keys[4], len(cfg.tail_pattern))
        p["tail"] = {f"t{i}_{kind}": B.block_init(tail_keys[i], kind, cfg, dtype)
                     for i, kind in enumerate(cfg.tail_pattern)}

    p["final_norm"] = L.norm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(keys[5], cfg.d_model, cfg.vocab_size, dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# embedding / frontend
# ---------------------------------------------------------------------------
def _embed(qc, params, batch, cfg) -> Tuple[jnp.ndarray, Optional[Dict]]:
    if cfg.frame_dim:
        x = L.dense(qc, batch["frames"].astype(_dtype(cfg)), params["frame_proj"])
    else:
        x = L.embed_apply(params["embed"], batch["tokens"])
    side = None
    if cfg.num_image_tokens and "image_emb" in batch:
        img = L.dense(qc, batch["image_emb"].astype(_dtype(cfg)), params["image_proj"])
        side = {"image_emb": img}
    return x, side


# ---------------------------------------------------------------------------
# forward (train) / prefill
# ---------------------------------------------------------------------------
def _run_stack(qc, params, x, cfg, *, positions, side, remat: bool, collect_cache: bool,
               act_constraint=None, lengths=None, s_max: int = 0):
    names = _stage_block_names(cfg)

    def stage_fn(x, stage_params):
        stage_params = peel_expanded(stage_params)
        caches = {}
        for name, kind in zip(names, cfg.stage_pattern):
            x, c = B.block_forward(qc, kind, stage_params[name], x, cfg,
                                   positions=positions, side=side,
                                   lengths=lengths, s_max=s_max)
            caches[name] = c if collect_cache else None
        if act_constraint is not None:  # e.g. sequence-parallel residual stream
            x = act_constraint(x)
        return x, caches

    body = jax.checkpoint(stage_fn) if remat else stage_fn
    x, stage_caches = jax.lax.scan(body, x, params["stages"])

    tail_caches = {}
    if cfg.tail_pattern:
        for i, kind in enumerate(cfg.tail_pattern):
            name = f"t{i}_{kind}"
            x, c = B.block_forward(qc, kind, params["tail"][name], x, cfg,
                                   positions=positions, side=side,
                                   lengths=lengths, s_max=s_max)
            tail_caches[name] = c if collect_cache else None
    return x, stage_caches, tail_caches


def forward(params: PyTree, batch: Dict, cfg: ArchConfig, qc: QuantContext = FP,
            *, remat: bool = False, act_constraint=None) -> jnp.ndarray:
    """Full-sequence logits (B, S, V) — training / evaluation path."""
    x, side = _embed(qc, params, batch, cfg)
    s = x.shape[1]
    positions = jnp.arange(s)
    x, _, _ = _run_stack(qc, params, x, cfg, positions=positions, side=side,
                         remat=remat, collect_cache=False,
                         act_constraint=act_constraint)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    return L.logits_apply(qc, params, x, tie_embeddings=cfg.tie_embeddings,
                          softcap=cfg.logit_softcap)


def prefill(params: PyTree, batch: Dict, cfg: ArchConfig, qc: QuantContext = FP,
            *, s_max: int = 0, act_constraint=None, lengths=None
            ) -> Tuple[jnp.ndarray, PyTree]:
    """Process a prompt; returns (last-position logits (B, V), caches).

    attn caches are padded to ``s_max`` (decode capacity) when given.

    ``lengths`` (B,) enables *padded prefill*: rows are right-padded to the
    common sequence length and each row's true prompt length is given here.
    Causal attention keeps valid positions exact under right padding; the
    returned logits are gathered at each row's last valid position, local
    rings are built per row in decode-invariant slot order, and recurrent
    state is carried through the pad — so the caches can be scattered
    straight into a live decode cache (``scatter_cache_into_slot``)."""
    x, side = _embed(qc, params, batch, cfg)
    s = x.shape[1]
    positions = jnp.arange(s)
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
    x, stage_caches, tail_caches = _run_stack(
        qc, params, x, cfg, positions=positions, side=side, remat=False,
        collect_cache=True, act_constraint=act_constraint, lengths=lengths,
        s_max=s_max)
    if lengths is None:
        x_last = x[:, -1:, :]
    else:
        idx = jnp.clip(lengths - 1, 0, s - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    x = L.apply_norm(cfg.norm, params["final_norm"], x_last)
    logits = L.logits_apply(qc, params, x, tie_embeddings=cfg.tie_embeddings,
                            softcap=cfg.logit_softcap)
    caches = {"stages": stage_caches, "tail": tail_caches}
    if s_max:
        caches = fit_caches_for_decode(caches, cfg, s, s_max,
                                       ring_invariant=lengths is not None)
    return logits[:, 0, :], caches


def fit_caches_for_decode(caches: PyTree, cfg: ArchConfig, s: int, s_max: int,
                          *, ring_invariant: bool = False) -> PyTree:
    """Resize prefill caches to decode capacity ``s_max``:

    * attn/moe KV: zero-pad the time axis from ``s`` to ``s_max``;
    * local (ring buffer): roll entries so slot ``j`` holds position ``p``
      with ``p % w == j`` (the decode-write invariant), pad if ``s < w``;
    * cross / recurrent caches: already fixed-size — untouched.

    ``ring_invariant=True`` (padded-prefill path) asserts the local rings
    are *already* in decode-invariant slot order per row — they are only
    padded to the target window, never rolled (a roll keyed on the padded
    scalar ``s`` would corrupt per-row rings).
    """
    def visit(path, leaf):
        if leaf is None:
            return None
        names = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        block = next((n for n in names if "_" in n), "")
        key = names[-1]
        is_local = block.endswith("_local")
        is_cross = block.endswith("_cross")
        if is_cross or key not in ("k", "v", "ks", "vs", "slot_pos"):
            return leaf
        # time axis: k/v (…,B,T,G,D) -> ndim-3; ks/vs (…,B,T,G) -> ndim-2;
        # slot_pos (…,W) -> ndim-1
        t_ax = {"k": leaf.ndim - 3, "v": leaf.ndim - 3,
                "ks": leaf.ndim - 2, "vs": leaf.ndim - 2,
                "slot_pos": leaf.ndim - 1}[key]
        cur = leaf.shape[t_ax]
        if is_local:
            w_target = min(cfg.window, s_max)
            if ring_invariant:
                if cur >= w_target:
                    return leaf
                # padded-prefill rings only grow when window >= padded length,
                # in which case slots hold identity positions (p == j) and a
                # tail pad preserves the decode-write invariant
            elif cur >= w_target and s >= w_target:
                shift = (s - cur) % w_target
                return jnp.roll(leaf, shift, axis=t_ax)
            pads = [(0, 0)] * leaf.ndim
            pads[t_ax] = (0, max(0, w_target - cur))
            fill = -1 if key == "slot_pos" else 0
            return jnp.pad(leaf, pads, constant_values=fill)
        if key == "slot_pos":
            return leaf
        pads = [(0, 0)] * leaf.ndim
        pads[t_ax] = (0, max(0, s_max - cur))
        return jnp.pad(leaf, pads)

    return jax.tree_util.tree_map_with_path(visit, caches)


def scatter_cache_into_slot(live: PyTree, pref: PyTree, slot) -> PyTree:
    """Write a one-request prefill cache into batch row ``slot`` of a live
    multi-slot decode cache (continuous batching admission).

    ``pref`` must come from :func:`prefill` with ``s_max`` equal to the live
    cache's decode capacity and batch 1, so every leaf matches the live leaf
    except along the batch axis (stacked stage leaves: axis 1 after the
    ``num_stages`` axis; tail leaves: axis 0).  Stale rows left by a
    previous occupant are fully overwritten.  jit-friendly (``slot`` is a
    dynamic operand) and donation-safe for ``live``."""
    slot = jnp.asarray(slot, jnp.int32)

    def put(axis):
        return lambda lv, pv: jax.lax.dynamic_update_slice_in_dim(
            lv, pv.astype(lv.dtype), slot, axis=axis)

    return {"stages": jax.tree_util.tree_map(put(1), live["stages"], pref["stages"]),
            "tail": jax.tree_util.tree_map(put(0), live["tail"], pref["tail"])}


def decode_step(params: PyTree, tokens: jnp.ndarray, caches: PyTree,
                cache_len: jnp.ndarray, cfg: ArchConfig, qc: QuantContext = FP,
                *, inplace: bool = False, moe_stats: bool = False
                ) -> Tuple[jnp.ndarray, PyTree]:
    """One token step: tokens (B, 1) -> (logits (B, V), updated caches).

    ``cache_len`` is a scalar () for the lock-step path or a (B,) vector for
    continuous batching: each batch row (slot) sits at its own sequence
    position — attention masks, rotary offsets, and local-ring slots are all
    indexed per row.

    ``inplace=True`` runs the layer loop as a fori_loop whose carry holds
    the *stacked* caches and writes only the new token's slice — the
    TPU-production pattern (while-carry aliasing + in-place DUS).  On this
    container's CPU backend the fori carry defeats XLA's buffer aliasing
    (measured 7x MORE traffic than the scan form — EXPERIMENTS.md §Perf
    iteration D2), so the default here is the scan form; flip the default
    when deploying on real TPUs.

    ``moe_stats=True`` (static; scan form only) returns
    ``(logits, caches, stats)`` with the MoE routing telemetry summed over
    every ``moe_attn`` block — the per-round expert-load signal the slot
    scheduler folds into its imbalance stats (DESIGN.md §15)."""
    batch = {"tokens": tokens}
    x, _ = _embed(qc, params, batch, cfg)
    names = _stage_block_names(cfg)
    b = tokens.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    rows = jnp.arange(b)

    if moe_stats and inplace:
        raise ValueError("moe_stats requires the scan decode form")

    if inplace:
        def write_delta(kind, stacked, delta, i):
            """Write the one-token delta into the stacked (L, B, ...) buffers
            at each row's own position (per-slot scatter)."""
            out = {}
            for key, val in delta.items():
                buf = stacked[key]
                if val is None:
                    out[key] = buf
                    continue
                if kind in ("attn", "moe_attn") and key in ("k", "v", "ks", "vs"):
                    out[key] = buf.at[i, rows, clen].set(val[:, 0].astype(buf.dtype))
                elif kind == "local" and key in ("k", "v"):
                    slot = jnp.mod(clen, buf.shape[2])
                    out[key] = buf.at[i, rows, slot].set(val[:, 0].astype(buf.dtype))
                elif kind == "local" and key == "slot_pos":
                    slot = jnp.mod(clen, buf.shape[2])
                    out[key] = buf.at[i, rows, slot].set(val.astype(buf.dtype))
                else:  # full small recurrent state (rglru/ssm)
                    out[key] = jax.lax.dynamic_update_index_in_dim(
                        buf, val.astype(buf.dtype), i, 0)
            return out

        def layer_body(i, carry):
            x, stage_caches = carry
            stage_params = peel_expanded(jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                params["stages"]))
            new_caches = {}
            xi = x
            for name, kind in zip(names, cfg.stage_pattern):
                layer_cache = jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                    stage_caches[name])
                xi, delta = B.block_decode_delta(qc, kind, stage_params[name], xi,
                                                 layer_cache, cfg, cache_len=clen)
                new_caches[name] = delta
            stage_caches = {
                name: write_delta(kind, stage_caches[name], new_caches[name], i)
                for name, kind in zip(names, cfg.stage_pattern)}
            return xi, stage_caches

        x, stage_caches = jax.lax.fori_loop(
            0, cfg.num_stages, layer_body, (x, caches["stages"]))
    else:
        def stage_fn(x, scan_in):
            stage_params, stage_cache = scan_in
            stage_params = peel_expanded(stage_params)
            new_caches = {}
            stats = MOE.zero_stats(cfg) if moe_stats else None
            for name, kind in zip(names, cfg.stage_pattern):
                if moe_stats:
                    x, c, st = B.block_decode(qc, kind, stage_params[name], x,
                                              stage_cache[name], cfg,
                                              cache_len=clen, moe_stats=True)
                    stats = MOE.add_stats(stats, st)
                else:
                    x, c = B.block_decode(qc, kind, stage_params[name], x,
                                          stage_cache[name], cfg, cache_len=clen)
                new_caches[name] = c
            if moe_stats:
                return x, (new_caches, stats)
            return x, new_caches

        if moe_stats:
            x, (stage_caches, stage_stats) = jax.lax.scan(
                stage_fn, x, (params["stages"], caches["stages"]))
            # scan stacks per-stage stats (L, ...); sum to the round total
            moe_totals = jax.tree_util.tree_map(
                lambda a: jnp.sum(a, axis=0), stage_stats)
        else:
            x, stage_caches = jax.lax.scan(
                stage_fn, x, (params["stages"], caches["stages"]))

    tail_caches = {}
    if cfg.tail_pattern:
        for i, kind in enumerate(cfg.tail_pattern):
            name = f"t{i}_{kind}"
            if moe_stats:
                x, c, st = B.block_decode(qc, kind, params["tail"][name], x,
                                          caches["tail"][name], cfg,
                                          cache_len=clen, moe_stats=True)
                moe_totals = MOE.add_stats(moe_totals, st)
            else:
                x, c = B.block_decode(qc, kind, params["tail"][name], x,
                                      caches["tail"][name], cfg, cache_len=clen)
            tail_caches[name] = c

    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.logits_apply(qc, params, x, tie_embeddings=cfg.tie_embeddings,
                            softcap=cfg.logit_softcap)
    caches_out = {"stages": stage_caches, "tail": tail_caches}
    if moe_stats:
        return logits[:, 0, :], caches_out, moe_totals
    return logits[:, 0, :], caches_out


# ---------------------------------------------------------------------------
# speculative verify: score a T-token draft chunk in one pass (DESIGN.md §10)
# ---------------------------------------------------------------------------
def verify_step(params: PyTree, tokens: jnp.ndarray, caches: PyTree,
                cache_len: jnp.ndarray, cfg: ArchConfig, qc: QuantContext = FP
                ) -> Tuple[jnp.ndarray, PyTree]:
    """Chunked decode continuation: tokens (B, T) at per-slot positions
    ``cache_len[b] .. cache_len[b]+T-1`` -> (logits (B, T, V), deltas).

    The full-series *verify* pass of self-speculative decoding: one batched
    forward scores every draft position at once (weights are read once for
    the whole chunk, unlike T sequential decode steps).  ``caches`` is only
    READ — attention sees the cache prefix plus the chunk's own causal KV —
    and ``deltas`` mirrors the cache tree with per-position chunk values;
    :func:`commit_verify` writes the accepted prefix once the caller has
    compared draft and verify tokens."""
    batch = {"tokens": tokens}
    x, _ = _embed(qc, params, batch, cfg)
    names = _stage_block_names(cfg)
    b = tokens.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))

    def stage_fn(x, scan_in):
        stage_params, stage_cache = scan_in
        stage_params = peel_expanded(stage_params)
        deltas = {}
        for name, kind in zip(names, cfg.stage_pattern):
            x, d = B.block_verify_delta(qc, kind, stage_params[name], x,
                                        stage_cache[name], cfg, cache_len=clen)
            deltas[name] = d
        return x, deltas

    x, stage_deltas = jax.lax.scan(stage_fn, x, (params["stages"], caches["stages"]))

    tail_deltas = {}
    for i, kind in enumerate(cfg.tail_pattern):
        name = f"t{i}_{kind}"
        x, d = B.block_verify_delta(qc, kind, params["tail"][name], x,
                                    caches["tail"][name], cfg, cache_len=clen)
        tail_deltas[name] = d

    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.logits_apply(qc, params, x, tie_embeddings=cfg.tie_embeddings,
                            softcap=cfg.logit_softcap)
    return logits, {"stages": stage_deltas, "tail": tail_deltas}


def _commit_block(kind: str, cfg: ArchConfig, cache: PyTree, delta: PyTree,
                  clen: jnp.ndarray, accept: jnp.ndarray) -> PyTree:
    """Write one block's verified chunk into its live cache row-wise.

    ``accept`` (B,) is the per-slot count of accepted *draft* tokens m; the
    round consumes m+1 chunk inputs, so positions ``clen..clen+m`` become
    valid KV/state and everything past them is rolled back:

    * attn/moe_attn: all T rows are written — rows past the new
      ``cache_len = clen+m+1`` are stale-but-masked (the slot scheduler's
      invariant) and are overwritten by later rounds before ever unmasking;
      out-of-capacity rows (an over-budget chunk tail) drop via JAX scatter
      OOB semantics and are never consumed.
    * local ring: chunk entries land at ``(clen+t) % W``; entries whose
      recorded position exceeds ``clen+accept`` are restored from the
      pre-round ring — a rejected draft must not evict a window entry that
      future queries still attend.  Requires T <= W (enforced at engine
      construction).
    * rglru/ssm: gather the per-step state at index ``accept`` (state after
      the m+1 accepted inputs).
    * cross: static — untouched.
    """
    if kind == "cross" or delta is None:
        return cache
    b = clen.shape[0]
    rows = jnp.arange(b)
    if kind in ("attn", "moe_attn"):
        t = delta["k"].shape[1]
        idx = clen[:, None] + jnp.arange(t)[None, :]            # (B, T)
        return {key: cache[key].at[rows[:, None], idx].set(
                    delta[key].astype(cache[key].dtype))
                for key in cache}
    if kind == "local":
        w = cache["k"].shape[1]
        t = delta["k"].shape[1]
        pos = clen[:, None] + jnp.arange(t)[None, :]            # (B, T)
        slot = jnp.mod(pos, w)
        sp_old = cache["slot_pos"]
        k_new = cache["k"].at[rows[:, None], slot].set(
            delta["k"].astype(cache["k"].dtype))
        v_new = cache["v"].at[rows[:, None], slot].set(
            delta["v"].astype(cache["v"].dtype))
        sp_new = sp_old.at[rows[:, None], slot].set(pos.astype(sp_old.dtype))
        keep = sp_new <= (clen + accept)[:, None]               # (B, W)
        return {"k": jnp.where(keep[:, :, None, None], k_new, cache["k"]),
                "v": jnp.where(keep[:, :, None, None], v_new, cache["v"]),
                "slot_pos": jnp.where(keep, sp_new, sp_old)}
    # recurrent kinds: per-step stacked states — gather the accepted index
    def pick(buf, d):
        idx = accept.reshape((b,) + (1,) * (d.ndim - 1))
        return jnp.take_along_axis(d, idx, axis=1)[:, 0].astype(buf.dtype)
    return {key: pick(cache[key], delta[key]) for key in cache}


def commit_verify(caches: PyTree, deltas: PyTree, cache_len: jnp.ndarray,
                  accept: jnp.ndarray, cfg: ArchConfig) -> PyTree:
    """Apply :func:`verify_step` deltas for the accepted prefix: the caches
    come out exactly as if the accepted tokens had been decoded one-by-one
    (modulo fp reassociation of the chunked GEMMs); rejected positions are
    rolled back by construction.  ``accept`` (B,) = accepted draft count per
    slot; the slot's new cache length is ``cache_len + accept + 1``."""
    b = accept.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    m = jnp.asarray(accept, jnp.int32)
    names = _stage_block_names(cfg)
    stages = {}
    for name, kind in zip(names, cfg.stage_pattern):
        if kind == "cross":
            stages[name] = caches["stages"][name]
            continue
        stages[name] = jax.vmap(
            lambda c, d, kind=kind: _commit_block(kind, cfg, c, d, clen, m)
        )(caches["stages"][name], deltas["stages"][name])
    tail = {}
    for i, kind in enumerate(cfg.tail_pattern):
        name = f"t{i}_{kind}"
        tail[name] = _commit_block(kind, cfg, caches["tail"][name],
                                   deltas["tail"][name], clen, m)
    return {"stages": stages, "tail": tail}


# ---------------------------------------------------------------------------
# paged KV serving (DESIGN.md §13): full-attention KV lives in global
# per-layer page pools addressed through per-slot block tables; every other
# cache kind (local rings, recurrent state) keeps its dense per-slot layout.
# The sentinel page is the LAST pool row; host-side allocation lives in
# repro.infer.kvcache.PageAllocator.
# ---------------------------------------------------------------------------
def _is_pool_leaf(path) -> bool:
    names = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
    block = next((n for n in names if "_" in n), "")
    return block.endswith("_attn") and names[-1] in ("k", "v", "ks", "vs")


def init_paged_cache(cfg: ArchConfig, batch: int, s_max: int, *,
                     page_size: int, num_pages: int, dtype=None,
                     int8_kv: bool = False, mesh=None) -> PyTree:
    """Like :func:`init_cache`, but attn/moe_attn KV is a page pool
    ``(num_pages + 1, page_size, G, Dh)`` per layer (last row = sentinel
    page) shared by all slots; non-attention caches stay per-slot dense."""
    dtype = dtype or _dtype(cfg)
    stage_caches = {}
    for i, kind in enumerate(cfg.stage_pattern):
        if kind in ("attn", "moe_attn"):
            one = lambda _, kind=kind: B.init_block_pool(
                kind, cfg, num_pages, page_size, dtype, int8_kv=int8_kv)
        else:
            one = lambda _, kind=kind: B.init_block_cache(
                kind, cfg, batch, s_max, dtype, int8_kv=int8_kv)
        stage_caches[f"b{i}_{kind}"] = jax.vmap(one)(jnp.arange(cfg.num_stages))
    tail = {}
    for i, kind in enumerate(cfg.tail_pattern):
        if kind in ("attn", "moe_attn"):
            tail[f"t{i}_{kind}"] = B.init_block_pool(
                kind, cfg, num_pages, page_size, dtype, int8_kv=int8_kv)
        else:
            tail[f"t{i}_{kind}"] = B.init_block_cache(
                kind, cfg, batch, s_max, dtype, int8_kv=int8_kv)
    caches = {"stages": stage_caches, "tail": tail}
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        caches = jax.device_put(caches, NamedSharding(mesh, PartitionSpec()))
    return caches


def scatter_cache_into_pages(live: PyTree, pref: PyTree, slot, page_ids,
                             page_size: int) -> PyTree:
    """Paged admission: write a one-request prefill cache into the live
    paged cache.  Pool leaves scatter the prompt KV into the slot's
    reserved pages (``page_ids`` (MP,), sentinel-padded past the
    allocation — always the full table length, so there is one scatter
    shape and one retrace); all other leaves write batch row ``slot``
    exactly like :func:`scatter_cache_into_slot`."""
    slot = jnp.asarray(slot, jnp.int32)
    page_ids = jnp.asarray(page_ids, jnp.int32)
    mp = page_ids.shape[0]
    cap = mp * page_size

    def visit(stage: bool):
        def f(path, lv, pv):
            if lv is None or pv is None:
                return lv
            if _is_pool_leaf(path):
                t_ax = 1 if stage else 0
                vals = jnp.squeeze(pv, axis=t_ax)          # drop batch-1 axis
                s = vals.shape[t_ax]
                if s < cap:
                    pads = [(0, 0)] * vals.ndim
                    pads[t_ax] = (0, cap - s)
                    vals = jnp.pad(vals, pads)
                shape = vals.shape[:t_ax] + (mp, page_size) + vals.shape[t_ax + 1:]
                vals = vals.reshape(shape).astype(lv.dtype)
                if stage:
                    return lv.at[:, page_ids].set(vals)
                return lv.at[page_ids].set(vals)
            return jax.lax.dynamic_update_slice_in_dim(
                lv, pv.astype(lv.dtype), slot, axis=1 if stage else 0)
        return f

    return {"stages": jax.tree_util.tree_map_with_path(
                visit(True), live["stages"], pref["stages"]),
            "tail": jax.tree_util.tree_map_with_path(
                visit(False), live["tail"], pref["tail"])}


def paged_decode_step(params: PyTree, tokens: jnp.ndarray, caches: PyTree,
                      cache_len: jnp.ndarray, block_tables: jnp.ndarray,
                      cfg: ArchConfig, qc: QuantContext = FP, *,
                      page_size: int) -> Tuple[jnp.ndarray, PyTree]:
    """Paged twin of :func:`decode_step` (scan form): attn blocks read/write
    through ``block_tables`` (B, MP); other kinds run their dense path."""
    x, _ = _embed(qc, params, {"tokens": tokens}, cfg)
    names = _stage_block_names(cfg)
    b = tokens.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    bt = jnp.asarray(block_tables, jnp.int32)

    def stage_fn(x, scan_in):
        stage_params, stage_cache = scan_in
        stage_params = peel_expanded(stage_params)
        new_caches = {}
        for name, kind in zip(names, cfg.stage_pattern):
            x, c = B.block_decode_paged(qc, kind, stage_params[name], x,
                                        stage_cache[name], cfg, cache_len=clen,
                                        block_tables=bt, page_size=page_size)
            new_caches[name] = c
        return x, new_caches

    x, stage_caches = jax.lax.scan(stage_fn, x, (params["stages"], caches["stages"]))

    tail_caches = {}
    for i, kind in enumerate(cfg.tail_pattern):
        name = f"t{i}_{kind}"
        x, c = B.block_decode_paged(qc, kind, params["tail"][name], x,
                                    caches["tail"][name], cfg, cache_len=clen,
                                    block_tables=bt, page_size=page_size)
        tail_caches[name] = c

    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.logits_apply(qc, params, x, tie_embeddings=cfg.tie_embeddings,
                            softcap=cfg.logit_softcap)
    return logits[:, 0, :], {"stages": stage_caches, "tail": tail_caches}


def paged_verify_step(params: PyTree, tokens: jnp.ndarray, caches: PyTree,
                      cache_len: jnp.ndarray, block_tables: jnp.ndarray,
                      cfg: ArchConfig, qc: QuantContext = FP, *,
                      page_size: int) -> Tuple[jnp.ndarray, PyTree]:
    """Paged twin of :func:`verify_step`: read-only chunk scoring against
    the paged cache; commit via :func:`commit_verify_paged`."""
    x, _ = _embed(qc, params, {"tokens": tokens}, cfg)
    names = _stage_block_names(cfg)
    b = tokens.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    bt = jnp.asarray(block_tables, jnp.int32)

    def stage_fn(x, scan_in):
        stage_params, stage_cache = scan_in
        stage_params = peel_expanded(stage_params)
        deltas = {}
        for name, kind in zip(names, cfg.stage_pattern):
            x, d = B.block_verify_paged(qc, kind, stage_params[name], x,
                                        stage_cache[name], cfg, cache_len=clen,
                                        block_tables=bt, page_size=page_size)
            deltas[name] = d
        return x, deltas

    x, stage_deltas = jax.lax.scan(stage_fn, x, (params["stages"], caches["stages"]))

    tail_deltas = {}
    for i, kind in enumerate(cfg.tail_pattern):
        name = f"t{i}_{kind}"
        x, d = B.block_verify_paged(qc, kind, params["tail"][name], x,
                                    caches["tail"][name], cfg, cache_len=clen,
                                    block_tables=bt, page_size=page_size)
        tail_deltas[name] = d

    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.logits_apply(qc, params, x, tie_embeddings=cfg.tie_embeddings,
                            softcap=cfg.logit_softcap)
    return logits, {"stages": stage_deltas, "tail": tail_deltas}


# ---------------------------------------------------------------------------
# chunked prefill scoring (DESIGN.md §14): verify_step's layout with per-row
# formulation selection so prefill rows reproduce monolithic prefill
# bit-for-bit while spliced decode rows reproduce the decode engine.
# ---------------------------------------------------------------------------
def chunk_prefill_step(params: PyTree, tokens: jnp.ndarray, caches: PyTree,
                       cache_len: jnp.ndarray, decode_rows: jnp.ndarray,
                       cfg: ArchConfig, qc: QuantContext = FP, *,
                       s_max: int) -> Tuple[jnp.ndarray, PyTree]:
    """Score one prefill chunk (B, T) read-only against the dense caches.

    Identical delta layout and commit path as :func:`verify_step`, but
    attention dispatches per row on ``decode_rows`` (B,) bool:
    prefill rows use the positional single-buffer formulation (bit-identical
    to :func:`prefill`'s lengths path over the same ``s_max``-wide buffer),
    decode rows keep the split cache/new decode formulation."""
    x, _ = _embed(qc, params, {"tokens": tokens}, cfg)
    names = _stage_block_names(cfg)
    b = tokens.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    dmask = jnp.asarray(decode_rows, bool)

    def stage_fn(x, scan_in):
        stage_params, stage_cache = scan_in
        stage_params = peel_expanded(stage_params)
        deltas = {}
        for name, kind in zip(names, cfg.stage_pattern):
            x, d = B.block_chunk_delta(qc, kind, stage_params[name], x,
                                       stage_cache[name], cfg, cache_len=clen,
                                       decode_rows=dmask, s_max=s_max)
            deltas[name] = d
        return x, deltas

    x, stage_deltas = jax.lax.scan(stage_fn, x, (params["stages"], caches["stages"]))

    tail_deltas = {}
    for i, kind in enumerate(cfg.tail_pattern):
        name = f"t{i}_{kind}"
        x, d = B.block_chunk_delta(qc, kind, params["tail"][name], x,
                                   caches["tail"][name], cfg, cache_len=clen,
                                   decode_rows=dmask, s_max=s_max)
        tail_deltas[name] = d

    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.logits_apply(qc, params, x, tie_embeddings=cfg.tie_embeddings,
                            softcap=cfg.logit_softcap)
    return logits, {"stages": stage_deltas, "tail": tail_deltas}


def paged_chunk_prefill_step(params: PyTree, tokens: jnp.ndarray,
                             caches: PyTree, cache_len: jnp.ndarray,
                             block_tables: jnp.ndarray,
                             decode_rows: jnp.ndarray, cfg: ArchConfig,
                             qc: QuantContext = FP, *, page_size: int,
                             s_max: int) -> Tuple[jnp.ndarray, PyTree]:
    """Paged twin of :func:`chunk_prefill_step` (commit via
    :func:`commit_prefill_chunk_paged`)."""
    x, _ = _embed(qc, params, {"tokens": tokens}, cfg)
    names = _stage_block_names(cfg)
    b = tokens.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    bt = jnp.asarray(block_tables, jnp.int32)
    dmask = jnp.asarray(decode_rows, bool)

    def stage_fn(x, scan_in):
        stage_params, stage_cache = scan_in
        stage_params = peel_expanded(stage_params)
        deltas = {}
        for name, kind in zip(names, cfg.stage_pattern):
            x, d = B.block_chunk_paged(qc, kind, stage_params[name], x,
                                       stage_cache[name], cfg, cache_len=clen,
                                       block_tables=bt, page_size=page_size,
                                       decode_rows=dmask, s_max=s_max)
            deltas[name] = d
        return x, deltas

    x, stage_deltas = jax.lax.scan(stage_fn, x, (params["stages"], caches["stages"]))

    tail_deltas = {}
    for i, kind in enumerate(cfg.tail_pattern):
        name = f"t{i}_{kind}"
        x, d = B.block_chunk_paged(qc, kind, params["tail"][name], x,
                                   caches["tail"][name], cfg, cache_len=clen,
                                   block_tables=bt, page_size=page_size,
                                   decode_rows=dmask, s_max=s_max)
        tail_deltas[name] = d

    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.logits_apply(qc, params, x, tie_embeddings=cfg.tie_embeddings,
                            softcap=cfg.logit_softcap)
    return logits, {"stages": stage_deltas, "tail": tail_deltas}


def _commit_pool(cache: PyTree, delta: PyTree, clen: jnp.ndarray,
                 block_tables: jnp.ndarray, page_size: int) -> PyTree:
    """Write a verified chunk into one layer's page pools: all T positions
    are written (positions past the accepted prefix are stale-but-masked,
    the same invariant as the dense commit); positions past the block table
    or on unallocated table slots land on the sentinel page."""
    t = delta["k"].shape[1]
    mp = block_tables.shape[1]
    pos = clen[:, None] + jnp.arange(t)[None, :]                 # (B, T)
    pidx = pos // page_size
    pid = jnp.take_along_axis(block_tables, jnp.clip(pidx, 0, mp - 1), axis=1)
    off = jnp.mod(pos, page_size)
    out = {}
    for key in cache:
        sentinel = cache[key].shape[0] - 1
        pid_k = jnp.where(pidx < mp, pid, sentinel)
        out[key] = cache[key].at[pid_k, off].set(
            delta[key].astype(cache[key].dtype))
    return out


def commit_verify_paged(caches: PyTree, deltas: PyTree, cache_len: jnp.ndarray,
                        accept: jnp.ndarray, block_tables: jnp.ndarray,
                        cfg: ArchConfig, *, page_size: int) -> PyTree:
    """Paged twin of :func:`commit_verify`: attn chunks go through the block
    tables; every other kind commits exactly as the dense path."""
    b = accept.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    m = jnp.asarray(accept, jnp.int32)
    bt = jnp.asarray(block_tables, jnp.int32)
    names = _stage_block_names(cfg)
    stages = {}
    for name, kind in zip(names, cfg.stage_pattern):
        if kind in ("attn", "moe_attn"):
            stages[name] = jax.vmap(
                lambda c, d: _commit_pool(c, d, clen, bt, page_size)
            )(caches["stages"][name], deltas["stages"][name])
        elif kind == "cross":
            stages[name] = caches["stages"][name]
        else:
            stages[name] = jax.vmap(
                lambda c, d, kind=kind: _commit_block(kind, cfg, c, d, clen, m)
            )(caches["stages"][name], deltas["stages"][name])
    tail = {}
    for i, kind in enumerate(cfg.tail_pattern):
        name = f"t{i}_{kind}"
        if kind in ("attn", "moe_attn"):
            tail[name] = _commit_pool(caches["tail"][name],
                                      deltas["tail"][name], clen, bt, page_size)
        else:
            tail[name] = _commit_block(kind, cfg, caches["tail"][name],
                                       deltas["tail"][name], clen, m)
    return {"stages": stages, "tail": tail}


# ---------------------------------------------------------------------------
# chunked prefill (DESIGN.md §14): a prompt is fed through verify_step /
# paged_verify_step in fixed-size chunks that resume at the slot's current
# cache offset; the commits below differ from the verify commits in taking a
# per-row *valid* count (chunk tails can be bucket padding) instead of an
# accepted-draft count, and — for the paged pool — a per-row *write floor*
# protecting shared (increfed) prefix pages from being re-written.
# ---------------------------------------------------------------------------
def _commit_chunk_block(kind: str, cfg: ArchConfig, cache: PyTree,
                        delta: PyTree, clen: jnp.ndarray,
                        valid: jnp.ndarray) -> PyTree:
    """Write one block's prefill chunk into its live cache row-wise.

    ``valid`` (B,) counts the real (non-padding) tokens at the head of the
    chunk; the slot's cache length advances to ``clen + valid``:

    * attn/moe_attn: all T rows are written — rows past ``clen + valid``
      are stale-but-masked (reads mask strictly below the cache length) and
      are overwritten by later chunks/decodes before ever unmasking.
    * local ring: gather-based — for each ring slot j the final position it
      should hold is ``last - ((last - j) mod W)`` with
      ``last = clen + valid - 1``; slots whose final position falls inside
      the chunk take the chunk entry, the rest keep their pre-chunk entry
      (by the ring invariant it is already the newest position ≡ j mod W
      below ``clen``).  Unlike the verify commit this handles T > W: a
      chunk wider than the window simply rewrites the whole ring.
    * rglru/ssm: gather the per-step state at index ``valid - 1`` (state
      after the last real token; padding never advances the carry).
    * cross: static — untouched (chunked prefill rejects cross archs at
      engine construction, so this branch only sees passthrough).
    """
    if kind == "cross" or delta is None:
        return cache
    b = clen.shape[0]
    rows = jnp.arange(b)
    if kind in ("attn", "moe_attn"):
        t = delta["k"].shape[1]
        idx = clen[:, None] + jnp.arange(t)[None, :]            # (B, T)
        return {key: cache[key].at[rows[:, None], idx].set(
                    delta[key].astype(cache[key].dtype))
                for key in cache}
    if kind == "local":
        w = cache["k"].shape[1]
        t = delta["k"].shape[1]
        j = jnp.arange(w)[None, :]                              # (1, W)
        last = (clen + valid - 1)[:, None]                      # (B, 1)
        ring_pos = last - jnp.mod(last - j, w)                  # (B, W)
        from_chunk = (ring_pos >= clen[:, None]) & (valid[:, None] > 0)
        idx = jnp.clip(ring_pos - clen[:, None], 0, t - 1)
        gk = jnp.take_along_axis(delta["k"].astype(cache["k"].dtype),
                                 idx[:, :, None, None], axis=1)
        gv = jnp.take_along_axis(delta["v"].astype(cache["v"].dtype),
                                 idx[:, :, None, None], axis=1)
        sp = cache["slot_pos"]
        return {"k": jnp.where(from_chunk[:, :, None, None], gk, cache["k"]),
                "v": jnp.where(from_chunk[:, :, None, None], gv, cache["v"]),
                "slot_pos": jnp.where(from_chunk, ring_pos, sp).astype(sp.dtype)}
    # recurrent kinds: per-step stacked states — state after the last real token
    def pick(buf, d):
        i = jnp.clip(valid - 1, 0, d.shape[1] - 1)
        i = i.reshape((b,) + (1,) * (d.ndim - 1))
        return jnp.take_along_axis(d, i, axis=1)[:, 0].astype(buf.dtype)
    return {key: pick(cache[key], delta[key]) for key in cache}


def commit_prefill_chunk(caches: PyTree, deltas: PyTree, cache_len: jnp.ndarray,
                         valid: jnp.ndarray, cfg: ArchConfig) -> PyTree:
    """Apply :func:`verify_step` deltas as a prefill chunk: the caches come
    out exactly as if positions ``cache_len .. cache_len+valid-1`` had been
    prefilled monolithically (modulo fp reassociation of the chunked GEMMs);
    padding positions (``>= valid``) never become visible."""
    b = valid.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    vld = jnp.asarray(valid, jnp.int32)
    names = _stage_block_names(cfg)
    stages = {}
    for name, kind in zip(names, cfg.stage_pattern):
        if kind == "cross":
            stages[name] = caches["stages"][name]
            continue
        stages[name] = jax.vmap(
            lambda c, d, kind=kind: _commit_chunk_block(kind, cfg, c, d,
                                                        clen, vld)
        )(caches["stages"][name], deltas["stages"][name])
    tail = {}
    for i, kind in enumerate(cfg.tail_pattern):
        name = f"t{i}_{kind}"
        tail[name] = _commit_chunk_block(kind, cfg, caches["tail"][name],
                                         deltas["tail"][name], clen, vld)
    return {"stages": stages, "tail": tail}


def _commit_pool_chunk(cache: PyTree, delta: PyTree, clen: jnp.ndarray,
                       valid: jnp.ndarray, write_from: jnp.ndarray,
                       block_tables: jnp.ndarray, page_size: int) -> PyTree:
    """Write a prefill chunk into one layer's page pools.

    Unlike :func:`_commit_pool` the write set is *exact*: only positions in
    ``[max(clen, write_from), clen + valid)`` land on real pages — padding
    rows and positions below the per-row write floor divert to the sentinel.
    The floor is what keeps shared prefixes sound: a request whose block
    table starts with increfed (trie-owned) pages must never re-write them,
    and a bucketed chunk tail must never leak pad KV into a page another
    request can match (the ``prefill_bucket`` x chunking interaction)."""
    t = delta["k"].shape[1]
    mp = block_tables.shape[1]
    pos = clen[:, None] + jnp.arange(t)[None, :]                 # (B, T)
    pidx = pos // page_size
    pid = jnp.take_along_axis(block_tables, jnp.clip(pidx, 0, mp - 1), axis=1)
    off = jnp.mod(pos, page_size)
    ok = ((pos >= write_from[:, None]) & (pos < (clen + valid)[:, None])
          & (pidx < mp))
    out = {}
    for key in cache:
        sentinel = cache[key].shape[0] - 1
        pid_k = jnp.where(ok, pid, sentinel)
        out[key] = cache[key].at[pid_k, off].set(
            delta[key].astype(cache[key].dtype))
    return out


def commit_prefill_chunk_paged(caches: PyTree, deltas: PyTree,
                               cache_len: jnp.ndarray, valid: jnp.ndarray,
                               write_from: jnp.ndarray,
                               block_tables: jnp.ndarray, cfg: ArchConfig, *,
                               page_size: int) -> PyTree:
    """Paged twin of :func:`commit_prefill_chunk`: attn chunks go through
    the block tables with the shared-page write floor; every other kind
    commits exactly as the dense chunk path."""
    b = valid.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    vld = jnp.asarray(valid, jnp.int32)
    wf = jnp.asarray(write_from, jnp.int32)
    bt = jnp.asarray(block_tables, jnp.int32)
    names = _stage_block_names(cfg)
    stages = {}
    for name, kind in zip(names, cfg.stage_pattern):
        if kind in ("attn", "moe_attn"):
            stages[name] = jax.vmap(
                lambda c, d: _commit_pool_chunk(c, d, clen, vld, wf, bt,
                                                page_size)
            )(caches["stages"][name], deltas["stages"][name])
        elif kind == "cross":
            stages[name] = caches["stages"][name]
        else:
            stages[name] = jax.vmap(
                lambda c, d, kind=kind: _commit_chunk_block(kind, cfg, c, d,
                                                            clen, vld)
            )(caches["stages"][name], deltas["stages"][name])
    tail = {}
    for i, kind in enumerate(cfg.tail_pattern):
        name = f"t{i}_{kind}"
        if kind in ("attn", "moe_attn"):
            tail[name] = _commit_pool_chunk(caches["tail"][name],
                                            deltas["tail"][name], clen, vld,
                                            wf, bt, page_size)
        else:
            tail[name] = _commit_chunk_block(kind, cfg, caches["tail"][name],
                                             deltas["tail"][name], clen, vld)
    return {"stages": stages, "tail": tail}


# ---------------------------------------------------------------------------
# cache construction & input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=None,
               int8_kv: bool = False, mesh=None) -> PyTree:
    """Zero decode caches for ``batch`` slots at capacity ``s_max``.

    ``mesh`` (a 1-D serving mesh, DESIGN.md §9) commits the caches
    *replicated* across the mesh devices — slot rows are identical
    everywhere; only weights are scattered by a placement — so the fused
    decode step's donation/aliasing works identically sharded and not."""
    dtype = dtype or _dtype(cfg)
    stage_caches = {}
    for i, kind in enumerate(cfg.stage_pattern):
        one = lambda _, kind=kind: B.init_block_cache(kind, cfg, batch, s_max,
                                                      dtype, int8_kv=int8_kv)
        stage_caches[f"b{i}_{kind}"] = jax.vmap(one)(jnp.arange(cfg.num_stages))
    tail = {f"t{i}_{kind}": B.init_block_cache(kind, cfg, batch, s_max, dtype,
                                               int8_kv=int8_kv)
            for i, kind in enumerate(cfg.tail_pattern)}
    caches = {"stages": stage_caches, "tail": tail}
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        caches = jax.device_put(caches, NamedSharding(mesh, PartitionSpec()))
    return caches


def input_specs(cfg: ArchConfig, shape: str | ShapeConfig,
                int8_kv: bool = False) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell."""
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    b, s = sh.global_batch, sh.seq_len
    dt = _dtype(cfg)
    tok = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)

    def batch_specs(seq):
        spec: Dict[str, Any] = {}
        if cfg.frame_dim:
            spec["frames"] = jax.ShapeDtypeStruct((b, seq, cfg.frame_dim), dt)
        else:
            spec["tokens"] = tok(b, seq)
        if cfg.num_image_tokens:
            spec["image_emb"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.image_embed_dim), dt)
        return spec

    if sh.kind == "train":
        spec = batch_specs(s)
        spec["labels"] = tok(b, s)
        return {"batch": spec}
    if sh.kind == "prefill":
        return {"batch": batch_specs(s)}
    if sh.kind == "decode":
        caches = jax.eval_shape(lambda: init_cache(cfg, b, s, int8_kv=int8_kv))
        spec: Dict[str, Any] = {"tokens": tok(b, 1), "caches": caches,
                                "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}
        return spec
    raise ValueError(sh.kind)


# ---------------------------------------------------------------------------
# convenience wrapper
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    qc: QuantContext = FP

    def init(self, key, dtype=None):
        return init_params(key, self.cfg, dtype)

    def __call__(self, params, batch, **kw):
        return forward(params, batch, self.cfg, self.qc, **kw)

    def prefill(self, params, batch, **kw):
        return prefill(params, batch, self.cfg, self.qc, **kw)

    def decode_step(self, params, tokens, caches, cache_len):
        return decode_step(params, tokens, caches, cache_len, self.cfg, self.qc)
