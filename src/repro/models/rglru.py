"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Linear recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)  with
input-dependent gates; parallelized over sequence with
``jax.lax.associative_scan`` (combine: (a1,b1)∘(a2,b2) = (a1*a2, a2*b1+b2)),
O(log L) depth — the TPU-native mapping of the recurrence.  Decode is an
O(1) state update (enables the ``long_500k`` cell for recurrentgemma).

The block is Griffin's "recurrent block": two D->D_rnn input GEMMs (gate
branch, recurrent branch), a short causal conv, the RG-LRU, and an output
GEMM — all GEMMs are FP=xINT-expandable ``kernel`` leaves.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import QuantContext

_C = 8.0  # Griffin's recurrence-gate temperature


def rglru_init(key, cfg, dtype=jnp.float32) -> Dict:
    d, dr = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 6)
    return {
        "in_x": L.dense_init(ks[0], d, dr, dtype=dtype),      # recurrent branch
        "in_gate": L.dense_init(ks[1], d, dr, dtype=dtype),   # GeLU gate branch
        "conv": L.conv1d_init(ks[2], dr, 4, dtype=dtype),
        "w_r": L.dense_init(ks[3], dr, dr, dtype=dtype),      # recurrence gate
        "w_i": L.dense_init(ks[4], dr, dr, dtype=dtype),      # input gate
        "lam": jnp.full((dr,), 4.0, dtype),                   # a = sigmoid(lam)^ (c r)
        "out": L.dense_init(ks[5], dr, d, dtype=dtype),
    }


def _gates(qc, params, xr):
    """log_a: (..., Dr) in (-inf, 0];  gated input."""
    r = jax.nn.sigmoid(L.dense(qc, xr, params["w_r"]))
    i = jax.nn.sigmoid(L.dense(qc, xr, params["w_i"]))
    log_a = -_C * r * jax.nn.softplus(params["lam"])          # <= 0
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12))
    return a, mult * (i * xr)


def rglru_apply(qc: QuantContext, params: Dict, x_in: jnp.ndarray,
                cfg, *, lengths=None) -> Tuple[jnp.ndarray, Dict]:
    """x_in: (B,L,D) -> (out (B,L,D), cache {'conv', 'h'}).

    ``lengths`` (B,) marks right-padded rows: padded positions run the
    recurrence as identity (a=1, b=0), so the final state ``h[:, -1]`` is
    exactly the state at each row's true length, and the conv cache is
    gathered from the last valid inputs per row (padded prefill-into-slot)."""
    xr_raw = L.dense(qc, x_in, params["in_x"])                # (B,L,Dr)
    gate = jax.nn.gelu(L.dense(qc, x_in, params["in_gate"]))
    xr = L.causal_conv1d(params["conv"], xr_raw)
    a, b = _gates(qc, params, xr)
    if lengths is not None:
        valid = (jnp.arange(x_in.shape[1])[None, :] < lengths[:, None])[..., None]
        a = jnp.where(valid, a, 1.0)                          # carry h through pad
        b = jnp.where(valid, b, 0.0)
        # serving prefill-into-slot: the sequential left fold.  A left fold
        # splits exactly at any chunk boundary and steps in precisely
        # rglru_verify / rglru_decode_step's per-token form, so chunked
        # prefill reproduces the trajectory bit-for-bit (DESIGN.md §14) —
        # the associative-scan tree reassociates intermediate states at the
        # ulp level, which per-batch quantization amplifies into token flips.

        def step(h_c, ab):
            a_t, b_t = ab
            h_n = a_t * h_c + b_t
            return h_n, h_n

        _, h = jax.lax.scan(step, jnp.zeros_like(a[:, 0]),
                            (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
        h = jnp.moveaxis(h, 0, 1)                             # (B,L,Dr)
    else:
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = L.dense(qc, h * gate, params["out"])
    k = params["conv"]["w"].shape[0]
    l_ = x_in.shape[1]
    if lengths is not None:
        conv_state = L.gather_tail(xr_raw, lengths, k - 1)
    else:
        conv_state = xr_raw[:, -(k - 1):, :] if l_ >= k - 1 else jnp.pad(
            xr_raw, ((0, 0), (k - 1 - l_, 0), (0, 0)))
    return out, {"conv": conv_state, "h": h[:, -1, :]}


def rglru_verify(qc: QuantContext, params: Dict, x: jnp.ndarray,
                 cache: Dict, cfg) -> Tuple[jnp.ndarray, Dict]:
    """Multi-token decode continuation (speculative verify, DESIGN.md §10).

    x: (B, T, D); cache: {'conv': (B, K-1, Dr), 'h': (B, Dr)} — the state
    *entering* the chunk.  Returns (out (B, T, D), per-step states
    {'conv': (B, T, K-1, Dr), 'h': (B, T, Dr)}): entry ``t`` is the state
    after consuming chunk tokens 0..t, so accept/rollback is a gather at the
    accepted index.  The input GEMMs run chunked (B, T, ·); the conv and the
    recurrence are unrolled per step in exactly
    :func:`rglru_decode_step`'s form, so per-token state trajectories match
    the sequential decode path."""
    t = x.shape[1]
    xr_raw = L.dense(qc, x, params["in_x"])                   # (B,T,Dr)
    gate = jax.nn.gelu(L.dense(qc, x, params["in_gate"]))
    w, bias = params["conv"]["w"], params["conv"]["b"]
    k = w.shape[0]
    xp = jnp.concatenate([cache["conv"].astype(xr_raw.dtype), xr_raw], axis=1)
    xr = jnp.stack([jnp.einsum("bkc,kc->bc", xp[:, j:j + k, :], w) + bias
                    for j in range(t)], axis=1)               # (B,T,Dr)
    a, b_in = _gates(qc, params, xr)
    h = cache["h"]
    hs = []
    for j in range(t):                                        # static unroll
        h = a[:, j] * h + b_in[:, j]
        hs.append(h)
    hs = jnp.stack(hs, axis=1)                                # (B,T,Dr)
    out = L.dense(qc, hs * gate, params["out"])
    convs = jnp.stack([xp[:, j + 1:j + k, :] for j in range(t)], axis=1)
    return out, {"conv": convs, "h": hs}


def rglru_decode_step(qc: QuantContext, params: Dict, x_t: jnp.ndarray,
                      cache: Dict, cfg) -> Tuple[jnp.ndarray, Dict]:
    """x_t: (B,1,D); cache: {'conv': (B,K-1,Dr), 'h': (B,Dr)}."""
    x = x_t[:, 0, :]
    xr_raw = L.dense(qc, x, params["in_x"])                   # (B,Dr)
    gate = jax.nn.gelu(L.dense(qc, x, params["in_gate"]))
    xr, conv_state = L.causal_conv1d_step(params["conv"], cache["conv"], xr_raw)
    a, b = _gates(qc, params, xr)
    h = a * cache["h"] + b
    out = L.dense(qc, h * gate, params["out"])
    return out[:, None, :], {"conv": conv_state, "h": h}
