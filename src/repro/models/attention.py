"""Attention: chunked online-softmax ("flash") prefill/train path + decode path.

The flash path double-scans (q chunks outer, kv chunks inner) with a running
(max, denom, accum) online softmax, so peak memory is
O(q_chunk * kv_chunk) per (batch, head) instead of O(S^2) — required for the
32k-sequence dry-run cells.  GQA is computed in grouped form
(B, G, R, S, D) without materializing repeated KV heads.

Supports: causal/full, sliding-window (``window > 0``), logit softcap
(grok), cross-attention (no causal mask, encoder KV), and single-token
decode against a pre-allocated KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk(x: jnp.ndarray, size: int, axis: int) -> jnp.ndarray:
    """(..., S, ...) -> (..., S//size, size, ...) with S % size == 0."""
    s = x.shape[axis]
    if s % size != 0:
        raise ValueError(
            f"axis {axis} of {x.shape} not divisible by chunk size {size}")
    new = x.shape[:axis] + (s // size, size) + x.shape[axis + 1:]
    return x.reshape(new)


def flash_attention(
    q: jnp.ndarray,           # (B, S, H, D)
    k: jnp.ndarray,           # (B, T, G, D)
    v: jnp.ndarray,           # (B, T, G, D)
    *,
    causal: bool = True,
    window: int = 0,          # 0 = unlimited; else sliding window size
    softcap: float = 0.0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    q_offset: int = 0,        # absolute position of q[0] (prefill continuation)
) -> jnp.ndarray:
    b, s, h, d = q.shape
    _, t, g, _ = k.shape
    if h % g != 0:
        raise ValueError(f"query heads {h} not divisible by kv heads {g}")
    r = h // g
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    scale = d ** -0.5

    # pad to chunk multiples; padded kv positions get +inf-masked via k_pos >= t
    s_pad = (-s) % q_chunk
    t_pad = (-t) % kv_chunk
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    sp, tp = s + s_pad, t + t_pad

    qg = _chunk(q.reshape(b, sp, g, r, d) * scale, q_chunk, axis=1)  # (B, nq, qc, G, R, D)
    kg = _chunk(k, kv_chunk, axis=1)                                  # (B, nk, kc, G, D)
    vg = _chunk(v, kv_chunk, axis=1)
    nq, nk = qg.shape[1], kg.shape[1]
    q_pos = q_offset + jnp.arange(sp).reshape(nq, q_chunk)
    k_pos = jnp.arange(tp).reshape(nk, kv_chunk)
    kv_valid_limit = t  # mask out padded kv positions

    # scan layout: leading axis = chunk index
    qg = jnp.moveaxis(qg, 1, 0)   # (nq, B, qc, G, R, D)
    kg = jnp.moveaxis(kg, 1, 0)   # (nk, B, kc, G, D)
    vg = jnp.moveaxis(vg, 1, 0)

    # score pipeline stays in the model dtype (bf16 on the TPU-target cells):
    # the score-sized buffers (sc, p) dominate HBM traffic in the kv loop —
    # measured 1.9x memory-term reduction on grok prefill (§Perf G1).  The
    # small online-softmax carries (m, l) and the output accumulator stay f32.
    sdt = q.dtype
    neg = jnp.asarray(NEG_INF, sdt)  # representable in bf16 (8-bit exponent);
                                     # never -inf: exp(-inf - -inf) would NaN

    def q_body(_, q_in):
        qc, qp = q_in             # (B, qc, G, R, D), (qc,)

        def kv_body(carry, kv_in):
            m, l, acc = carry
            kc, vc, kp = kv_in    # (B, kc, G, D), (B, kc, G, D), (kc,)
            sc = jnp.einsum("bqgrd,bkgd->bgrqk", qc, kc,
                            preferred_element_type=sdt)               # (B,G,R,qc,kc)
            if softcap > 0.0:
                sc = (softcap * jnp.tanh(sc / softcap)).astype(sdt)
            mask = jnp.broadcast_to(kp[None, :] < kv_valid_limit, (q_chunk, kv_chunk))
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window > 0:
                mask &= kp[None, :] > qp[:, None] - window
            sc = jnp.where(mask, sc, neg)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1).astype(jnp.float32))
            p = jnp.exp(sc - m_new[..., None].astype(sdt))            # (…,qc,kc) sdt
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1, dtype=jnp.float32)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p, vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, g, r, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, r, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, g, r, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (kg, vg, k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]                   # (B,G,R,qc,D)
        return None, jnp.moveaxis(out, 3, 1)                          # (B,qc,G,R,D)

    _, out = jax.lax.scan(q_body, None, (qg, q_pos))                   # (nq,B,qc,G,R,D)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sp, h, d)
    if s_pad:
        out = out[:, :s]
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,           # (B, 1, H, D)
    k_cache: jnp.ndarray,     # (B, T, G, D)
    v_cache: jnp.ndarray,     # (B, T, G, D)
    cache_len: jnp.ndarray,   # () int32 — valid prefix length (new token included)
    *,
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Single new token attends to the cache prefix [0, cache_len)."""
    b, _, h, d = q.shape
    _, t, g, _ = k_cache.shape
    r = h // g
    qg = q.reshape(b, g, r, d) * (d ** -0.5)
    sc = jnp.einsum("bgrd,bkgd->bgrk", qg, k_cache)                    # (B,G,R,T)
    if softcap > 0.0:
        sc = softcap * jnp.tanh(sc / softcap)
    pos = jnp.arange(t)
    clen = jnp.broadcast_to(jnp.asarray(cache_len), (b,))              # scalar or (B,)
    mask = pos[None, :] < clen[:, None]                                # (B, T)
    if window > 0:
        mask &= pos[None, :] > clen[:, None] - 1 - window
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc.astype(jnp.float32), axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bgrk,bkgd->bgrd", p, v_cache)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def decode_attention_appended(
    q: jnp.ndarray,           # (B, 1, H, D)
    k_cache: jnp.ndarray,     # (B, T, G, D) — WITHOUT the new token
    v_cache: jnp.ndarray,
    k_new: jnp.ndarray,       # (B, 1, G, D)
    v_new: jnp.ndarray,
    cache_len: jnp.ndarray,   # () — tokens already in cache (new token excluded)
    *,
    valid_mask: Optional[jnp.ndarray] = None,  # (T,) or (B,T): ring-buffer masks
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Decode attention that treats the new token's KV separately, so the
    cache buffer is never copied (the caller writes the one-token slice into
    the stacked cache afterwards).  Exactly equals attention over the
    concatenated cache."""
    b, _, h, d = q.shape
    _, t, g, _ = k_cache.shape
    r = h // g
    qg = q.reshape(b, g, r, d) * (d ** -0.5)
    sc = jnp.einsum("bgrd,bkgd->bgrk", qg, k_cache)                    # (B,G,R,T)
    sc_new = jnp.einsum("bgrd,bkgd->bgrk", qg, k_new)                  # (B,G,R,1)
    if softcap > 0.0:
        sc = softcap * jnp.tanh(sc / softcap)
        sc_new = softcap * jnp.tanh(sc_new / softcap)
    if valid_mask is None:
        pos = jnp.arange(t)
        clen = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
        mask = pos[None, :] < clen[:, None]                            # (B,T)
    else:
        mask = jnp.broadcast_to(valid_mask, (b, t)) if valid_mask.ndim == 1 else valid_mask
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    both = jnp.concatenate([sc, sc_new], axis=-1)                      # (B,G,R,T+1)
    # softmax in f32 for stability, but weights cast back to the cache dtype:
    # an f32 `p` would promote (materialize-convert) the whole KV cache
    p = jax.nn.softmax(both.astype(jnp.float32), axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bgrk,bkgd->bgrd", p[..., :t], v_cache) \
        + p[..., t:].astype(jnp.float32) * v_new.reshape(b, g, 1, d).astype(jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def positional_prefill_attention(
    q: jnp.ndarray,           # (B, T, H, D) — rows at absolute positions qpos
    k_buf: jnp.ndarray,       # (B, S, G, D) — key for position j at index j
    v_buf: jnp.ndarray,
    qpos: jnp.ndarray,        # (B, T) int32 absolute positions
    *,
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Serving-prefill attention over a positionally-indexed KV buffer.

    The bitwise-reproducibility anchor of chunked prefill (DESIGN.md §14):
    every query row's computation touches ONE S-wide buffer whose contents
    and masks depend only on the row's absolute position — not on how the
    prompt was split into chunks — so monolithic prefill-into-slot and any
    chunked schedule produce bit-identical outputs per row.  Entries at
    positions a row cannot see (future, out-of-window, never-written) may
    hold arbitrary finite values: the mask sends them to ``exp -> 0.0``
    exactly.  Both :func:`repro.models.blocks.block_forward` (``lengths``
    path) and the chunk-fused step's prefill rows call THIS function with
    S equal to the slot capacity; flash attention's online softmax stays
    the train/eval path (its accumulation order differs at the ulp level,
    which per-batch quantization amplifies into token flips)."""
    b, t, h, d = q.shape
    s, g = k_buf.shape[1], k_buf.shape[2]
    r = h // g
    qg = q.reshape(b, t, g, r, d) * (d ** -0.5)
    sc = jnp.einsum("btgrd,bkgd->bgrtk", qg, k_buf)                # (B,G,R,T,S)
    if softcap > 0.0:
        sc = softcap * jnp.tanh(sc / softcap)
    pos = jnp.arange(s)
    mask = pos[None, None, :] <= qpos[:, :, None]                  # (B,T,S)
    if window > 0:
        mask &= pos[None, None, :] > qpos[:, :, None] - window
    sc = jnp.where(mask[:, None, None, :, :], sc, NEG_INF)
    p = jax.nn.softmax(sc.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bgrtk,bkgd->bgrtd", p.astype(v_buf.dtype), v_buf)
    return jnp.moveaxis(out, 3, 1).reshape(b, t, h, d).astype(q.dtype)


def chunk_decode_attention(
    q: jnp.ndarray,           # (B, T, H, D) — T new tokens per slot
    k_cache: jnp.ndarray,     # (B, S, G, D) — WITHOUT the new tokens
    v_cache: jnp.ndarray,
    k_new: jnp.ndarray,       # (B, T, G, D) — the chunk's own KV, in order
    v_new: jnp.ndarray,
    cache_len: jnp.ndarray,   # () or (B,) — tokens already in each row's cache
    *,
    window: int = 0,
    softcap: float = 0.0,
    slot_pos: Optional[jnp.ndarray] = None,  # (B, S): local ring positions
) -> jnp.ndarray:
    """Multi-token decode attention (speculative *verify*, DESIGN.md §10).

    Query ``t`` sits at absolute position ``cache_len + t`` and attends the
    cache prefix ``[0, cache_len)`` plus chunk keys ``0..t`` (causal within
    the chunk) — the T-query generalization of
    :func:`decode_attention_appended`, so the cache buffer is never copied;
    the caller commits the chunk KV afterwards (accept/rollback).  With
    ``slot_pos`` the cache is a local ring: slots are masked by recorded
    position (valid, in-window, strictly pre-chunk), and ``window`` also
    masks chunk keys more than ``window-1`` behind a query."""
    b, tq, h, d = q.shape
    _, s, g, _ = k_cache.shape
    r = h // g
    qg = q.reshape(b, tq, g, r, d) * (d ** -0.5)
    clen = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
    qpos = clen[:, None] + jnp.arange(tq)[None, :]                 # (B, T)
    sc = jnp.einsum("btgrd,bkgd->bgrtk", qg, k_cache)              # (B,G,R,T,S)
    sc_new = jnp.einsum("btgrd,bjgd->bgrtj", qg, k_new)            # (B,G,R,T,T)
    if softcap > 0.0:
        sc = softcap * jnp.tanh(sc / softcap)
        sc_new = softcap * jnp.tanh(sc_new / softcap)
    if slot_pos is not None:
        sp = slot_pos[:, None, :]                                  # (B,1,S)
        cmask = (sp >= 0) & (sp > qpos[:, :, None] - window) \
            & (sp < clen[:, None, None])
    else:
        pos = jnp.arange(s)
        cmask = pos[None, None, :] < clen[:, None, None]           # (B,1,S)
        if window > 0:
            cmask = cmask & (pos[None, None, :] > qpos[:, :, None] - window)
        cmask = jnp.broadcast_to(cmask, (b, tq, s))
    t_idx = jnp.arange(tq)
    nmask = t_idx[None, :] <= t_idx[:, None]                       # (T, T) causal
    if window > 0:
        nmask &= (t_idx[:, None] - t_idx[None, :]) < window
    sc = jnp.where(cmask[:, None, None, :, :], sc, NEG_INF)
    sc_new = jnp.where(nmask[None, None, None, :, :], sc_new, NEG_INF)
    both = jnp.concatenate([sc, sc_new], axis=-1)                  # (B,G,R,T,S+T)
    p = jax.nn.softmax(both.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bgrtk,bkgd->bgrtd",
                     p[..., :s].astype(v_cache.dtype), v_cache) \
        + jnp.einsum("bgrtj,bjgd->bgrtd",
                     p[..., s:], v_new.astype(jnp.float32))
    return jnp.moveaxis(out, 3, 1).reshape(b, tq, h, d).astype(q.dtype)


def chunk_decode_attention_int8(
    q: jnp.ndarray,           # (B, T, H, D) fp
    k_q: jnp.ndarray,         # (B, S, G, D) int8
    k_s: jnp.ndarray,         # (B, S, G) f32
    v_q: jnp.ndarray,
    v_s: jnp.ndarray,
    k_new: jnp.ndarray,       # (B, T, G, D) fp — chunk keys (not yet written)
    v_new: jnp.ndarray,
    cache_len: jnp.ndarray,
    *,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """INT8-KV twin of :func:`chunk_decode_attention` (full attention only —
    local rings store fp KV): int8 dots against the cache exactly as
    :func:`decode_attention_int8`, fp dots against the chunk's own KV."""
    b, tq, h, d = q.shape
    _, s, g, _ = k_q.shape
    r = h // g
    qg = q.reshape(b, tq, g, r, d).astype(jnp.float32) * (d ** -0.5)
    q_i8, q_s = _quantize_rows(qg)                                 # (B,T,G,R,*)
    sc_i = jnp.einsum("btgrd,bkgd->bgrtk", q_i8, k_q,
                      preferred_element_type=jnp.int32)            # int8 MXU
    ks_t = jnp.moveaxis(k_s, 1, 2)                                 # (B,G,S)
    qs_t = jnp.moveaxis(q_s, 1, 3)                                 # (B,G,R,T)
    sc = sc_i.astype(jnp.float32) * qs_t[..., None] * ks_t[:, :, None, None, :]
    sc_new = jnp.einsum("btgrd,bjgd->bgrtj", qg, k_new.astype(jnp.float32))
    if softcap > 0.0:
        sc = softcap * jnp.tanh(sc / softcap)
        sc_new = softcap * jnp.tanh(sc_new / softcap)
    clen = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
    pos = jnp.arange(s)
    cmask = jnp.broadcast_to(pos[None, None, :] < clen[:, None, None],
                             (b, tq, s))
    t_idx = jnp.arange(tq)
    nmask = t_idx[None, :] <= t_idx[:, None]
    sc = jnp.where(cmask[:, None, None, :, :], sc, NEG_INF)
    sc_new = jnp.where(nmask[None, None, None, :, :], sc_new, NEG_INF)
    p = jax.nn.softmax(jnp.concatenate([sc, sc_new], axis=-1), axis=-1)
    vs_t = jnp.moveaxis(v_s, 1, 2)                                 # (B,G,S)
    p_fold = p[..., :s] * vs_t[:, :, None, None, :]
    p_i8, p_s = _quantize_rows(p_fold)
    out = jnp.einsum("bgrtk,bkgd->bgrtd", p_i8, v_q,
                     preferred_element_type=jnp.int32
                     ).astype(jnp.float32) * p_s[..., None] \
        + jnp.einsum("bgrtj,bjgd->bgrtd", p[..., s:],
                     v_new.astype(jnp.float32))
    return jnp.moveaxis(out, 3, 1).reshape(b, tq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# INT8 KV cache (beyond-paper: the series quantizer applied to attention).
# K/V are stored as int8 planes with per-(position, kv-head) scales; scores
# use int8 x int8 -> int32 MXU dots.  K scales factor out of the QK^T dot
# per column; V's per-position scales are folded into the softmax weights
# BEFORE the PV dot (exact), so both GEMMs run fully in int8.
# ---------------------------------------------------------------------------
def quantize_kv(x: jnp.ndarray):
    """x: (B, T, G, D) -> (int8 planes, f32 scales (B, T, G))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _quantize_rows(x: jnp.ndarray):
    """per-row symmetric int8: x (..., D) -> (int8, f32 scale (...))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def decode_attention_int8(
    q: jnp.ndarray,           # (B, 1, H, D) fp
    k_q: jnp.ndarray,         # (B, T, G, D) int8
    k_s: jnp.ndarray,         # (B, T, G) f32
    v_q: jnp.ndarray,         # (B, T, G, D) int8
    v_s: jnp.ndarray,         # (B, T, G) f32
    k_new: jnp.ndarray,       # (B, 1, G, D) fp — new token (not yet written)
    v_new: jnp.ndarray,
    cache_len: jnp.ndarray,
    *,
    valid_mask: Optional[jnp.ndarray] = None,
    softcap: float = 0.0,
) -> jnp.ndarray:
    b, _, h, d = q.shape
    _, t, g, _ = k_q.shape
    r = h // g
    qg = q.reshape(b, g, r, d).astype(jnp.float32) * (d ** -0.5)
    q_i8, q_s = _quantize_rows(qg)                                     # (B,G,R,*)
    sc_i = jnp.einsum("bgrd,bkgd->bgrk", q_i8, k_q,
                      preferred_element_type=jnp.int32)                # int8 MXU
    ks_t = jnp.moveaxis(k_s, 1, 2)                                     # (B,G,T)
    sc = sc_i.astype(jnp.float32) * q_s[..., None] * ks_t[:, :, None, :]
    sc_new = jnp.einsum("bgrd,bkgd->bgrk", qg, k_new.astype(jnp.float32))
    if softcap > 0.0:
        sc = softcap * jnp.tanh(sc / softcap)
        sc_new = softcap * jnp.tanh(sc_new / softcap)
    if valid_mask is None:
        pos = jnp.arange(t)
        clen = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
        mask = pos[None, :] < clen[:, None]
    else:
        mask = jnp.broadcast_to(valid_mask, (b, t)) if valid_mask.ndim == 1 else valid_mask
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    both = jnp.concatenate([sc, sc_new], axis=-1)
    p = jax.nn.softmax(both, axis=-1)                                  # (B,G,R,T+1) f32
    # fold V's per-position scales into the weights, then int8 the weights
    vs_t = jnp.moveaxis(v_s, 1, 2)                                     # (B,G,T)
    p_fold = p[..., :t] * vs_t[:, :, None, :]
    p_i8, p_s = _quantize_rows(p_fold)
    out_i = jnp.einsum("bgrk,bkgd->bgrd", p_i8, v_q,
                       preferred_element_type=jnp.int32)               # int8 MXU
    out = out_i.astype(jnp.float32) * p_s[..., None] \
        + p[..., t:] * v_new.reshape(b, g, 1, d).astype(jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged KV cache (DESIGN.md §13).  KV lives in global per-layer page pools
# (P+1, page, G, D) — the last row is the sentinel page — addressed through
# per-slot block tables (B, MP).  The reference path gathers a slot's pages
# to a dense (B, MP*page, ...) view and reuses the dense decode/chunk
# attention above: it is the token-identity oracle.  The kernel path streams
# pages through the Pallas partial kernels and merges the chunk's own causal
# KV by the exact two-way online-softmax merge — no dense (B, S) gather.
# ---------------------------------------------------------------------------
def gather_pages(pool: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """(P, page, ...) pool + (B, MP) tables -> dense (B, MP*page, ...)."""
    b, mp = block_tables.shape
    g = jnp.take(pool, block_tables, axis=0)           # (B, MP, page, ...)
    return g.reshape((b, mp * pool.shape[1]) + pool.shape[2:])


def merge_partial_softmax(acc, m, l, sc_new, v_new):
    """Exact two-way online-softmax merge of a kernel partial (acc, m, l)
    with already-masked chunk scores sc_new (B, T, G, R, J) over chunk
    values v_new (B, J, G, D) f32.  Returns normalized (B, T, G, R, D)."""
    m_c = jnp.max(sc_new, axis=-1)
    p_c = jnp.exp(sc_new - m_c[..., None])
    l_c = jnp.sum(p_c, axis=-1)
    acc_c = jnp.einsum("btgrj,bjgd->btgrd", p_c, v_new)
    m_t = jnp.maximum(m, m_c)
    a1 = jnp.exp(m - m_t)
    a2 = jnp.exp(m_c - m_t)
    denom = jnp.maximum(l * a1 + l_c * a2, 1e-30)
    return (acc * a1[..., None] + acc_c * a2[..., None]) / denom[..., None]


def _chunk_scores(qg, k_new, softcap, causal_chunk):
    sc_new = jnp.einsum("btgrd,bjgd->btgrj", qg, k_new.astype(jnp.float32))
    if softcap > 0.0:
        sc_new = softcap * jnp.tanh(sc_new / softcap)
    if causal_chunk:
        t = qg.shape[1]
        t_idx = jnp.arange(t)
        nmask = t_idx[None, :] <= t_idx[:, None]                   # (T, J)
        sc_new = jnp.where(nmask[None, :, None, None, :], sc_new, NEG_INF)
    return sc_new


def _paged_flash(q, k_pool, v_pool, block_tables, cache_len, k_new, v_new,
                 softcap, causal_chunk):
    from repro.kernels import ops as _ops
    b, t, h, d = q.shape
    g = k_pool.shape[2]
    r = h // g
    qg = q.reshape(b, t, g, r, d).astype(jnp.float32) * (d ** -0.5)
    acc, m, l = _ops.paged_flash_partial(qg, k_pool, v_pool, block_tables,
                                         cache_len, softcap=softcap)
    out = merge_partial_softmax(acc, m, l,
                                _chunk_scores(qg, k_new, softcap, causal_chunk),
                                v_new.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)


def _paged_flash_int8(q, kq_pool, ks_pool, vq_pool, vs_pool, block_tables,
                      cache_len, k_new, v_new, softcap, causal_chunk):
    from repro.kernels import ops as _ops
    b, t, h, d = q.shape
    g = kq_pool.shape[2]
    r = h // g
    qg = q.reshape(b, t, g, r, d).astype(jnp.float32) * (d ** -0.5)
    q_i8, q_s = _quantize_rows(qg)
    acc, m, l = _ops.paged_flash_partial_int8(q_i8, q_s, kq_pool, ks_pool,
                                              vq_pool, vs_pool, block_tables,
                                              cache_len, softcap=softcap)
    out = merge_partial_softmax(acc, m, l,
                                _chunk_scores(qg, k_new, softcap, causal_chunk),
                                v_new.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)


def paged_decode_attention(q, k_pool, v_pool, block_tables, cache_len,
                           k_new, v_new, *, softcap: float = 0.0,
                           use_kernel: bool = False) -> jnp.ndarray:
    """Paged twin of :func:`decode_attention_appended`: q/k_new/v_new are the
    single new token (B, 1, ...), the cache lives in pools + block tables."""
    if use_kernel:
        return _paged_flash(q, k_pool, v_pool, block_tables, cache_len,
                            k_new, v_new, softcap, causal_chunk=False)
    kd = gather_pages(k_pool, block_tables)
    vd = gather_pages(v_pool, block_tables)
    return decode_attention_appended(q, kd, vd, k_new, v_new, cache_len,
                                     softcap=softcap)


def paged_chunk_decode_attention(q, k_pool, v_pool, block_tables, cache_len,
                                 k_new, v_new, *, softcap: float = 0.0,
                                 use_kernel: bool = False) -> jnp.ndarray:
    """Paged twin of :func:`chunk_decode_attention` (full attention only —
    local rings are never paged): T chunk queries, causal within the chunk."""
    if use_kernel:
        return _paged_flash(q, k_pool, v_pool, block_tables, cache_len,
                            k_new, v_new, softcap, causal_chunk=True)
    kd = gather_pages(k_pool, block_tables)
    vd = gather_pages(v_pool, block_tables)
    return chunk_decode_attention(q, kd, vd, k_new, v_new, cache_len,
                                  softcap=softcap)


def paged_decode_attention_int8(q, kq_pool, ks_pool, vq_pool, vs_pool,
                                block_tables, cache_len, k_new, v_new, *,
                                softcap: float = 0.0,
                                use_kernel: bool = False) -> jnp.ndarray:
    if use_kernel:
        return _paged_flash_int8(q, kq_pool, ks_pool, vq_pool, vs_pool,
                                 block_tables, cache_len, k_new, v_new,
                                 softcap, causal_chunk=False)
    return decode_attention_int8(
        q, gather_pages(kq_pool, block_tables),
        gather_pages(ks_pool, block_tables),
        gather_pages(vq_pool, block_tables),
        gather_pages(vs_pool, block_tables),
        k_new, v_new, cache_len, softcap=softcap)


def paged_chunk_decode_attention_int8(q, kq_pool, ks_pool, vq_pool, vs_pool,
                                      block_tables, cache_len, k_new, v_new,
                                      *, softcap: float = 0.0,
                                      use_kernel: bool = False) -> jnp.ndarray:
    if use_kernel:
        return _paged_flash_int8(q, kq_pool, ks_pool, vq_pool, vs_pool,
                                 block_tables, cache_len, k_new, v_new,
                                 softcap, causal_chunk=True)
    return chunk_decode_attention_int8(
        q, gather_pages(kq_pool, block_tables),
        gather_pages(ks_pool, block_tables),
        gather_pages(vq_pool, block_tables),
        gather_pages(vs_pool, block_tables),
        k_new, v_new, cache_len, softcap=softcap)


def cross_attention(
    q: jnp.ndarray,           # (B, S, H, D)
    k: jnp.ndarray,           # (B, T_img, G, D)
    v: jnp.ndarray,
    *,
    softcap: float = 0.0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Full (non-causal) attention over encoder outputs — VLM cross layers."""
    return flash_attention(q, k, v, causal=False, softcap=softcap,
                           q_chunk=q_chunk, kv_chunk=kv_chunk)
