"""Transformer-family blocks: attn / local / cross / moe_attn / rglru / ssm.

Each block kind provides (init, forward, decode_step) with a uniform
signature so ``model.py`` can scan heterogeneous stage patterns.  Forward
returns ``(x, cache)`` where cache feeds the decode path:

  attn/moe_attn : {"k","v"} full KV           (B, S_max, G, Dh)
  local         : {"k","v","slot_pos"} ring   (B, W, G, Dh) sliding window,
                                              slot_pos (B, W) per slot
  cross         : {"k","v"} static image KV   (B, T_img, G, Dh)
  rglru         : {"conv","h"}                O(1) recurrent state
  ssm           : {"conv","ssm"}              O(1) SSD state

Decode accepts ``cache_len`` as a scalar (lock-step batch) or a (B,) vector
(continuous batching: every slot at its own sequence position), and forward
accepts per-row ``lengths`` for right-padded prompts (prefill-into-slot).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as ATT
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models.layers import QuantContext


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _attn_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    h, g = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "q": L.dense_init(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "k": L.dense_init(ks[1], d, g * hd, bias=cfg.qkv_bias, dtype=dtype),
        "v": L.dense_init(ks[2], d, g * hd, bias=cfg.qkv_bias, dtype=dtype),
        "o": L.dense_init(ks[3], h * hd, d, dtype=dtype),
    }


def block_init(key, kind: str, cfg, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("attn", "local", "cross", "moe_attn"):
        p = {"ln": L.norm_init(d, dtype), "attn": _attn_init(k1, cfg, dtype),
             "mlp_ln": L.norm_init(d, dtype)}
        if kind == "moe_attn":
            p["moe"] = MOE.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = L.mlp_init(k2, d, cfg.d_ff, gated=cfg.mlp_gated, dtype=dtype)
        if kind == "cross":
            p["xattn_gate"] = jnp.zeros((), dtype)  # gated cross-attn (llama3.2-v)
        return p
    if kind == "rglru":
        return {"ln": L.norm_init(d, dtype), "rec": RG.rglru_init(k1, cfg, dtype),
                "mlp_ln": L.norm_init(d, dtype),
                "mlp": L.mlp_init(k2, d, cfg.d_ff, gated=cfg.mlp_gated, dtype=dtype)}
    if kind == "ssm":
        return {"ln": L.norm_init(d, dtype), "mixer": SSM.ssm_init(k1, cfg, dtype)}
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _qkv(qc, p, x, cfg, positions: Optional[jnp.ndarray], *, rope: bool):
    b, s, _ = x.shape
    hd, h, g = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = L.dense(qc, x, p["q"]).reshape(b, s, h, hd)
    k = L.dense(qc, x, p["k"]).reshape(b, s, g, hd)
    v = L.dense(qc, x, p["v"]).reshape(b, s, g, hd)
    if rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp_part(qc, kind, p, x, cfg):
    h = L.apply_norm(cfg.norm, p["mlp_ln"], x)
    if kind == "moe_attn":
        return x + MOE.moe_apply(qc, p["moe"], h, cfg)
    return x + L.mlp_apply(qc, p["mlp"], h, cfg.mlp_act)


# ---------------------------------------------------------------------------
# forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------
def block_forward(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray, cfg, *,
                  positions: jnp.ndarray, side: Optional[Dict] = None,
                  s_max: int = 0, lengths: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Dict]:
    """``lengths`` (B,) marks right-padded prompt rows (padded prefill-into-
    slot): causal attention keeps valid positions exact under right padding,
    so only the *caches* need per-row handling — the local ring is gathered
    from each row's true window and recurrent state is carried through pad."""
    b = x.shape[0]
    if kind in ("attn", "local", "moe_attn"):
        h = L.apply_norm(cfg.norm, p["ln"], x)
        causal = not cfg.is_encoder
        window = cfg.window if kind == "local" else 0
        q, k, v = _qkv(qc, p["attn"], h, cfg, positions, rope=not cfg.is_encoder)
        if lengths is None or not causal:
            att = ATT.flash_attention(q, k, v, causal=causal, window=window,
                                      softcap=cfg.attn_softcap,
                                      q_chunk=cfg.attn_q_chunk or 1024,
                                      kv_chunk=cfg.attn_kv_chunk or 1024)
        else:
            # serving prefill-into-slot: the positional formulation over a
            # buffer padded to the slot capacity, so a chunked prefill can
            # reproduce every row bit-for-bit (DESIGN.md §14).  Causal
            # masking makes the pad keys (>= each row's length) invisible
            # to valid rows, exactly as under flash.
            s_buf = max(s_max, k.shape[1])
            pad = s_buf - k.shape[1]
            kb = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
            vb = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
            qpos = jnp.broadcast_to(positions[None, :].astype(jnp.int32),
                                    (b, k.shape[1]))
            att = ATT.positional_prefill_attention(q, kb, vb, qpos,
                                                   window=window,
                                                   softcap=cfg.attn_softcap)
        x = x + L.dense(qc, att.reshape(b, att.shape[1], -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        if kind == "local":
            w = min(cfg.window, k.shape[1])
            if lengths is None:
                pos_tail = positions[-w:] if positions.ndim == 1 else positions[0, -w:]
                cache = {"k": k[:, -w:], "v": v[:, -w:],
                         "slot_pos": jnp.broadcast_to(
                             pos_tail.astype(jnp.int32), (b, w))}
            else:
                # per-row decode-invariant ring: slot j holds the largest
                # position p < length with p % w == j (or -1 when none)
                j = jnp.arange(w)[None, :]
                last = lengths[:, None] - 1                           # (B,1)
                ring_pos = last - jnp.mod(last - j, w)                # (B,w)
                ok = ring_pos >= 0
                idx = jnp.clip(ring_pos, 0, k.shape[1] - 1)
                gk = jnp.take_along_axis(k, idx[:, :, None, None], axis=1)
                gv = jnp.take_along_axis(v, idx[:, :, None, None], axis=1)
                cache = {"k": jnp.where(ok[:, :, None, None], gk, 0).astype(k.dtype),
                         "v": jnp.where(ok[:, :, None, None], gv, 0).astype(v.dtype),
                         "slot_pos": jnp.where(ok, ring_pos, -1).astype(jnp.int32)}
        elif qc.int8_kv:
            kq, ks = ATT.quantize_kv(k)
            vq, vs = ATT.quantize_kv(v)
            cache = {"k": kq, "ks": ks, "v": vq, "vs": vs}
        else:
            cache = {"k": k, "v": v}
        return x, cache
    if kind == "cross":
        if side is None or "image_emb" not in side:
            raise ValueError("cross block needs an 'image_emb' side input")
        h = L.apply_norm(cfg.norm, p["ln"], x)
        hd, hq, g = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
        q = L.dense(qc, h, p["attn"]["q"]).reshape(b, h.shape[1], hq, hd)
        img = side["image_emb"]                               # (B, T_img, D)
        t_img = img.shape[1]
        k_img = L.dense(qc, img, p["attn"]["k"]).reshape(b, t_img, g, hd)
        v_img = L.dense(qc, img, p["attn"]["v"]).reshape(b, t_img, g, hd)
        att = ATT.cross_attention(q, k_img, v_img)
        gate = jnp.tanh(p["xattn_gate"])
        x = x + gate * L.dense(qc, att.reshape(b, att.shape[1], -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, {"k": k_img, "v": v_img}
    if kind == "rglru":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        y, cache = RG.rglru_apply(qc, p["rec"], h, cfg, lengths=lengths)
        x = x + y
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, cache
    if kind == "ssm":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        y, cache = SSM.ssm_apply(qc, p["mixer"], h, cfg, lengths=lengths)
        return x + y, cache
    raise ValueError(kind)


def make_image_kv(qc: QuantContext, p: Dict, image_emb: jnp.ndarray, cfg):
    """Compute the static cross-attention KV from projected image embeddings
    using the *first cross block's* K/V projections (shared convention)."""
    b, t, _ = image_emb.shape
    g, hd = cfg.num_kv_heads, cfg.head_dim
    k = L.dense(qc, image_emb, p["k"]).reshape(b, t, g, hd)
    v = L.dense(qc, image_emb, p["v"]).reshape(b, t, g, hd)
    return k, v


# ---------------------------------------------------------------------------
# decode (single token against cache)
# ---------------------------------------------------------------------------
def block_decode(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray, cache: Dict,
                 cfg, *, cache_len: jnp.ndarray, moe_stats: bool = False
                 ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, 1, D); cache_len: () or (B,) — tokens already in each row's
    cache (the new token lands at position cache_len[b]).  A scalar serves
    the lock-step legacy path; a vector serves slots at different sequence
    positions in one step (continuous batching).

    ``moe_stats=True`` (static) returns ``(x, cache', stats)`` where stats
    is the MoE routing telemetry of this block (:func:`moe.zero_stats`
    structure; the zero element for non-MoE kinds) — the channel
    ``decode_step`` sums into the scheduler's expert-imbalance signal."""
    if moe_stats and kind != "moe_attn":
        x, cache = block_decode(qc, kind, p, x, cache, cfg, cache_len=cache_len)
        return x, cache, MOE.zero_stats(cfg)
    b = x.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    pos = clen[:, None]                                        # per-slot rope
    rows = jnp.arange(b)
    if kind in ("attn", "moe_attn"):
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, pos, rope=True)
        if qc.int8_kv:
            att = ATT.decode_attention_int8(
                q, cache["k"], cache["ks"], cache["v"], cache["vs"], k, v,
                clen, softcap=cfg.attn_softcap)
            kq, ks = ATT.quantize_kv(k)
            vq, vs = ATT.quantize_kv(v)
            new_cache = {
                "k": cache["k"].at[rows, clen].set(kq[:, 0]),
                "ks": cache["ks"].at[rows, clen].set(ks[:, 0]),
                "v": cache["v"].at[rows, clen].set(vq[:, 0]),
                "vs": cache["vs"].at[rows, clen].set(vs[:, 0]),
            }
        else:
            kc = cache["k"].at[rows, clen].set(k[:, 0].astype(cache["k"].dtype))
            vc = cache["v"].at[rows, clen].set(v[:, 0].astype(cache["v"].dtype))
            att = ATT.decode_attention(q, kc, vc, clen + 1,
                                       softcap=cfg.attn_softcap)
            new_cache = {"k": kc, "v": vc}
        x = x + L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
        if moe_stats:                                  # kind == "moe_attn"
            h2 = L.apply_norm(cfg.norm, p["mlp_ln"], x)
            y, stats = MOE.moe_apply(qc, p["moe"], h2, cfg, return_stats=True)
            return x + y, new_cache, stats
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, new_cache
    if kind == "local":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, pos, rope=True)
        w = cache["k"].shape[1]
        slot = jnp.mod(clen, w)                                # (B,)
        kc = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
        vc = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
        slot_pos = cache["slot_pos"].at[rows, slot].set(
            clen.astype(cache["slot_pos"].dtype))              # (B, w)
        # ring attention: mask slots outside (cache_len - window, cache_len]
        valid = (slot_pos >= 0) & (slot_pos > pos - cfg.window) & (slot_pos <= pos)
        sc_q = q.reshape(b, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, -1)
        sc = jnp.einsum("bgrd,bkgd->bgrk", sc_q * (cfg.head_dim ** -0.5), kc)
        sc = jnp.where(valid[:, None, None, :], sc, ATT.NEG_INF)
        att = jnp.einsum("bgrk,bkgd->bgrd", jax.nn.softmax(sc, axis=-1), vc)
        x = x + L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, {"k": kc, "v": vc, "slot_pos": slot_pos}
    if kind == "cross":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        hd, hq = cfg.head_dim, cfg.num_heads
        q = L.dense(qc, h, p["attn"]["q"]).reshape(b, 1, hq, hd)
        att = ATT.decode_attention(q, cache["k"], cache["v"],
                                   jnp.int32(cache["k"].shape[1]))
        gate = jnp.tanh(p["xattn_gate"])
        x = x + gate * L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, cache
    if kind == "rglru":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        y, cache = RG.rglru_decode_step(qc, p["rec"], h, cache, cfg)
        x = x + y
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, cache
    if kind == "ssm":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        y, cache = SSM.ssm_decode_step(qc, p["mixer"], h, cache, cfg)
        return x + y, cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# delta decode: read the (old) layer cache, return one-token deltas so the
# caller can update the stacked cache in place (no full-buffer copies).
# Exactly equal to block_decode (tests assert bitwise-level closeness).
# ---------------------------------------------------------------------------
def block_decode_delta(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray,
                       cache: Dict, cfg, *, cache_len: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, Dict]:
    """Returns (x, delta).  delta keys mirror the cache; values are either
    one-token slices (attn k/v, local k/v), per-row slot positions
    (local slot_pos: (B,)), full small states (rglru/ssm), or None (cross:
    static).  ``cache_len`` may be () or (B,) — per-slot decode."""
    b = x.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    pos = clen[:, None]
    if kind in ("attn", "moe_attn"):
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, pos, rope=True)
        if qc.int8_kv:
            att = ATT.decode_attention_int8(
                q, cache["k"], cache["ks"], cache["v"], cache["vs"], k, v,
                clen, softcap=cfg.attn_softcap)
            kq, ks = ATT.quantize_kv(k)
            vq, vs = ATT.quantize_kv(v)
            delta = {"k": kq, "ks": ks, "v": vq, "vs": vs}
        else:
            att = ATT.decode_attention_appended(q, cache["k"], cache["v"], k, v,
                                                clen, softcap=cfg.attn_softcap)
            delta = {"k": k, "v": v}
        x = x + L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, delta
    if kind == "local":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, pos, rope=True)
        sp = cache["slot_pos"]                                  # (B, w)
        # mask out the slot we are about to overwrite plus out-of-window slots
        valid = (sp >= 0) & (sp > pos - cfg.window) & (sp < pos)
        att = ATT.decode_attention_appended(q, cache["k"], cache["v"], k, v,
                                            clen, valid_mask=valid,
                                            softcap=cfg.attn_softcap)
        x = x + L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, {"k": k, "v": v, "slot_pos": clen.astype(sp.dtype)}
    if kind == "cross":
        x, _ = block_decode(qc, kind, p, x, cache, cfg, cache_len=cache_len)
        return x, {"k": None, "v": None}
    # recurrent kinds: the full (small) state is the delta
    return block_decode(qc, kind, p, x, cache, cfg, cache_len=cache_len)


# ---------------------------------------------------------------------------
# chunked verify: score T speculative tokens at once against the cache,
# WITHOUT mutating it — the caller decides the accepted prefix from the
# logits and commits via model.commit_verify (DESIGN.md §10).
# ---------------------------------------------------------------------------
def block_verify_delta(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray,
                       cache: Dict, cfg, *, cache_len: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, T, D) — T draft-chunk tokens per slot, token ``t`` at absolute
    position ``cache_len[b] + t``.  Returns (x, delta) where delta mirrors
    the cache keys with chunk values:

      attn/moe_attn : k/v (B, T, G, Dh) (+ ks/vs (B, T, G) under int8_kv)
      local         : k/v (B, T, G, Dh) (ring slots/positions derive at commit)
      cross         : None values (static image KV)
      rglru/ssm     : per-step states, leading (B, T, ...) — entry t is the
                      state after chunk tokens 0..t

    Nothing is written into ``cache``; attention reads the cache prefix
    ``[0, cache_len)`` plus the chunk's own causal KV
    (:func:`repro.models.attention.chunk_decode_attention`)."""
    b, t = x.shape[0], x.shape[1]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    positions = clen[:, None] + jnp.arange(t)[None, :]         # (B, T)
    if kind in ("attn", "moe_attn"):
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, positions, rope=True)
        if qc.int8_kv:
            att = ATT.chunk_decode_attention_int8(
                q, cache["k"], cache["ks"], cache["v"], cache["vs"], k, v,
                clen, softcap=cfg.attn_softcap)
            kq, ks = ATT.quantize_kv(k)
            vq, vs = ATT.quantize_kv(v)
            delta = {"k": kq, "ks": ks, "v": vq, "vs": vs}
        else:
            att = ATT.chunk_decode_attention(q, cache["k"], cache["v"], k, v,
                                             clen, softcap=cfg.attn_softcap)
            delta = {"k": k, "v": v}
        x = x + L.dense(qc, att.reshape(b, t, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, delta
    if kind == "local":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, positions, rope=True)
        att = ATT.chunk_decode_attention(q, cache["k"], cache["v"], k, v,
                                         clen, window=cfg.window,
                                         slot_pos=cache["slot_pos"],
                                         softcap=cfg.attn_softcap)
        x = x + L.dense(qc, att.reshape(b, t, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, {"k": k, "v": v}
    if kind == "cross":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        hd, hq = cfg.head_dim, cfg.num_heads
        q = L.dense(qc, h, p["attn"]["q"]).reshape(b, t, hq, hd)
        att = ATT.cross_attention(q, cache["k"], cache["v"])
        gate = jnp.tanh(p["xattn_gate"])
        x = x + gate * L.dense(qc, att.reshape(b, t, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, {"k": None, "v": None}
    if kind == "rglru":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        y, states = RG.rglru_verify(qc, p["rec"], h, cache, cfg)
        x = x + y
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, states
    if kind == "ssm":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        y, states = SSM.ssm_verify(qc, p["mixer"], h, cache, cfg)
        return x + y, states
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# paged decode/verify (DESIGN.md §13): full-attention KV lives in page pools
# (P+1, page, G, Dh) addressed through per-slot block tables (B, MP); the
# last pool row is the sentinel page.  Only attn/moe_attn page — local rings
# and recurrent states are already O(window)/O(1) per slot and keep their
# dense layout (other kinds route to the dense functions above).
# ---------------------------------------------------------------------------
def _use_paged_kernel(qc: QuantContext) -> bool:
    from repro.kernels import ops as _ops
    return bool(qc.use_kernel) and _ops.kernels_enabled()


def paged_write_token(pool_cache: Dict, writes: Dict, block_tables: jnp.ndarray,
                      clen: jnp.ndarray, page_size: int) -> Dict:
    """Scatter one token per slot into the pools at logical position
    ``clen[b]``: physical page ``block_tables[b, clen // page]``, offset
    ``clen % page``.  Positions past the table (or on unallocated table
    slots) land on the sentinel page — harmless garbage, never read
    unmasked."""
    mp = block_tables.shape[1]
    sentinel = next(iter(pool_cache.values())).shape[0] - 1
    pidx = clen // page_size                                     # (B,)
    pid = jnp.take_along_axis(
        block_tables, jnp.clip(pidx, 0, mp - 1)[:, None], axis=1)[:, 0]
    pid = jnp.where(pidx < mp, pid, sentinel)
    off = jnp.mod(clen, page_size)
    return {key: pool_cache[key].at[pid, off].set(val.astype(pool_cache[key].dtype))
            for key, val in writes.items()}


def block_decode_paged(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray,
                       cache: Dict, cfg, *, cache_len: jnp.ndarray,
                       block_tables: jnp.ndarray, page_size: int
                       ) -> Tuple[jnp.ndarray, Dict]:
    """Paged twin of :func:`block_decode` for full-attention kinds; all other
    kinds keep their dense cache and route through :func:`block_decode`."""
    if kind not in ("attn", "moe_attn"):
        return block_decode(qc, kind, p, x, cache, cfg, cache_len=cache_len)
    b = x.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    h = L.apply_norm(cfg.norm, p["ln"], x)
    q, k, v = _qkv(qc, p["attn"], h, cfg, clen[:, None], rope=True)
    use_k = _use_paged_kernel(qc)
    if qc.int8_kv:
        att = ATT.paged_decode_attention_int8(
            q, cache["k"], cache["ks"], cache["v"], cache["vs"],
            block_tables, clen, k, v, softcap=cfg.attn_softcap,
            use_kernel=use_k)
        kq, ks = ATT.quantize_kv(k)
        vq, vs = ATT.quantize_kv(v)
        writes = {"k": kq[:, 0], "ks": ks[:, 0], "v": vq[:, 0], "vs": vs[:, 0]}
    else:
        att = ATT.paged_decode_attention(
            q, cache["k"], cache["v"], block_tables, clen, k, v,
            softcap=cfg.attn_softcap, use_kernel=use_k)
        writes = {"k": k[:, 0], "v": v[:, 0]}
    new_cache = paged_write_token(cache, writes, block_tables, clen, page_size)
    x = x + L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
    x = _mlp_part(qc, kind, p, x, cfg)
    return x, new_cache


def block_verify_paged(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray,
                       cache: Dict, cfg, *, cache_len: jnp.ndarray,
                       block_tables: jnp.ndarray, page_size: int
                       ) -> Tuple[jnp.ndarray, Dict]:
    """Paged twin of :func:`block_verify_delta`: scores T chunk tokens
    against the paged cache WITHOUT mutating it; the caller commits the
    accepted prefix through the block tables (model.commit_verify_paged)."""
    if kind not in ("attn", "moe_attn"):
        return block_verify_delta(qc, kind, p, x, cache, cfg,
                                  cache_len=cache_len)
    b, t = x.shape[0], x.shape[1]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    positions = clen[:, None] + jnp.arange(t)[None, :]
    h = L.apply_norm(cfg.norm, p["ln"], x)
    q, k, v = _qkv(qc, p["attn"], h, cfg, positions, rope=True)
    use_k = _use_paged_kernel(qc)
    if qc.int8_kv:
        att = ATT.paged_chunk_decode_attention_int8(
            q, cache["k"], cache["ks"], cache["v"], cache["vs"],
            block_tables, clen, k, v, softcap=cfg.attn_softcap,
            use_kernel=use_k)
        kq, ks = ATT.quantize_kv(k)
        vq, vs = ATT.quantize_kv(v)
        delta = {"k": kq, "ks": ks, "v": vq, "vs": vs}
    else:
        att = ATT.paged_chunk_decode_attention(
            q, cache["k"], cache["v"], block_tables, clen, k, v,
            softcap=cfg.attn_softcap, use_kernel=use_k)
        delta = {"k": k, "v": v}
    x = x + L.dense(qc, att.reshape(b, t, -1), p["attn"]["o"])
    x = _mlp_part(qc, kind, p, x, cfg)
    return x, delta


# ---------------------------------------------------------------------------
# chunked prefill (DESIGN.md §14): score one prefill chunk per slot against
# the cache WITHOUT mutating it, with per-row formulation selection —
#   decode_rows[b]  : live decode rows spliced into chunk column 0 use the
#                     split cache/new form (chunk_decode_attention), bitwise-
#                     matched to the slots decode engine;
#   prefill rows    : use the positional single-buffer form — chunk keys are
#                     scattered into a copy of the slot-capacity cache buffer
#                     at their absolute positions, then attended exactly as
#                     block_forward's lengths path.  Same function, same
#                     buffer width, same buffer contents ⇒ chunked prefill is
#                     bit-identical to monolithic prefill by construction
#                     (masked positions contribute exactly 0.0).
# Recurrent kinds route to block_verify_delta: their sequential per-step
# unroll composes exactly across chunk boundaries (left fold).
# ---------------------------------------------------------------------------
def block_chunk_delta(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray,
                      cache: Dict, cfg, *, cache_len: jnp.ndarray,
                      decode_rows: jnp.ndarray, s_max: int
                      ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, T, D); decode_rows: (B,) bool; s_max: slot capacity (the dense
    cache width).  Returns (x, delta) with the same delta layout as
    :func:`block_verify_delta`."""
    b, t = x.shape[0], x.shape[1]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    positions = clen[:, None] + jnp.arange(t)[None, :]         # (B, T)
    rows = jnp.arange(b)
    dmask = decode_rows[:, None, None, None]
    if kind in ("attn", "moe_attn"):
        if qc.int8_kv:
            raise ValueError("chunked prefill requires exact (fp) KV caches; "
                             "int8_kv is rejected at Engine validation")
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, positions, rope=True)
        att_dec = ATT.chunk_decode_attention(q, cache["k"], cache["v"], k, v,
                                             clen, softcap=cfg.attn_softcap)
        kb = cache["k"].at[rows[:, None], positions].set(
            k.astype(cache["k"].dtype))
        vb = cache["v"].at[rows[:, None], positions].set(
            v.astype(cache["v"].dtype))
        att_pos = ATT.positional_prefill_attention(
            q, kb, vb, positions, softcap=cfg.attn_softcap)
        att = jnp.where(dmask, att_dec, att_pos)
        x = x + L.dense(qc, att.reshape(b, t, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, {"k": k, "v": v}
    if kind == "local":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, positions, rope=True)
        att_dec = ATT.chunk_decode_attention(q, cache["k"], cache["v"], k, v,
                                             clen, window=cfg.window,
                                             slot_pos=cache["slot_pos"],
                                             softcap=cfg.attn_softcap)
        # positional reconstruction: scatter the ring into a zero buffer at
        # the recorded absolute positions (empty slots land on the sliced-off
        # sentinel row s_max), then the chunk keys at theirs.  The ring holds
        # every position in [clen - window, clen), so all in-window keys are
        # present; out-of-window zeros are window-masked to exactly 0.0.
        g, hd = cfg.num_kv_heads, cfg.head_dim
        sp = cache["slot_pos"]                                  # (B, w)
        idx = jnp.where(sp >= 0, sp, s_max).astype(jnp.int32)
        kb = jnp.zeros((b, s_max + 1, g, hd), k.dtype)
        vb = jnp.zeros((b, s_max + 1, g, hd), v.dtype)
        kb = kb.at[rows[:, None], idx].set(cache["k"].astype(k.dtype))
        vb = vb.at[rows[:, None], idx].set(cache["v"].astype(v.dtype))
        kb = kb.at[rows[:, None], positions].set(k)[:, :s_max]
        vb = vb.at[rows[:, None], positions].set(v)[:, :s_max]
        att_pos = ATT.positional_prefill_attention(
            q, kb, vb, positions, window=cfg.window, softcap=cfg.attn_softcap)
        att = jnp.where(dmask, att_dec, att_pos)
        x = x + L.dense(qc, att.reshape(b, t, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, {"k": k, "v": v}
    return block_verify_delta(qc, kind, p, x, cache, cfg, cache_len=cache_len)


def block_chunk_paged(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray,
                      cache: Dict, cfg, *, cache_len: jnp.ndarray,
                      block_tables: jnp.ndarray, page_size: int,
                      decode_rows: jnp.ndarray, s_max: int
                      ) -> Tuple[jnp.ndarray, Dict]:
    """Paged twin of :func:`block_chunk_delta` (full-attention kinds only;
    others keep dense caches).  The gathered pool buffer is positionally
    indexed by construction — logical position j of row b lives at dense
    index j through the block table — so prefill rows reuse the same
    positional formulation over ``gather_pages`` (requires
    ``MP * page_size == s_max``, validated at Engine construction)."""
    if kind not in ("attn", "moe_attn"):
        return block_chunk_delta(qc, kind, p, x, cache, cfg,
                                 cache_len=cache_len,
                                 decode_rows=decode_rows, s_max=s_max)
    if qc.int8_kv:
        raise ValueError("chunked prefill requires exact (fp) KV caches; "
                         "int8_kv is rejected at Engine validation")
    b, t = x.shape[0], x.shape[1]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    positions = clen[:, None] + jnp.arange(t)[None, :]
    rows = jnp.arange(b)
    h = L.apply_norm(cfg.norm, p["ln"], x)
    q, k, v = _qkv(qc, p["attn"], h, cfg, positions, rope=True)
    att_dec = ATT.paged_chunk_decode_attention(
        q, cache["k"], cache["v"], block_tables, clen, k, v,
        softcap=cfg.attn_softcap, use_kernel=_use_paged_kernel(qc))
    kd = ATT.gather_pages(cache["k"], block_tables)            # (B, MP*page, …)
    vd = ATT.gather_pages(cache["v"], block_tables)
    kb = kd.at[rows[:, None], positions].set(k.astype(kd.dtype))
    vb = vd.at[rows[:, None], positions].set(v.astype(vd.dtype))
    att_pos = ATT.positional_prefill_attention(
        q, kb, vb, positions, softcap=cfg.attn_softcap)
    att = jnp.where(decode_rows[:, None, None, None], att_dec, att_pos)
    x = x + L.dense(qc, att.reshape(b, t, -1), p["attn"]["o"])
    x = _mlp_part(qc, kind, p, x, cfg)
    return x, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# empty caches for serve_step lowering (shapes only — works under eval_shape)
# ---------------------------------------------------------------------------
def init_block_cache(kind: str, cfg, batch: int, s_max: int, dtype=jnp.bfloat16,
                     int8_kv: bool = False):
    g, hd = cfg.num_kv_heads, cfg.head_dim
    if kind in ("attn", "moe_attn"):
        shape = (batch, s_max, g, hd)
        if int8_kv:
            return {"k": jnp.zeros(shape, jnp.int8),
                    "ks": jnp.zeros(shape[:-1], jnp.float32),
                    "v": jnp.zeros(shape, jnp.int8),
                    "vs": jnp.zeros(shape[:-1], jnp.float32)}
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == "local":
        w = min(cfg.window, s_max)
        shape = (batch, w, g, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "slot_pos": jnp.full((batch, w), -1, jnp.int32)}
    if kind == "cross":
        t = cfg.num_image_tokens
        return {"k": jnp.zeros((batch, t, g, hd), dtype),
                "v": jnp.zeros((batch, t, g, hd), dtype)}
    if kind == "rglru":
        dr = cfg.rnn_width
        return {"conv": jnp.zeros((batch, 3, dr), dtype), "h": jnp.zeros((batch, dr), dtype)}
    if kind == "ssm":
        d = SSM.ssm_dims(cfg)
        return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, d["conv_ch"]), dtype),
                "ssm": jnp.zeros((batch, d["heads"], d["p"], d["n"]), dtype)}
    raise ValueError(kind)


def init_block_pool(kind: str, cfg, num_pages: int, page_size: int,
                    dtype=jnp.bfloat16, int8_kv: bool = False):
    """Page pool for a full-attention block: ``num_pages`` usable pages plus
    the sentinel page as the LAST pool row (block-table id ``num_pages``)."""
    if kind not in ("attn", "moe_attn"):
        raise ValueError(f"only full-attention blocks page, got {kind!r}")
    g, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (num_pages + 1, page_size, g, hd)
    if int8_kv:
        return {"k": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros(shape[:-1], jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "vs": jnp.zeros(shape[:-1], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
