"""Transformer-family blocks: attn / local / cross / moe_attn / rglru / ssm.

Each block kind provides (init, forward, decode_step) with a uniform
signature so ``model.py`` can scan heterogeneous stage patterns.  Forward
returns ``(x, cache)`` where cache feeds the decode path:

  attn/moe_attn : {"k","v"} full KV           (B, S_max, G, Dh)
  local         : {"k","v","slot_pos"} ring   (B, W, G, Dh) sliding window
  cross         : {"k","v"} static image KV   (B, T_img, G, Dh)
  rglru         : {"conv","h"}                O(1) recurrent state
  ssm           : {"conv","ssm"}              O(1) SSD state
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as ATT
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models.layers import QuantContext


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _attn_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    h, g = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "q": L.dense_init(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "k": L.dense_init(ks[1], d, g * hd, bias=cfg.qkv_bias, dtype=dtype),
        "v": L.dense_init(ks[2], d, g * hd, bias=cfg.qkv_bias, dtype=dtype),
        "o": L.dense_init(ks[3], h * hd, d, dtype=dtype),
    }


def block_init(key, kind: str, cfg, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("attn", "local", "cross", "moe_attn"):
        p = {"ln": L.norm_init(d, dtype), "attn": _attn_init(k1, cfg, dtype),
             "mlp_ln": L.norm_init(d, dtype)}
        if kind == "moe_attn":
            p["moe"] = MOE.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = L.mlp_init(k2, d, cfg.d_ff, gated=cfg.mlp_gated, dtype=dtype)
        if kind == "cross":
            p["xattn_gate"] = jnp.zeros((), dtype)  # gated cross-attn (llama3.2-v)
        return p
    if kind == "rglru":
        return {"ln": L.norm_init(d, dtype), "rec": RG.rglru_init(k1, cfg, dtype),
                "mlp_ln": L.norm_init(d, dtype),
                "mlp": L.mlp_init(k2, d, cfg.d_ff, gated=cfg.mlp_gated, dtype=dtype)}
    if kind == "ssm":
        return {"ln": L.norm_init(d, dtype), "mixer": SSM.ssm_init(k1, cfg, dtype)}
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _qkv(qc, p, x, cfg, positions: Optional[jnp.ndarray], *, rope: bool):
    b, s, _ = x.shape
    hd, h, g = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = L.dense(qc, x, p["q"]).reshape(b, s, h, hd)
    k = L.dense(qc, x, p["k"]).reshape(b, s, g, hd)
    v = L.dense(qc, x, p["v"]).reshape(b, s, g, hd)
    if rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp_part(qc, kind, p, x, cfg):
    h = L.apply_norm(cfg.norm, p["mlp_ln"], x)
    if kind == "moe_attn":
        return x + MOE.moe_apply(qc, p["moe"], h, cfg)
    return x + L.mlp_apply(qc, p["mlp"], h, cfg.mlp_act)


# ---------------------------------------------------------------------------
# forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------
def block_forward(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray, cfg, *,
                  positions: jnp.ndarray, side: Optional[Dict] = None,
                  s_max: int = 0) -> Tuple[jnp.ndarray, Dict]:
    b = x.shape[0]
    if kind in ("attn", "local", "moe_attn"):
        h = L.apply_norm(cfg.norm, p["ln"], x)
        causal = not cfg.is_encoder
        window = cfg.window if kind == "local" else 0
        q, k, v = _qkv(qc, p["attn"], h, cfg, positions, rope=not cfg.is_encoder)
        att = ATT.flash_attention(q, k, v, causal=causal, window=window,
                                  softcap=cfg.attn_softcap,
                                  q_chunk=cfg.attn_q_chunk or 1024,
                                  kv_chunk=cfg.attn_kv_chunk or 1024)
        x = x + L.dense(qc, att.reshape(b, att.shape[1], -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        if kind == "local":
            w = min(cfg.window, k.shape[1])
            cache = {"k": k[:, -w:], "v": v[:, -w:],
                     "slot_pos": positions[-w:] if positions.ndim == 1 else positions[0, -w:]}
        elif qc.int8_kv:
            kq, ks = ATT.quantize_kv(k)
            vq, vs = ATT.quantize_kv(v)
            cache = {"k": kq, "ks": ks, "v": vq, "vs": vs}
        else:
            cache = {"k": k, "v": v}
        return x, cache
    if kind == "cross":
        assert side is not None and "image_emb" in side, "cross block needs image side input"
        h = L.apply_norm(cfg.norm, p["ln"], x)
        hd, hq, g = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
        q = L.dense(qc, h, p["attn"]["q"]).reshape(b, h.shape[1], hq, hd)
        img = side["image_emb"]                               # (B, T_img, D)
        t_img = img.shape[1]
        k_img = L.dense(qc, img, p["attn"]["k"]).reshape(b, t_img, g, hd)
        v_img = L.dense(qc, img, p["attn"]["v"]).reshape(b, t_img, g, hd)
        att = ATT.cross_attention(q, k_img, v_img)
        gate = jnp.tanh(p["xattn_gate"])
        x = x + gate * L.dense(qc, att.reshape(b, att.shape[1], -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, {"k": k_img, "v": v_img}
    if kind == "rglru":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        y, cache = RG.rglru_apply(qc, p["rec"], h, cfg)
        x = x + y
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, cache
    if kind == "ssm":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        y, cache = SSM.ssm_apply(qc, p["mixer"], h, cfg)
        return x + y, cache
    raise ValueError(kind)


def make_image_kv(qc: QuantContext, p: Dict, image_emb: jnp.ndarray, cfg):
    """Compute the static cross-attention KV from projected image embeddings
    using the *first cross block's* K/V projections (shared convention)."""
    b, t, _ = image_emb.shape
    g, hd = cfg.num_kv_heads, cfg.head_dim
    k = L.dense(qc, image_emb, p["k"]).reshape(b, t, g, hd)
    v = L.dense(qc, image_emb, p["v"]).reshape(b, t, g, hd)
    return k, v


# ---------------------------------------------------------------------------
# decode (single token against cache)
# ---------------------------------------------------------------------------
def block_decode(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray, cache: Dict,
                 cfg, *, cache_len: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, 1, D); cache_len: () — tokens already in cache (new token at
    position cache_len)."""
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    if kind in ("attn", "moe_attn"):
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, pos, rope=True)
        if qc.int8_kv:
            att = ATT.decode_attention_int8(
                q, cache["k"], cache["ks"], cache["v"], cache["vs"], k, v,
                cache_len, softcap=cfg.attn_softcap)
            kq, ks = ATT.quantize_kv(k)
            vq, vs = ATT.quantize_kv(v)
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, cache_len, axis=1),
                "ks": jax.lax.dynamic_update_slice_in_dim(cache["ks"], ks, cache_len, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, cache_len, axis=1),
                "vs": jax.lax.dynamic_update_slice_in_dim(cache["vs"], vs, cache_len, axis=1),
            }
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_len, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_len, axis=1)
            att = ATT.decode_attention(q, kc, vc, cache_len + 1,
                                       softcap=cfg.attn_softcap)
            new_cache = {"k": kc, "v": vc}
        x = x + L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, new_cache
    if kind == "local":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, pos, rope=True)
        w = cache["k"].shape[1]
        slot = jnp.mod(cache_len, w)
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        slot_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["slot_pos"], pos[0].astype(cache["slot_pos"].dtype), slot, axis=0)
        # ring attention: mask slots outside (cache_len - window, cache_len]
        valid = (slot_pos >= 0) & (slot_pos > cache_len - cfg.window) & (slot_pos <= cache_len)
        sc_q = q.reshape(b, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, -1)
        sc = jnp.einsum("bgrd,bkgd->bgrk", sc_q * (cfg.head_dim ** -0.5), kc)
        sc = jnp.where(valid[None, None, None, :], sc, ATT.NEG_INF)
        att = jnp.einsum("bgrk,bkgd->bgrd", jax.nn.softmax(sc, axis=-1), vc)
        x = x + L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, {"k": kc, "v": vc, "slot_pos": slot_pos}
    if kind == "cross":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        hd, hq = cfg.head_dim, cfg.num_heads
        q = L.dense(qc, h, p["attn"]["q"]).reshape(b, 1, hq, hd)
        att = ATT.decode_attention(q, cache["k"], cache["v"],
                                   jnp.int32(cache["k"].shape[1]))
        gate = jnp.tanh(p["xattn_gate"])
        x = x + gate * L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, cache
    if kind == "rglru":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        y, cache = RG.rglru_decode_step(qc, p["rec"], h, cache, cfg)
        x = x + y
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, cache
    if kind == "ssm":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        y, cache = SSM.ssm_decode_step(qc, p["mixer"], h, cache, cfg)
        return x + y, cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# delta decode: read the (old) layer cache, return one-token deltas so the
# caller can update the stacked cache in place (no full-buffer copies).
# Exactly equal to block_decode (tests assert bitwise-level closeness).
# ---------------------------------------------------------------------------
def block_decode_delta(qc: QuantContext, kind: str, p: Dict, x: jnp.ndarray,
                       cache: Dict, cfg, *, cache_len: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, Dict]:
    """Returns (x, delta).  delta keys mirror the cache; values are either
    one-token slices (attn k/v, local k/v/slot_pos), full small states
    (rglru/ssm), or None (cross: static)."""
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    if kind in ("attn", "moe_attn"):
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, pos, rope=True)
        if qc.int8_kv:
            att = ATT.decode_attention_int8(
                q, cache["k"], cache["ks"], cache["v"], cache["vs"], k, v,
                cache_len, softcap=cfg.attn_softcap)
            kq, ks = ATT.quantize_kv(k)
            vq, vs = ATT.quantize_kv(v)
            delta = {"k": kq, "ks": ks, "v": vq, "vs": vs}
        else:
            att = ATT.decode_attention_appended(q, cache["k"], cache["v"], k, v,
                                                cache_len, softcap=cfg.attn_softcap)
            delta = {"k": k, "v": v}
        x = x + L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, delta
    if kind == "local":
        h = L.apply_norm(cfg.norm, p["ln"], x)
        q, k, v = _qkv(qc, p["attn"], h, cfg, pos, rope=True)
        w = cache["k"].shape[1]
        slot = jnp.mod(cache_len, w)
        sp = cache["slot_pos"]
        # mask out the slot we are about to overwrite plus out-of-window slots
        valid = (sp >= 0) & (sp > cache_len - cfg.window) & (sp < cache_len)
        att = ATT.decode_attention_appended(q, cache["k"], cache["v"], k, v,
                                            cache_len, valid_mask=valid,
                                            softcap=cfg.attn_softcap)
        x = x + L.dense(qc, att.reshape(b, 1, -1), p["attn"]["o"])
        x = _mlp_part(qc, kind, p, x, cfg)
        return x, {"k": k, "v": v,
                   "slot_pos": pos[0].astype(sp.dtype)}
    if kind == "cross":
        x, _ = block_decode(qc, kind, p, x, cache, cfg, cache_len=cache_len)
        return x, {"k": None, "v": None}
    # recurrent kinds: the full (small) state is the delta
    return block_decode(qc, kind, p, x, cache, cfg, cache_len=cache_len)


# ---------------------------------------------------------------------------
# empty caches for serve_step lowering (shapes only — works under eval_shape)
# ---------------------------------------------------------------------------
def init_block_cache(kind: str, cfg, batch: int, s_max: int, dtype=jnp.bfloat16,
                     int8_kv: bool = False):
    g, hd = cfg.num_kv_heads, cfg.head_dim
    if kind in ("attn", "moe_attn"):
        shape = (batch, s_max, g, hd)
        if int8_kv:
            return {"k": jnp.zeros(shape, jnp.int8),
                    "ks": jnp.zeros(shape[:-1], jnp.float32),
                    "v": jnp.zeros(shape, jnp.int8),
                    "vs": jnp.zeros(shape[:-1], jnp.float32)}
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == "local":
        w = min(cfg.window, s_max)
        shape = (batch, w, g, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "slot_pos": jnp.full((w,), -1, jnp.int32)}
    if kind == "cross":
        t = cfg.num_image_tokens
        return {"k": jnp.zeros((batch, t, g, hd), dtype),
                "v": jnp.zeros((batch, t, g, hd), dtype)}
    if kind == "rglru":
        dr = cfg.rnn_width
        return {"conv": jnp.zeros((batch, 3, dr), dtype), "h": jnp.zeros((batch, dr), dtype)}
    if kind == "ssm":
        d = SSM.ssm_dims(cfg)
        return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, d["conv_ch"]), dtype),
                "ssm": jnp.zeros((batch, d["heads"], d["p"], d["n"]), dtype)}
    raise ValueError(kind)
