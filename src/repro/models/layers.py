"""Shared layer primitives: norms, MLPs, RoPE, embeddings, init helpers.

Every GEMM weight is a dict leaf named ``kernel`` and is applied through
:func:`repro.core.linear.dense`, so the whole zoo is expandable by
``core.ptq.expand_params`` without model-specific plumbing.  A
:class:`QuantContext` (policy + on/off) is threaded through apply fns as a
static argument.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.expansion import ExpandedTensor
from repro.core.linear import dense as _dense
from repro.core.policy import ExpansionPolicy

PyTree = Any


@dataclasses.dataclass(frozen=True)
class QuantContext:
    """Static quantization context threaded through model apply fns.

    ``mesh``/``placement`` select the distributed execution of expanded
    GEMMs (DESIGN.md §9): ``placement="term"`` with a 1-D ``"expand"`` mesh
    routes every :class:`ExpandedTensor` dense through the Theorem-2
    ``shard_map``+psum executor; ``"tensor"`` (column-parallel) and
    ``"replicated"`` keep the local apply — their distribution lives in the
    parameter shardings, consumed by GSPMD, not in the compute graph.

    ``term_budget`` caps every expanded GEMM at its first ``k`` series terms
    (Theorem 1 prefix = a coherent lower-precision model, DESIGN.md §10):
    the truncated-series *draft* context of self-speculative decoding.
    ``None`` serves the full series; weights with fewer terms are served
    whole.  Replicated/tensor placements slice the term axis
    (:meth:`ExpandedTensor.truncate`, genuinely fewer per-term GEMMs);
    ``placement="term"`` masks the trailing scales to zero instead — the
    Abelian identity — because the term axis lives scattered across the
    mesh."""
    policy: Optional[ExpansionPolicy] = None
    use_kernel: bool = False  # Pallas path (CPU interpret / TPU Mosaic)
    int8_kv: bool = False     # int8 KV cache + int8 attention dots (serving)
    mesh: Optional[Any] = None       # jax.sharding.Mesh (hashable) or None
    placement: str = "replicated"    # "replicated"|"term"|"tensor"|"expert"
    term_budget: Optional[int] = None  # k-term series prefix (draft model)
    # MoE routing rule (models/moe.py): "group" = capacity/drop batch
    # semantics; "token" = dropless per-token dispatch — the serving
    # contract (bit-frozen per row, slot-order invariant), set by the
    # Engine so decode/verify/chunk rounds never couple rows through a
    # shared capacity cumsum.
    moe_routing: str = "group"       # "group" | "token"

    @property
    def enabled(self) -> bool:
        return self.policy is not None

    @property
    def term_parallel(self) -> bool:
        if self.mesh is None:
            return False
        if self.placement == "term":
            return True
        # 2-D expert×term composition: an "expert" placement whose mesh
        # carries a non-trivial "expand" axis also term-shards dense leaves
        return (self.placement == "expert"
                and self.mesh.shape.get("expand", 1) > 1)

    @property
    def expert_parallel(self) -> bool:
        return self.placement == "expert" and self.mesh is not None


FP = QuantContext(policy=None)


def dense(qc: QuantContext, x: jnp.ndarray, params: Dict, name: str = "kernel") -> jnp.ndarray:
    w = params[name]
    if isinstance(w, ExpandedTensor):
        if qc.term_parallel and w.batch_dims == 0:
            # Theorem-2 execution: weight terms live scattered over the mesh
            # "expand" axis; each device contributes its basis-model partial
            # and one psum (AbelianAdd) combines them (DESIGN.md §9)
            from repro.dist.expansion_parallel import term_parallel_apply
            y = term_parallel_apply(x, w, qc.policy, qc.mesh,
                                    term_budget=qc.term_budget).astype(x.dtype)
        else:
            # the series GEMM accumulates in f32; return in the stream dtype
            y = _dense(x, w, qc.policy, use_kernel=qc.use_kernel,
                       term_budget=qc.term_budget).astype(x.dtype)
    else:
        y = jnp.dot(x, w)
    if "bias" in params:
        y = y + params["bias"]
    return y


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, scale: float = 1.0,
               dtype=jnp.float32) -> Dict:
    std = scale / (d_in ** 0.5)
    p = {"kernel": jax.random.normal(key, (d_in, d_out), dtype) * std}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def norm_init(dim: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


def layernorm(params: Dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


def apply_norm(kind: str, params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # nemotron squared-ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def mlp_init(key, d_model: int, d_ff: int, *, gated: bool, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d_model, d_ff, dtype=dtype),
         "wo": dense_init(ks[1], d_ff, d_model, dtype=dtype)}
    if gated:
        p["wg"] = dense_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(qc: QuantContext, params: Dict, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    h = dense(qc, x, params["wi"])
    if "wg" in params:  # gated (SwiGLU / GeGLU)
        h = act_fn(activation)(dense(qc, x, params["wg"])) * h
    else:
        h = act_fn(activation)(h)
    return dense(qc, h, params["wo"])


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # (Dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / heads
# ---------------------------------------------------------------------------
def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> Dict:
    return {"embedding": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed_apply(params: Dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["embedding"], tokens, axis=0)


def logits_apply(qc: QuantContext, params: PyTree, x: jnp.ndarray, *,
                 tie_embeddings: bool, softcap: float = 0.0) -> jnp.ndarray:
    if tie_embeddings:
        logits = jnp.dot(x, params["embed"]["embedding"].T)
    else:
        logits = dense(qc, x, params["lm_head"])
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# ---------------------------------------------------------------------------
# causal depthwise conv (mamba2 / RG-LRU short conv)
# ---------------------------------------------------------------------------
def conv1d_init(key, channels: int, width: int, dtype=jnp.float32) -> Dict:
    return {"w": jax.random.normal(key, (width, channels), dtype) * (1.0 / width ** 0.5),
            "b": jnp.zeros((channels,), dtype)}


def causal_conv1d(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv; x: (B, L, C) -> (B, L, C)."""
    w = params["w"]                                   # (K, C)
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):                                # small static unroll
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + params["b"]


def causal_conv1d_step(params: Dict, conv_state: jnp.ndarray, x_t: jnp.ndarray):
    """Single-token conv step.  conv_state: (B, K-1, C); x_t: (B, C)."""
    w = params["w"]
    k = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window, w) + params["b"]
    return out, window[:, 1:, :]


def gather_tail(x: jnp.ndarray, lengths: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-row tail of a right-padded sequence: the last ``k`` *valid*
    positions of each row.  x: (B, L, C); lengths: (B,) -> (B, k, C).
    Rows shorter than ``k`` are left-zero-filled (matches the zero left-pad
    the unpadded path applies when a prompt is shorter than the window)."""
    if k <= 0:
        return x[:, :0, :]
    idx = lengths[:, None] - k + jnp.arange(k)[None, :]        # (B, k)
    ok = idx >= 0
    g = jnp.take_along_axis(x, jnp.clip(idx, 0, x.shape[1] - 1)[:, :, None], axis=1)
    return jnp.where(ok[:, :, None], g, 0).astype(x.dtype)
