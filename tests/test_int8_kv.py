"""INT8 KV cache + int8 attention dots (beyond-paper serving feature)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import attention as ATT
from repro.models import model as M
from repro.models.layers import FP, QuantContext

QC8 = QuantContext(int8_kv=True)


def test_quantize_kv_roundtrip(rng):
    x = jnp.array(rng.normal(size=(2, 16, 4, 32)).astype(np.float32))
    q, s = ATT.quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 16, 4)
    rec = q.astype(jnp.float32) * s[..., None]
    rel = float(jnp.linalg.norm(rec - x) / jnp.linalg.norm(x))
    assert rel < 0.01


def test_int8_decode_attention_close_to_fp(rng):
    b, t, g, r, d = 2, 24, 2, 2, 16
    h = g * r
    q = jnp.array(rng.normal(size=(b, 1, h, d)).astype(np.float32))
    k = jnp.array(rng.normal(size=(b, t, g, d)).astype(np.float32))
    v = jnp.array(rng.normal(size=(b, t, g, d)).astype(np.float32))
    k_new = jnp.array(rng.normal(size=(b, 1, g, d)).astype(np.float32))
    v_new = jnp.array(rng.normal(size=(b, 1, g, d)).astype(np.float32))
    clen = jnp.int32(20)
    fp = ATT.decode_attention_appended(q, k, v, k_new, v_new, clen)
    kq, ks = ATT.quantize_kv(k)
    vq, vs = ATT.quantize_kv(v)
    i8 = ATT.decode_attention_int8(q, kq, ks, vq, vs, k_new, v_new, clen)
    rel = float(jnp.linalg.norm(i8 - fp) / jnp.linalg.norm(fp))
    assert rel < 0.03, rel


@pytest.mark.parametrize("arch", ("qwen2_1_5b", "grok_1_314b"))
def test_int8_kv_decode_consistency(rng, arch):
    """int8-kv decode stays close to FP decode; inplace == scan exactly."""
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 20
    tokens = jnp.array(rng.integers(0, cfg.vocab_size, (b, s + 2)), jnp.int32)
    pre = {"tokens": tokens[:, :s]}
    _, c_fp = M.prefill(params, pre, cfg, FP, s_max=32)
    _, c_i8 = M.prefill(params, pre, cfg, QC8, s_max=32)
    # cache layout: int8 planes + scales
    k_leaf = c_i8["stages"][f"b0_{cfg.stage_pattern[0]}"]["k"]
    assert k_leaf.dtype == jnp.int8
    clen = jnp.int32(s)
    for t in range(2):
        tok = tokens[:, s + t:s + t + 1]
        l_fp, c_fp = M.decode_step(params, tok, c_fp, clen, cfg, FP)
        l_i8, c_i8 = M.decode_step(params, tok, c_i8, clen, cfg, QC8)
        rel = float(jnp.linalg.norm(l_i8 - l_fp) / jnp.linalg.norm(l_fp))
        assert rel < 0.08, (t, rel)
        clen = clen + 1


def test_int8_kv_inplace_equals_scan(rng):
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.array(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    _, caches = M.prefill(params, {"tokens": tokens[:, :10]}, cfg, QC8, s_max=24)
    l1, _ = M.decode_step(params, tokens[:, 10:11], caches, jnp.int32(10), cfg, QC8, inplace=True)
    l2, _ = M.decode_step(params, tokens[:, 10:11], caches, jnp.int32(10), cfg, QC8, inplace=False)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_int8_cache_specs_and_sizes():
    cfg = get_arch("deepseek_7b")
    c8 = jax.eval_shape(lambda: M.init_cache(cfg, 8, 1024, int8_kv=True))
    cf = jax.eval_shape(lambda: M.init_cache(cfg, 8, 1024))
    b8 = sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(c8))
    bf = sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(cf))
    assert b8 < 0.6 * bf  # int8 + f32 scales ~= 0.52x of bf16
