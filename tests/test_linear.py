"""Layer-level expansion (Eq. 3/4): error bounds + affine-path exactness."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import expansion as E
from repro.core import linear as LIN
from repro.core.policy import ExpansionPolicy, W2A2, W4A4, W4A16, W8A8


def _xw(rng, m=16, k=48, n=24):
    x = jnp.array(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.array(rng.normal(size=(k, n)).astype(np.float32))
    return x, w


@pytest.mark.parametrize("pol,tol", [(W8A8, 2e-2), (W4A4, 2e-2), (W2A2, 0.35), (W4A16, 2e-2)])
def test_relative_error_by_policy(rng, pol, tol):
    x, w = _xw(rng)
    w_et = LIN.expand_weight(w, pol)
    y = LIN.expanded_apply(x, w_et, pol)
    y_fp = x @ w
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < tol, rel


def test_more_activation_terms_reduce_error(rng):
    """Fig. 4b at the layer level: error decreases monotonically in a_terms."""
    x, w = _xw(rng)
    pol = W4A4
    w_et = LIN.expand_weight(w, pol)
    errs = []
    for t in (1, 2, 3, 4):
        y = LIN.expanded_apply(x, w_et, pol, a_terms=t)
        errs.append(float(jnp.linalg.norm(y - x @ w)))
    assert errs[0] > errs[1] > errs[2], errs
    assert errs[3] <= errs[2] * 1.1


def test_weight_only_path_exact_activation(rng):
    """W4A16: error comes only from the weight series."""
    x, w = _xw(rng)
    w_et = LIN.expand_weight(w, W4A16)
    y = LIN.expanded_apply(x, w_et, W4A16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ E.reconstruct(w_et)),
                               rtol=2e-4, atol=2e-4)


def test_colsum_identities(rng):
    _, w = _xw(rng)
    pol = ExpansionPolicy(w_bits=4, a_bits=4, w_symmetric=False, w_saturating=True)
    w_et = LIN.expand_weight(w, pol)
    k = w.shape[0]
    # full_colsum == colsum of the reconstruction
    np.testing.assert_allclose(np.asarray(LIN.full_colsum(w_et)),
                               np.asarray(jnp.sum(E.reconstruct(w_et), axis=0)),
                               rtol=1e-4, atol=1e-4)


def test_dropped_term_is_only_quant_residual(rng):
    """expanded_apply == Q(x~)@S_w + exact affine terms — i.e. the ONLY
    approximation is the activation series residual (DESIGN.md §2)."""
    x, w = _xw(rng, m=8, k=32, n=12)
    pol = ExpansionPolicy(w_bits=4, a_bits=4, w_terms=2, a_terms=3,
                          a_symmetric=False, w_saturating=True, a_saturating=True,
                          keep_w_sat=True, keep_a_sat=True)
    w_et = LIN.expand_weight(w, pol)
    y = LIN.expanded_apply(x, w_et, pol)
    # rebuild the decomposition exactly as the apply path defines it
    x2 = x.reshape(-1, 32)
    xt, bias_a, sigma, s1 = LIN._dynamic_act_params(x2, pol, pol.a_bits)
    from repro.kernels import ref
    a_planes = ref.residual_quantize_ref(xt, s1, pol.a_bits, pol.a_terms)
    x_hat = sum((s1 / float(E.scale_ratio(pol.a_bits) ** i)) * a_planes[i].astype(jnp.float32)
                for i in range(pol.a_terms))
    w_rec = E.reconstruct(w_et)
    sat = w_et.sat if w_et.sat is not None else jnp.zeros_like(w_rec)
    bias_w = w_et.bias if w_et.bias is not None else jnp.zeros((12,), jnp.float32)
    s_w = w_rec - sat - jnp.broadcast_to(bias_w, w_rec.shape)  # series part only
    expect = (x_hat @ s_w
              + jnp.sum(xt, axis=-1, keepdims=True) * bias_w
              + xt @ sat)
    if bias_a is not None:
        expect = expect + bias_a * LIN.full_colsum(w_et)[None, :]
    if sigma is not None:
        expect = expect + sigma @ w_rec
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), rtol=2e-3, atol=2e-3)


def test_dense_dispatch(rng):
    x, w = _xw(rng)
    np.testing.assert_allclose(np.asarray(LIN.dense(x, w)), np.asarray(x @ w))
    w_et = LIN.expand_weight(w, W4A4)
    y = LIN.dense(x, w_et, W4A4)
    assert y.shape == (16, 24)
