"""Multi-device serving placements (DESIGN.md §9), on fake host devices via
subprocess — the main pytest process must keep 1 device, per the dry-run
isolation contract (same pattern as test_multidevice.py):

* ``placement="term"`` (Theorem-2 series-term scattering, shard_map + one
  psum per expanded GEMM) serves the slot-scheduler continuous-batching
  workload with generated tokens IDENTICAL to the replicated engine, for
  the attn, rglru and ssm arch classes — including mixed lengths, slot
  recycling and per-request budgets;
* term counts that do not divide the mesh axis are zero-plane padded
  (W2A4's w_terms=3 on 4 devices) and weight-only policies (W4A16) take
  the per-term dequant psum path — both token-identical;
* ``placement="tensor"`` (column-parallel) is token-identical too;
* HBM admission control is mesh-aware: scattering weights shrinks the
  per-device parameter residency, so the same per-device budget admits at
  least as many slots (strictly more at the constructed budget).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(*parts: str, n_devices: int = 4, timeout=560):
    """Run the dedented concatenation of ``parts`` in a fake-device
    subprocess.  Each part is dedented separately (the shared prelude and
    per-test bodies carry different source indentation), and the combined
    script must end by printing OK — guarding against a silently truncated
    script that defines helpers but never executes the assertions."""
    py_src = "\n".join(textwrap.dedent(p) for p in parts)
    assert "OK" in py_src.rsplit("print", 1)[-1], "test body must print ...OK"
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_NO_PALLAS"] = "1"   # sharded placements serve the ref path
    out = subprocess.run([sys.executable, "-c", py_src],
                         capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout, f"script did not reach its OK print:\n{out.stdout}"
    return out.stdout


_COMMON = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import QuantRecipe, Runtime, quantize
    from repro.configs.base import get_arch
    from repro.core.policy import W4A4, W2A4, W4A16
    from repro.dist.placement import make_serve_mesh
    from repro.infer.serve import ServeConfig
    from repro.models import model as M

    def build(arch, policy, placement, mesh=None, cfg=None, art=None):
        cfg = cfg or get_arch(arch, smoke=True)
        if art is None:
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            art = quantize(params, QuantRecipe(policy=policy, arch=arch,
                                               smoke=True))
        rt = Runtime(art, backend="ref", cfg=cfg, mesh=mesh,
                     placement=placement)
        return cfg, art, rt

    def serve_workload(rt, cfg, *, n_req=6, slots=2, max_seq=48, seed=1):
        # mixed lengths + per-request budgets + recycling (n_req > slots)
        eng = rt.serve(ServeConfig(max_seq=max_seq, max_batch=slots,
                                   max_slots=slots))
        rng = np.random.default_rng(seed)
        for _ in range(n_req):
            L = int(rng.integers(4, 14))
            eng.add_request(rng.integers(0, cfg.vocab_size, L).tolist(),
                            max_new_tokens=int(rng.integers(3, 7)))
        out = eng.run(max_new_tokens=6)
        return out, eng.last_run_stats
"""


def test_term_parallel_serving_token_identical_attn():
    """attn arch class on a 4-device term mesh: identical served tokens,
    logits within psum-reassociation tolerance, stats report the mesh."""
    _run(_COMMON, """
        arch = "qwen2_1_5b"
        cfg, art, rt_rep = build(arch, W4A4, "replicated")
        mesh = make_serve_mesh(4, "term")
        _, _, rt_term = build(arch, W4A4, "term", mesh, cfg=cfg, art=art)

        toks = jnp.array(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (2, 12)), jnp.int32)
        y_rep, y_term = rt_rep.apply(toks), rt_term.apply(toks)
        np.testing.assert_allclose(np.asarray(y_term), np.asarray(y_rep),
                                   rtol=1e-3, atol=1e-3)

        out_rep, st_rep = serve_workload(rt_rep, cfg)
        out_term, st_term = serve_workload(rt_term, cfg)
        assert out_term == out_rep, (out_term, out_rep)
        assert st_term["placement"] == "term" and st_term["mesh_devices"] == 4
        assert st_rep["placement"] == "replicated" and st_rep["mesh_devices"] == 1
        assert st_term["n_slots"] == st_rep["n_slots"]
        print("attn term-parallel OK")
    """)


@pytest.mark.parametrize("arch", ["recurrentgemma_9b", "mamba2_780m"])
def test_term_parallel_serving_token_identical_recurrent(arch):
    """rglru and ssm arch classes: the term placement must compose with
    per-row recurrent state carry, local rings and conv tails."""
    _run(_COMMON, f"""
        arch = {arch!r}
        cfg, art, rt_rep = build(arch, W4A4, "replicated")
        mesh = make_serve_mesh(4, "term")
        _, _, rt_term = build(arch, W4A4, "term", mesh, cfg=cfg, art=art)
        out_rep, _ = serve_workload(rt_rep, cfg)
        out_term, _ = serve_workload(rt_term, cfg)
        assert out_term == out_rep, (out_term, out_rep)
        print("recurrent term-parallel OK")
    """)


def test_term_padding_weight_only_and_tensor_placement():
    """Non-dividing term counts (W2A4: w_terms=3 on 4 shards -> one zero
    plane), the weight-only dequant psum path (W4A16), and column-parallel
    tensor placement — all token-identical to replicated."""
    _run(_COMMON, """
        from repro.core.expansion import ExpandedTensor
        arch = "qwen2_1_5b"
        mesh = make_serve_mesh(4, "term")

        for policy in (W2A4, W4A16):
            cfg, art, rt_rep = build(arch, policy, "replicated")
            _, _, rt_term = build(arch, policy, "term", mesh, cfg=cfg, art=art)
            # zero-plane padding: every expanded leaf's term axis divides 4
            for leaf in jax.tree_util.tree_leaves(
                    rt_term.params,
                    is_leaf=lambda l: isinstance(l, ExpandedTensor)):
                if isinstance(leaf, ExpandedTensor):
                    assert leaf.num_terms % 4 == 0, leaf
            out_rep, _ = serve_workload(rt_rep, cfg)
            out_term, _ = serve_workload(rt_term, cfg)
            assert out_term == out_rep, (policy, out_term, out_rep)
        print("padding + weight-only OK")

        cfg, art, rt_rep = build(arch, W4A4, "replicated")
        mesh_t = make_serve_mesh(4, "tensor")
        _, _, rt_tensor = build(arch, W4A4, "tensor", mesh_t, cfg=cfg, art=art)
        out_rep, _ = serve_workload(rt_rep, cfg)
        out_tensor, st = serve_workload(rt_tensor, cfg)
        assert out_tensor == out_rep
        assert st["placement"] == "tensor" and st["mesh_devices"] == 4
        print("tensor placement OK")
    """)


def test_hbm_admission_mesh_aware():
    """Per-device HBM admission: scattering the series terms shrinks the
    per-device param bytes, so a budget that fits k replicated slots fits
    strictly more term-sharded slots; the scalar (replicated) math is
    unchanged from the single-device engine."""
    _run(_COMMON, """
        from repro.infer import kvcache
        from repro.infer.scheduler import plan_slots

        arch = "qwen2_1_5b"
        cfg, art, rt_rep = build(arch, W4A4, "replicated")
        mesh = make_serve_mesh(4, "term")
        _, _, rt_term = build(arch, W4A4, "term", mesh, cfg=cfg, art=art)

        pb_rep = kvcache.param_bytes_per_device(rt_rep.params)
        pb_term = kvcache.param_bytes_per_device(rt_term.params)
        assert pb_rep == kvcache.param_bytes(rt_rep.params)  # unsharded: equal
        assert pb_term < pb_rep, (pb_term, pb_rep)

        max_seq = 32
        per_seq = kvcache.total_cache_bytes(cfg, 1, max_seq)
        budget = pb_rep + 2.5 * per_seq   # fits 2 replicated slots
        sc = ServeConfig(max_seq=max_seq, max_batch=64, max_slots=64,
                         hbm_budget_bytes=budget)
        n_rep = plan_slots(cfg, sc, rt_rep.params)
        n_term = plan_slots(cfg, sc, rt_term.params)
        assert n_rep == 2, n_rep
        expected = int((budget - pb_term) // per_seq)
        assert n_term == expected and n_term > n_rep, (n_term, expected, n_rep)

        # and the caps actually gate engines end-to-end
        eng = rt_term.serve(sc)
        for i in range(4):
            eng.add_request([1 + i, 2, 3], max_new_tokens=2)
        eng.run(max_new_tokens=2)
        assert eng.last_run_stats["n_slots"] == n_term
        print("mesh-aware HBM admission OK")
    """)
