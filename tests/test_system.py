"""End-to-end system behaviour: the full FP=xINT lifecycle on one model.

train (synthetic Markov LM) -> PTQ series-expand (calibration-free) ->
serve -> measure: (a) the expanded model preserves the trained model's
task accuracy far better than naive RTN at the same bit-width, and (b) the
Fig. 4b stopping rule (maxdiff < 1e-4) picks a sensible term count.
This is the paper's central claim, reproduced in-miniature.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core import expansion as E
from repro.core.policy import ExpansionPolicy, W2A2, W4A4
from repro.core.ptq import expand_params, max_weight_residual
from repro.models import model as M
from repro.models.layers import FP, QuantContext
from repro.quant.baselines import rtn_quantize_params
from repro.train.data import make_batch
from repro.train.train_step import TrainConfig, loss_fn, make_train_step


@pytest.fixture(scope="module")
def trained():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt, step = make_train_step(cfg, TrainConfig(lr=3e-3, remat=False))
    opt_state = opt.init(params)
    step = jax.jit(step)
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, i).items()}
        params, opt_state, m = step(params, opt_state, batch)
    return cfg, params, float(m["loss"])


def _eval_loss(cfg, params, qc=FP, n=4, seed_base=1000):
    losses = []
    for i in range(n):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, seed_base + i).items()}
        l, _ = loss_fn(params, batch, cfg, qc)
        losses.append(float(l))
    return float(np.mean(losses))


def test_training_learned_something(trained):
    cfg, params, final_loss = trained
    fresh = M.init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    assert _eval_loss(cfg, params) < _eval_loss(cfg, fresh) - 0.5


def test_series_expansion_preserves_accuracy_vs_rtn(trained):
    """Table 1 in miniature: the multi-term series at W4A4 vs the SAME
    quantizer family truncated to 1 term (= round-to-nearest W4A4).  The
    comparison isolates exactly the paper's contribution: extra series
    terms."""
    cfg, params, _ = trained
    base = _eval_loss(cfg, params)
    q = expand_params(params, W4A4)
    ours = _eval_loss(cfg, q, QuantContext(policy=W4A4))
    rtn_pol = ExpansionPolicy(w_bits=4, a_bits=4, w_terms=1, a_terms=1,
                              w_saturating=False)
    rtn = _eval_loss(cfg, expand_params(params, rtn_pol),
                     QuantContext(policy=rtn_pol))
    assert ours - base < 0.05, (base, ours)
    assert (rtn - base) > 2.0 * (ours - base) + 0.02, (base, ours, rtn)


def test_extreme_low_bit_still_works(trained):
    """W2A2 (paper's hardest setting): degraded but functional, and far
    better than 1-term RTN W2A2 (which collapses)."""
    cfg, params, _ = trained
    base = _eval_loss(cfg, params)
    q = expand_params(params, W2A2)
    ours = _eval_loss(cfg, q, QuantContext(policy=W2A2))
    rtn_pol = ExpansionPolicy(w_bits=2, a_bits=2, w_terms=1, a_terms=1,
                              w_saturating=False)
    rtn2 = _eval_loss(cfg, expand_params(params, rtn_pol),
                      QuantContext(policy=rtn_pol))
    assert ours < base + 1.0, (base, ours)
    assert rtn2 > ours + 0.5, (base, ours, rtn2)


def test_fig4b_stopping_rule(trained):
    """maxdiff < 1e-4 rule: the auto-selected term count reaches the plateau."""
    cfg, params, _ = trained
    diffs = []
    for t in (1, 2, 3, 4):
        pol = ExpansionPolicy(w_bits=4, a_bits=4, w_terms=t, first_last_terms=t)
        diffs.append(float(max_weight_residual(params, expand_params(params, pol))))
    assert diffs[0] > diffs[1] > diffs[2] > diffs[3]
    # the rule picks the first t with bound < 1e-4
    s1 = max(float(jnp.max(jnp.abs(l))) / 7.0
             for l in jax.tree_util.tree_leaves(params) if l.ndim >= 2)
    t_rule = E.auto_num_terms(s1, 4, 1e-4)
    assert diffs[min(t_rule, 4) - 1] < 1e-3  # measured ~ bound within an order


def test_serving_the_expanded_model(trained):
    from repro.infer.serve import Engine, ServeConfig
    cfg, params, _ = trained
    eng = Engine(cfg, params, policy=W4A4,
                 serve_cfg=ServeConfig(max_seq=48, max_batch=4))
    r = np.random.default_rng(0)
    ids = [eng.add_request(r.integers(0, cfg.vocab_size, 8).tolist()) for _ in range(4)]
    out = eng.run(max_new_tokens=6)
    assert all(len(out[i]) == 6 for i in ids)
