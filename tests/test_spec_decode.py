"""Self-speculative decoding from truncated-series drafts (DESIGN.md §10).

Theorem 1 makes the first ``k < t`` terms of every expansion a coherent
low-bit model sharing weights/scales/KV layout with the full series — a
free draft model.  Contracts tested here:

* ``ExpandedTensor.truncate(k)`` / ``QuantContext.term_budget``: the
  truncated prefix is exactly the model the budgeted context serves;
* ``model.verify_step`` scores a T-token chunk with per-position logits
  that match T sequential ``decode_step`` calls (token-level; fp caches
  bitwise-close), and ``commit_verify`` performs accept/rollback such that
  continuing to decode is indistinguishable from never having speculated —
  for the attn, local+rglru, and ssm arch classes;
* the engine's speculative slot scheduler emits GREEDY output
  token-identical to the non-speculative slots engine (weight-only and
  activation-quantized policies), through EOS recycling, per-request
  budgets, and mixed lengths;
* acceptance-rate metrics behave (full-budget draft => acceptance 1.0);
* multi-device: ``placement="term"`` at 4 fake devices serves the same
  speculative stream (subprocess, fake host devices).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core import expansion as E
from repro.core.policy import ExpansionPolicy, W4A4
from repro.core.ptq import expand_params
from repro.infer.serve import Engine, ServeConfig
from repro.models import model as M
from repro.models.layers import FP, QuantContext

# weight-only with THREE weight terms: k=1/2 are genuine truncations
W4A16_T3 = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=3, a_terms=0)

ARCHS = ["qwen2_1_5b", "recurrentgemma_9b", "mamba2_780m"]


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lengths, seed=0):
    r = np.random.default_rng(seed)
    return [r.integers(0, cfg.vocab_size, l).tolist() for l in lengths]


# ---------------------------------------------------------------------------
# truncate / term_budget
# ---------------------------------------------------------------------------
def test_truncate_method_is_prefix_view(rng):
    w = jnp.asarray(rng.normal(size=(16, 24)).astype(np.float32))
    et = E.expand(w, 4, 3, per_channel=True)
    tr = et.truncate(2)
    assert tr.num_terms == 2 and tr.orig_shape == et.orig_shape
    np.testing.assert_array_equal(np.asarray(tr.planes),
                                  np.asarray(et.planes[:2]))
    np.testing.assert_array_equal(np.asarray(tr.scales),
                                  np.asarray(et.scales[:2]))
    # bias/sat are affine corrections, not series terms: kept
    et_s = E.expand(w, 4, 3, symmetric=False, saturating=True)
    tr_s = et_s.truncate(1)
    assert tr_s.bias is not None and tr_s.sat is not None
    # over-budget is a no-op; the prefix reconstruction is the k-term model
    assert et.truncate(7).num_terms == 3
    np.testing.assert_allclose(np.asarray(E.reconstruct(et.truncate(2))),
                               np.asarray(E.reconstruct(et, terms=2)),
                               rtol=0, atol=0)


def test_term_budget_context_serves_truncated_model(rng):
    """A QuantContext with term_budget=k applies every expanded GEMM as if
    the weights had been truncated to k terms up front."""
    from repro.models.layers import dense

    w = jnp.asarray(rng.normal(size=(16, 24)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(5, 16)).astype(np.float32))
    et = E.expand(w, 4, 3, per_channel=True)
    qc_full = QuantContext(policy=W4A16_T3)
    qc_k = dataclasses.replace(qc_full, term_budget=2)
    y_budget = dense(qc_k, x, {"kernel": et})
    y_trunc = dense(qc_full, x, {"kernel": et.truncate(2)})
    np.testing.assert_array_equal(np.asarray(y_budget), np.asarray(y_trunc))
    # budget=None and an over-budget both serve the full series
    y_full = dense(qc_full, x, {"kernel": et})
    y_over = dense(dataclasses.replace(qc_full, term_budget=9), x,
                   {"kernel": et})
    np.testing.assert_array_equal(np.asarray(y_full), np.asarray(y_over))
    assert not np.array_equal(np.asarray(y_full), np.asarray(y_budget))


# ---------------------------------------------------------------------------
# model layer: verify_step + commit_verify vs sequential decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCHS)
def test_verify_step_matches_sequential_decode(rng, arch):
    """One chunked verify pass == T sequential decode steps: same argmax
    tokens at every position, caches (after a full-accept commit) close to
    the sequentially-built caches — for full-attn, local-ring+rglru, and
    ssm arch classes, at per-slot (vector) cache lengths."""
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s_max, T = 2, 32, 4
    lens = [7, 11]
    toks = rng.integers(0, cfg.vocab_size, (b, max(lens) + T))
    pad = np.zeros((b, max(lens)), np.int32)
    for i, l in enumerate(lens):
        pad[i, :l] = toks[i, :l]
    cl = jnp.asarray(lens, jnp.int32)
    _, c1 = M.prefill(params, {"tokens": jnp.asarray(pad)}, cfg,
                      s_max=s_max, lengths=cl)
    _, c2 = M.prefill(params, {"tokens": jnp.asarray(pad)}, cfg,
                      s_max=s_max, lengths=cl)
    chunk = jnp.asarray(toks[:, -T:], jnp.int32)
    seq_logits = []
    cc = c1
    for j in range(T):
        lg, cc = M.decode_step(params, chunk[:, j:j + 1], cc, cl + j, cfg)
        seq_logits.append(lg)
    seq_logits = jnp.stack(seq_logits, axis=1)                 # (B,T,V)
    v_logits, deltas = M.verify_step(params, chunk, c2, cl, cfg)
    np.testing.assert_allclose(np.asarray(v_logits), np.asarray(seq_logits),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(v_logits, -1)),
                                  np.asarray(jnp.argmax(seq_logits, -1)))
    # full accept (m = T-1: all T inputs consumed) == sequential caches
    committed = M.commit_verify(c2, deltas, cl, jnp.full((b,), T - 1,
                                                         jnp.int32), cfg)
    for a, bb in zip(jax.tree_util.tree_leaves(cc),
                     jax.tree_util.tree_leaves(committed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(bb, np.float32),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("arch", ARCHS)
def test_commit_rollback_is_invisible_to_later_decodes(rng, arch):
    """Accept only m < T-1 drafts, roll the rest back, then keep decoding:
    the stream must match a reference that never speculated — the rollback
    contract (stale attn rows masked by cache_len, local-ring entries
    restored, recurrent state gathered at the accepted step)."""
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s, s_max, T = 2, 9, 32, 4
    accept = 1                                   # consume 2 of 4 chunk inputs
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + T)), jnp.int32)
    _, c_ref = M.prefill(params, {"tokens": toks[:, :s]}, cfg, s_max=s_max)
    _, c_spec = M.prefill(params, {"tokens": toks[:, :s]}, cfg, s_max=s_max)
    cl = jnp.full((b,), s, jnp.int32)
    # speculate a chunk, accept only `accept` drafts
    _, deltas = M.verify_step(params, toks[:, s:s + T], c_spec, cl, cfg)
    c_spec = M.commit_verify(c_spec, deltas, cl,
                             jnp.full((b,), accept, jnp.int32), cfg)
    # reference: plain sequential decode of the SAME accepted tokens
    cc = c_ref
    for j in range(accept + 1):
        _, cc = M.decode_step(params, toks[:, s + j:s + j + 1], cc, cl + j, cfg)
    # both continue decoding the same continuation — tokens must agree
    cl2 = cl + accept + 1
    x_spec, x_ref = c_spec, cc
    inp = toks[:, s + accept + 1:s + accept + 2]
    for j in range(4):
        lg_s, x_spec = M.decode_step(params, inp, x_spec, cl2 + j, cfg)
        lg_r, x_ref = M.decode_step(params, inp, x_ref, cl2 + j, cfg)
        np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_r),
                                   rtol=2e-4, atol=2e-5)
        nxt = jnp.argmax(lg_r, -1)[:, None].astype(jnp.int32)
        assert bool(jnp.all(jnp.argmax(lg_s, -1)[:, None] == nxt))
        inp = nxt


# ---------------------------------------------------------------------------
# engine: greedy token identity + recycling + metrics
# ---------------------------------------------------------------------------
def _engine(cfg, params, policy, **sc_kw):
    kw = dict(max_seq=48, max_batch=2, max_slots=2)
    kw.update(sc_kw)
    return Engine(cfg, params, policy=policy, serve_cfg=ServeConfig(**kw))


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("policy", [W4A16_T3, W4A4],
                         ids=["w4a16_t3", "w4a4"])
def test_spec_engine_token_identical(arch, policy):
    """The acceptance contract: greedy speculative output is token-identical
    to the non-speculative slots engine — mixed lengths, slot recycling,
    more requests than slots — for every arch class, weight-only AND
    activation-quantized policies."""
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, [5, 9, 13, 7])
    base = _engine(cfg, params, policy)
    ids_b = [base.add_request(p) for p in prompts]
    ref = base.run(max_new_tokens=6)
    spec = _engine(cfg, params, policy, spec_terms=1, spec_lookahead=3)
    ids_s = [spec.add_request(p) for p in prompts]
    out = spec.run(max_new_tokens=6)
    for a, b in zip(ids_b, ids_s):
        assert out[b] == ref[a], (arch, ref[a], out[b])
    st = spec.last_run_stats
    assert 0.0 <= st["acceptance_rate"] <= 1.0
    assert st["spec_rounds"] == st["decode_steps"] > 0
    assert st["generated_tokens"] == 24
    assert st["tokens_per_round"] > 1.0        # speculation amortizes steps
    # never MORE dispatches than the baseline; strictly fewer whenever the
    # draft earns any acceptance at all (a weak draft can only tie)
    assert st["decode_steps"] <= base.last_run_stats["decode_steps"]


def test_spec_eos_and_budget_recycling(setup):
    """EOS inside an accepted chunk stops the request exactly where the
    baseline stops it (tokens after EOS in the chunk are dropped), frees the
    slot, and a queued request recycles it; per-request budgets truncate the
    chunk tail the same way."""
    cfg, params = setup
    prompts = _prompts(cfg, [8, 10, 6])
    base = _engine(cfg, params, W4A16_T3)
    r = base.add_request(prompts[0])
    eos = base.run(max_new_tokens=6)[r][3]     # a token mid-stream -> EOS
    base = _engine(cfg, params, W4A16_T3, eos_id=eos, max_slots=1)
    ids_b = [base.add_request(p, max_new_tokens=m)
             for p, m in zip(prompts, [6, 4, 6])]
    ref = base.run(max_new_tokens=6)
    spec = _engine(cfg, params, W4A16_T3, eos_id=eos, max_slots=1,
                   spec_terms=1, spec_lookahead=3)
    ids_s = [spec.add_request(p, max_new_tokens=m)
             for p, m in zip(prompts, [6, 4, 6])]
    out = spec.run(max_new_tokens=6)
    for a, b in zip(ids_b, ids_s):
        assert out[b] == ref[a]
    assert len(out[ids_s[0]]) == 4             # stopped at EOS
    assert len(out[ids_s[1]]) == 4             # per-request budget honored


def test_spec_full_budget_draft_accepts_everything(setup):
    """spec_terms >= w_terms makes the draft the full model: every draft
    token verifies, acceptance is exactly 1.0, and every round yields
    lookahead+1 tokens (modulo the final partial round)."""
    cfg, params = setup
    eng = _engine(cfg, params, W4A16_T3, spec_terms=3, spec_lookahead=3)
    for p in _prompts(cfg, [6, 6]):
        eng.add_request(p)
    out = eng.run(max_new_tokens=8)
    st = eng.last_run_stats
    assert st["acceptance_rate"] == 1.0
    assert all(len(v) == 8 for v in out.values())
    assert st["spec_rounds"] == 2              # ceil(8 / (3+1)) lock-step


def test_spec_one_transfer_per_round(setup, monkeypatch):
    """One device_get per speculative round — the round transfer carries up
    to γ+1 tokens per slot, so speculation REDUCES host syncs per token."""
    cfg, params = setup
    eng = _engine(cfg, params, W4A16_T3, spec_terms=3, spec_lookahead=3)
    for p in _prompts(cfg, [6, 6]):
        eng.add_request(p)
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    eng.run(max_new_tokens=8)
    assert len(calls) == eng.last_run_stats["spec_rounds"] == 2


def test_spec_validation_errors(setup):
    """Construction-time preconditions: slots scheduler only, expanded
    params only, lookahead >= 1, ring-window headroom; greedy-only at run
    time (temperature is dynamic)."""
    cfg, params = setup
    with pytest.raises(ValueError, match="scheduler='slots'"):
        Engine(cfg, params, policy=W4A16_T3, serve_cfg=ServeConfig(
            scheduler="grouped", spec_terms=1))
    with pytest.raises(ValueError, match="ExpandedTensor"):
        Engine(cfg, params, serve_cfg=ServeConfig(spec_terms=1))  # FP params
    with pytest.raises(ValueError, match="spec_lookahead"):
        Engine(cfg, params, policy=W4A16_T3, serve_cfg=ServeConfig(
            spec_terms=1, spec_lookahead=0))
    rg = get_arch("recurrentgemma_9b", smoke=True)           # window 16
    rg_params = M.init_params(jax.random.PRNGKey(0), rg)
    with pytest.raises(ValueError, match="window"):
        Engine(rg, rg_params, policy=W4A16_T3, serve_cfg=ServeConfig(
            spec_terms=1, spec_lookahead=16))
    eng = _engine(cfg, params, W4A16_T3, spec_terms=1, temperature=0.7)
    eng.add_request([1, 2, 3])
    with pytest.raises(ValueError, match="greedy"):
        eng.run(max_new_tokens=4)


def test_spec_admission_charges_draft_cache_copy(setup):
    """HBM admission must charge each slot's cache TWICE in spec mode: the
    fused round drafts on a functional copy of the caches while the
    committed caches stay live for verify/commit — admitting by the
    1x-cache model would OOM the first speculative round on real HBM."""
    from repro.infer.kvcache import param_bytes, total_cache_bytes
    from repro.infer.scheduler import plan_slots

    cfg, params = setup
    pbytes = param_bytes(params)
    per_seq = total_cache_bytes(cfg, 1, 48)
    sc = ServeConfig(max_seq=48, max_batch=8,
                     hbm_budget_bytes=pbytes + 4.5 * per_seq)
    assert plan_slots(cfg, sc, params) == 4
    assert plan_slots(cfg, dataclasses.replace(sc, spec_terms=1), params) == 2


def test_runtime_applies_recipe_spec_intent(setup):
    """QuantRecipe.spec_terms is recorded intent: Runtime.serve applies it
    when the ServeConfig doesn't choose its own, same pattern as
    recipe.placement."""
    from repro.api import QuantRecipe, Runtime, quantize

    cfg, params = setup
    art = quantize(params, QuantRecipe(
        method="fpxint", policy=W4A16_T3, arch="qwen2_1_5b", smoke=True,
        spec_terms=1))
    eng = Runtime(art, backend="ref", cfg=cfg).serve(
        ServeConfig(max_seq=48, max_batch=2))
    assert eng.spec_enabled and eng.sc.spec_terms == 1
    # an explicit ServeConfig choice wins; grouped scheduler opts out
    eng2 = Runtime(art, backend="ref", cfg=cfg).serve(
        ServeConfig(max_seq=48, max_batch=2, scheduler="grouped"))
    assert not eng2.spec_enabled
    with pytest.raises(ValueError, match="term axis"):
        QuantRecipe(method="rtn", spec_terms=1)


# ---------------------------------------------------------------------------
# multi-device: term placement serves the same speculative stream
# ---------------------------------------------------------------------------
def test_spec_term_placement_token_identical_4dev():
    """placement="term" at 4 fake devices: the speculative engine emits the
    replicated non-speculative stream (the draft's term budget is realized
    by zero-masking scattered scales — the Abelian identity)."""
    import os
    import subprocess
    import sys
    import textwrap

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = textwrap.dedent("""
        import jax, numpy as np
        from repro.api import QuantRecipe, Runtime, quantize
        from repro.configs.base import get_arch
        from repro.core.policy import ExpansionPolicy
        from repro.dist.placement import make_serve_mesh
        from repro.infer.serve import ServeConfig
        from repro.models import model as M

        pol = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=3, a_terms=0)
        cfg = get_arch("qwen2_1_5b", smoke=True)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        art = quantize(params, QuantRecipe(method="fpxint", policy=pol,
                                           arch="qwen2_1_5b", smoke=True))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, l).tolist()
                   for l in (5, 9, 13)]
        outs = {}
        for placement, ndev, spec in [("replicated", 0, 0),
                                      ("term", 1, 1), ("term", 4, 0),
                                      ("term", 4, 1)]:
            mesh = (make_serve_mesh(ndev, "term") if placement == "term"
                    else None)
            eng = Runtime(art, backend="ref", cfg=cfg, mesh=mesh,
                          placement=placement).serve(ServeConfig(
                max_seq=48, max_batch=2, max_slots=2,
                spec_terms=spec, spec_lookahead=3))
            ids = [eng.add_request(p) for p in prompts]
            out = eng.run(max_new_tokens=6)
            outs[(placement, ndev, spec)] = [out[i] for i in ids]
            if spec:
                st = eng.last_run_stats
                assert 0.0 <= st["acceptance_rate"] <= 1.0
        base = outs[("replicated", 0, 0)]
        assert outs[("term", 4, 0)] == base, "term baseline diverged"
        assert outs[("term", 1, 1)] == base, "term@1 speculative diverged"
        assert outs[("term", 4, 1)] == base, "term@4 speculative diverged"
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["REPRO_NO_PALLAS"] = "1"
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, timeout=560, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout
