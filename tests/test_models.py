"""Per-arch smoke tests (assignment deliverable): reduced config of the same
family, one forward + one train step on CPU, asserting shapes + no NaNs;
plus prefill/decode consistency for every decoder arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.models import model as M
from repro.train.train_step import TrainConfig, make_train_step


def _batch(rng, cfg, b=2, s=32, labels=False):
    batch = {}
    if cfg.frame_dim:
        batch["frames"] = jnp.array(rng.normal(size=(b, s, cfg.frame_dim)).astype(np.float32))
    else:
        batch["tokens"] = jnp.array(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.num_image_tokens:
        batch["image_emb"] = jnp.array(
            rng.normal(size=(b, cfg.num_image_tokens, cfg.image_embed_dim)).astype(np.float32))
    if labels:
        batch["labels"] = jnp.array(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(rng, arch):
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    logits = M.forward(params, _batch(rng, cfg, b, s), cfg)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(rng, arch):
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt, step = make_train_step(cfg, TrainConfig(grad_accum=2, remat=True, lr=1e-3))
    opt_state = opt.init(params)
    batch = _batch(rng, cfg, b=4, s=16, labels=True)
    params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        jax.tree_util.tree_map(lambda a, b_: (a, b_), params, params2), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not get_arch(a, smoke=True).is_encoder])
def test_smoke_decode_consistency(rng, arch):
    """prefill + N decode steps reproduce the full forward logits."""
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s, extra, s_max = 2, 20, 3, 32
    batch = _batch(rng, cfg, b, s + extra)
    full = M.forward(params, batch, cfg)
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :s]
    lp, caches = M.prefill(params, pre_batch, cfg, s_max=s_max)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, s - 1]),
                               rtol=1e-3, atol=2e-4)
    clen = jnp.int32(s)
    for t in range(extra):
        ld, caches = M.decode_step(params, batch["tokens"][:, s+t:s+t+1], caches, clen, cfg)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(full[:, s + t]),
                                   rtol=1e-3, atol=2e-4)
        clen = clen + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_remat_does_not_change_loss(rng, arch):
    from repro.train.train_step import loss_fn
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(rng, cfg, b=2, s=16, labels=True)
    l1, _ = loss_fn(params, batch, cfg, remat=False)
    l2, _ = loss_fn(params, batch, cfg, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
