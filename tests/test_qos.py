"""Serving robustness layer (DESIGN.md §11): QoS tiers, load-adaptive
term-budget degradation, deadlines/backpressure, and the chaos harness.

Contracts tested here:

* ``quality="full"`` through a tiered engine is token-identical to the
  pre-QoS engine (grouped bit-exactness baseline, batch 1);
* a degraded tier is bit-identical to an engine statically built on the
  truncated context (``ServeConfig(term_budget=k)``) — Theorem 1's prefix
  coherence served live, for the attn and recurrent arch classes;
* mixed-tier pools serve every request, leak no slots, and report per-tier
  metrics (nominal vs effective terms, degraded-step fraction);
* deadlines cancel queued and mid-run requests and recycle their slots, in
  BOTH plain and speculative modes; validation failures leave the queue
  intact in both modes;
* backpressure is typed (``Rejection``: CAPACITY retryable,
  DEADLINE_INFEASIBLE not) and ``submit_with_backoff`` honors it with
  bounded sleeps;
* chaos injection (latency spikes, transient failures, HBM squeezes) is
  seeded-deterministic, never hangs, never leaks slots; with degradation
  off the chaotic token streams are bit-identical to a calm run, and with
  degradation on a squeeze degrades (instead of rejecting) then recovers;
* rate metrics are finite at zero/near-zero durations.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.api import QuantRecipe, quantize
from repro.core.policy import ExpansionPolicy
from repro.infer import qos as Q
from repro.infer.scheduler import Request, SlotScheduler
from repro.infer.serve import Engine, ServeConfig
from repro.launch.common import submit_with_backoff
from repro.models import model as M

# weight-only with THREE weight terms: k=1/2 are genuine truncations
W4A16_T3 = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=3, a_terms=0)

TIERS = (("k2", 2), ("k1", 1))
NO_DEGRADE = Q.DegradeConfig(enabled=False)


def _artifact(arch):
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, quantize(params, QuantRecipe(method="fpxint",
                                             policy=W4A16_T3))


@pytest.fixture(scope="module")
def setup():
    return _artifact("qwen2_1_5b")


def _prompts(cfg, lengths, seed=0):
    r = np.random.default_rng(seed)
    return [r.integers(0, cfg.vocab_size, l).tolist() for l in lengths]


def _tiered_cfg(**kw):
    base = dict(max_seq=48, max_slots=2, tier_budgets=TIERS,
                degrade=NO_DEGRADE)
    base.update(kw)
    return ServeConfig(**base)


# ---------------------------------------------------------------------------
# exactness: full tier == pre-QoS engine; degraded tier == static truncation
# ---------------------------------------------------------------------------
def test_full_tier_token_identical_to_pre_qos(setup):
    """quality='full' through a tiered engine reproduces the grouped
    bit-exactness baseline per request — the QoS layer is a no-op for the
    full tier."""
    cfg, art = setup
    prompts = _prompts(cfg, [5, 9, 13, 7])
    eng = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg())
    ids = [eng.add_request(p) for p in prompts]
    out = eng.run(max_new_tokens=6)
    for rid, p in zip(ids, prompts):
        ref = Engine(cfg, artifact=art, serve_cfg=ServeConfig(
            max_seq=48, max_batch=1, scheduler="grouped"))
        rr = ref.add_request(p)
        assert out[rid] == ref.run(max_new_tokens=6)[rr]


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "recurrentgemma_9b"])
@pytest.mark.parametrize("k", [2, 1])
def test_degraded_tier_bit_identical_to_static_truncation(arch, k):
    """A k-term tier's stream is bit-identical to an engine statically
    truncated to k terms (ServeConfig(term_budget=k)) — for a full-attn
    arch and a local-ring+rglru recurrent arch."""
    cfg, art = _artifact(arch)
    prompts = _prompts(cfg, [6, 10, 8])
    tiered = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg())
    ids = [tiered.add_request(p, quality=f"k{k}") for p in prompts]
    out = tiered.run(max_new_tokens=5)
    static = Engine(cfg, artifact=art, serve_cfg=ServeConfig(
        max_seq=48, max_slots=2, term_budget=k, degrade=NO_DEGRADE))
    sids = [static.add_request(p) for p in prompts]
    sout = static.run(max_new_tokens=5)
    for rid, sid in zip(ids, sids):
        assert out[rid] == sout[sid]


def test_mixed_tiers_served_with_per_tier_metrics(setup):
    """A mixed full/k2/k1 pool serves every request to its budget, leaks
    nothing, and reports per-tier nominal vs effective terms."""
    cfg, art = setup
    prompts = _prompts(cfg, [5, 9, 13, 9, 3, 7])
    eng = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg(max_slots=3))
    names = ["full", "k2", "k1"]
    ids = [eng.add_request(p, quality=names[i % 3])
           for i, p in enumerate(prompts)]
    out = eng.run(max_new_tokens=5)
    assert set(out) == set(ids)
    assert all(len(v) == 5 for v in out.values())
    st = eng.last_run_stats
    assert st["slots_leaked"] == 0 and st["queue_leftover"] == 0
    tiers = st["tiers"]
    assert set(tiers) == {"full", "k2", "k1"}
    assert tiers["full"]["nominal_terms"] == 3
    assert tiers["k2"]["nominal_terms"] == 2
    assert tiers["k1"]["nominal_terms"] == 1
    for name in names:    # degradation off: effective == nominal
        assert tiers[name]["mean_effective_terms"] == \
            pytest.approx(tiers[name]["nominal_terms"])
        assert tiers[name]["degraded_step_fraction"] == 0.0
        assert tiers[name]["served_tokens"] == 2 * 5
    # mixed budgets need one dispatch per distinct budget per step
    assert st["dispatches"] > st["decode_steps"]


def test_single_tier_workload_one_dispatch_per_step(setup):
    """An all-'full' workload collapses to one dispatch per decode step —
    the tier machinery costs nothing when unused."""
    cfg, art = setup
    eng = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg())
    for p in _prompts(cfg, [6, 6]):
        eng.add_request(p)
    eng.run(max_new_tokens=4)
    st = eng.last_run_stats
    assert st["dispatches"] == st["decode_steps"]


# ---------------------------------------------------------------------------
# deadlines / cancellation / queue integrity — plain AND speculative modes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec_terms", [0, 2])
def test_deadline_cancels_and_recycles(setup, spec_terms):
    """An expired deadline cancels the request (queued or mid-run), frees
    its slot for remaining work, and reports deadline metrics — on both the
    plain and the speculative scheduler."""
    cfg, art = setup
    sc = ServeConfig(max_seq=48, max_slots=1, spec_terms=spec_terms,
                     degrade=NO_DEGRADE)
    eng = Engine(cfg, artifact=art, serve_cfg=sc)
    p1, p2 = _prompts(cfg, [8, 8])
    rid_dead = eng.add_request(p1, deadline_s=1e-6)   # expires immediately
    rid_ok = eng.add_request(p2)
    out = eng.run(max_new_tokens=4)
    assert out[rid_dead] == []           # cancelled before its first token
    assert len(out[rid_ok]) == 4
    m = eng.last_request_metrics
    assert m[rid_dead]["status"] == "cancelled"
    assert m[rid_dead]["deadline_missed"] is True
    assert m[rid_ok]["status"] == "ok"
    st = eng.last_run_stats
    assert st["cancelled"] == 1
    assert st["slots_leaked"] == 0 and st["queue_leftover"] == 0
    ts = st["tiers"]["full"]
    assert ts["deadline_total"] == 1 and ts["deadline_hits"] == 0


@pytest.mark.parametrize("spec_terms", [0, 2])
def test_validation_failure_leaves_queue_intact(setup, spec_terms):
    """A run() whose run-level budget overflows max_seq raises BEFORE any
    work and leaves the queue intact; a corrected retry then serves every
    queued request — on both scheduler modes."""
    cfg, art = setup
    eng = Engine(cfg, artifact=art, serve_cfg=ServeConfig(
        max_seq=24, max_slots=2, spec_terms=spec_terms, degrade=NO_DEGRADE))
    ids = [eng.add_request(p) for p in _prompts(cfg, [8, 10])]
    with pytest.raises(ValueError, match="max_seq"):
        eng.run(max_new_tokens=20)
    assert [r.rid for r in eng._queue] == ids     # untouched
    out = eng.run(max_new_tokens=4)
    assert set(out) == set(ids)
    assert all(len(v) == 4 for v in out.values())
    assert eng.last_run_stats["slots_leaked"] == 0


# ---------------------------------------------------------------------------
# typed backpressure + retry helper
# ---------------------------------------------------------------------------
def test_capacity_rejection_and_retry_helper(setup):
    cfg, art = setup
    eng = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg(max_queue=2))
    p = _prompts(cfg, [6])[0]
    assert isinstance(eng.add_request(p), int)
    assert isinstance(eng.add_request(p), int)
    rej = eng.add_request(p)
    assert isinstance(rej, Q.Rejection)
    assert rej.reason is Q.RejectReason.CAPACITY and rej.retryable
    assert rej.retry_after_s > 0
    # bounded backoff: saturated queue -> sleeps between attempts, then the
    # last Rejection is returned (not raised)
    sleeps = []
    res = submit_with_backoff(eng, p, max_attempts=3, max_delay_s=0.2,
                              sleep=sleeps.append)
    assert isinstance(res, Q.Rejection)
    assert len(sleeps) == 2 and all(0 < s <= 0.2 for s in sleeps)
    assert sleeps[1] > sleeps[0]          # exponential (below the cap)
    # draining the queue makes room; the helper then succeeds, no sleeps
    eng.run(max_new_tokens=2)
    sleeps.clear()
    assert isinstance(submit_with_backoff(eng, p, sleep=sleeps.append), int)
    assert sleeps == []


def test_infeasible_deadline_not_retryable(setup):
    cfg, art = setup
    eng = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg())
    p = _prompts(cfg, [6])[0]
    rej = eng.add_request(p, deadline_s=-1.0)
    assert isinstance(rej, Q.Rejection)
    assert rej.reason is Q.RejectReason.DEADLINE_INFEASIBLE
    assert not rej.retryable
    # the helper returns it immediately — no pointless retries
    sleeps = []
    res = submit_with_backoff(eng, p, deadline_s=-1.0, sleep=sleeps.append)
    assert res.reason is Q.RejectReason.DEADLINE_INFEASIBLE
    assert sleeps == []
    assert eng._queue == []               # nothing was enqueued


# ---------------------------------------------------------------------------
# chaos harness: determinism, identity, degradation + recovery, no leaks
# ---------------------------------------------------------------------------
def _chaos_cfg(**kw):
    return Q.ChaosConfig(seed=7, latency_s=0.002, **kw)


def test_chaos_latency_and_failures_token_identical(setup):
    """With degradation off, a run under injected latency spikes and
    transient dispatch failures emits bit-identical tokens to a calm run
    (injection happens strictly before each dispatch, so retries re-issue
    the identical computation), and the same seed reproduces the same
    fault schedule."""
    cfg, art = setup
    prompts = _prompts(cfg, [5, 9, 7, 11])

    def run_engine(chaos):
        eng = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg(chaos=chaos))
        ids = [eng.add_request(p, quality=q) for p, q in
               zip(prompts, ["full", "k2", "k1", "full"])]
        out = eng.run(max_new_tokens=5)
        return [out[r] for r in ids], eng.last_run_stats

    calm, _ = run_engine(None)
    chaotic1, st1 = run_engine(_chaos_cfg(latency_p=0.4, fail_p=0.3, max_retries=8))
    chaotic2, st2 = run_engine(_chaos_cfg(latency_p=0.4, fail_p=0.3, max_retries=8))
    assert chaotic1 == calm
    assert chaotic2 == chaotic1                      # seeded-deterministic
    assert st1["chaos"]["failures_injected"] > 0
    assert st1["chaos"]["failures_injected"] == st2["chaos"]["failures_injected"]
    assert st1["dispatch_retries"] > 0
    assert st1["chaos"]["latency_injected"] > 0
    assert st1["watchdog"]["stalled_rounds"] > 0     # spikes were flagged
    assert st1["slots_leaked"] == 0 and st1["queue_leftover"] == 0


def test_chaos_hbm_squeeze_degrades_then_recovers(setup):
    """An HBM squeeze makes the controller degrade degradable tiers
    (serving their floor budget) instead of rejecting; when the window
    passes, nominal budgets are restored, every request completes, and no
    slot leaks."""
    cfg, art = setup
    chaos = _chaos_cfg(hbm_squeeze_start=2, hbm_squeeze_steps=4,
                       hbm_squeeze_frac=0.4)
    eng = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg(
        max_slots=2, chaos=chaos, degrade=Q.DegradeConfig()))
    prompts = _prompts(cfg, [6, 8, 10, 6])
    ids = [eng.add_request(p, quality="k2") for p in prompts]
    out = eng.run(max_new_tokens=6)
    assert set(out) == set(ids)
    assert all(len(v) == 6 for v in out.values())    # degraded, not shed
    st = eng.last_run_stats
    assert st["usable_slots_min"] < st["n_slots"]    # the squeeze bit
    assert st["qos"]["degraded_rounds"] > 0
    assert st["qos"]["degrade_transitions"] >= 1
    assert not st["qos"]["degraded_now"]             # recovered by the end
    ts = st["tiers"]["k2"]
    assert ts["degraded_step_fraction"] > 0.0
    assert 1.0 <= ts["mean_effective_terms"] < 2.0   # floor < mean < nominal
    assert st["slots_leaked"] == 0 and st["queue_leftover"] == 0


def test_chaos_retry_exhaustion_raises(setup):
    """fail_p=1 exhausts max_retries: the ChaosFailure surfaces instead of
    hanging, and the queue/slot invariants still hold afterwards."""
    cfg, art = setup
    eng = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg(
        chaos=Q.ChaosConfig(seed=0, fail_p=1.0, max_retries=2)))
    eng.add_request(_prompts(cfg, [6])[0])
    with pytest.raises(Q.ChaosFailure):
        eng.run(max_new_tokens=3)


# ---------------------------------------------------------------------------
# priority + metrics hygiene
# ---------------------------------------------------------------------------
def test_priority_admission_order(setup):
    """Higher priority admits first (FCFS within a level): on a 1-slot
    pool the priority-5 request reaches its first token before the
    priority-0 one enqueued earlier."""
    cfg, art = setup
    reqs = [Request(rid=0, tokens=[1], priority=0),
            Request(rid=1, tokens=[1], priority=5),
            Request(rid=2, tokens=[1], priority=0)]
    assert [r.rid for r in SlotScheduler._order(reqs)] == [1, 0, 2]
    eng = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg(max_slots=1))
    p1, p2 = _prompts(cfg, [6, 6])
    rid_lo = eng.add_request(p1, priority=0)
    rid_hi = eng.add_request(p2, priority=5)
    eng.run(max_new_tokens=3)
    m = eng.last_request_metrics
    assert m[rid_hi]["ttft_s"] < m[rid_lo]["ttft_s"]


def test_zero_duration_metrics_are_finite():
    """safe_rate and the derived request metrics return 0.0 (never
    inf/NaN) at zero/near-zero durations — tiny CI runs stay JSON-safe."""
    assert Q.safe_rate(5, 0.0) == 0.0
    assert Q.safe_rate(5, -1.0) == 0.0
    assert Q.safe_rate(3, 2.0) == pytest.approx(1.5)
    r = Request(rid=0, tokens=[1, 2])
    r.t_admitted = r.t_done = 5.0
    r.new_tokens = 4
    assert r.tokens_per_sec == 0.0        # zero-duration run
    assert r.ttft_seconds == 0.0          # never produced a token
    assert r.deadline_missed is None      # no deadline attached
    m = r.metrics()
    assert m["tokens_per_sec"] == 0.0 and "deadline_missed" not in m


# ---------------------------------------------------------------------------
# validation: the QoS knobs reject unserveable configurations up front
# ---------------------------------------------------------------------------
def test_qos_validation_errors(setup):
    cfg, art = setup
    fp_params = M.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="slots"):
        Engine(cfg, artifact=art, serve_cfg=ServeConfig(
            scheduler="grouped", tier_budgets=TIERS))
    with pytest.raises(ValueError, match="exclusive"):
        Engine(cfg, artifact=art, serve_cfg=ServeConfig(
            spec_terms=2, tier_budgets=TIERS))
    with pytest.raises(ValueError, match="ExpandedTensor"):
        Engine(cfg, fp_params, serve_cfg=ServeConfig(tier_budgets=TIERS))
    with pytest.raises(ValueError, match="ExpandedTensor"):
        Engine(cfg, fp_params, serve_cfg=ServeConfig(term_budget=2))
    with pytest.raises(ValueError, match="max_queue"):
        Engine(cfg, artifact=art, serve_cfg=ServeConfig(max_queue=-1))
    # FP engine serves quality='full' only; unknown tiers are programmer
    # errors (raised), not load conditions (Rejection)
    eng_fp = Engine(cfg, fp_params, serve_cfg=ServeConfig(max_seq=48))
    assert sorted(eng_fp.tiers) == ["full"]
    with pytest.raises(ValueError, match="quality"):
        eng_fp.add_request([1, 2, 3], quality="k2")
    eng = Engine(cfg, artifact=art, serve_cfg=_tiered_cfg())
    with pytest.raises(ValueError, match="quality"):
        eng.add_request([1, 2, 3], quality="k9")
    with pytest.raises(ValueError, match="slots"):
        Engine(cfg, artifact=art, serve_cfg=ServeConfig(
            scheduler="grouped", max_batch=1)).add_request(
                [1, 2, 3], deadline_s=1.0)
