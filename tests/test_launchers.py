"""CLI launchers: train.py (incl. crash/restart + compression) and serve.py."""
import numpy as np
import pytest

from repro.launch import serve as serve_cli
from repro.launch import train as train_cli


def test_train_cli_runs_and_restarts(tmp_path):
    args = ["--arch", "qwen2_1_5b", "--smoke", "--seq", "32", "--batch", "4",
            "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "2"]
    # first life: stop after 3 of 6 steps
    train_cli.main(args + ["--steps", "6", "--max-steps-this-life", "3"])
    # second life: restores and finishes
    state = train_cli.main(args + ["--steps", "6"])
    assert state is not None
    from repro.dist import checkpoint as CKPT
    assert CKPT.latest_step(str(tmp_path / "ck")) == 5


def test_train_cli_with_compression(tmp_path):
    state = train_cli.main([
        "--arch", "qwen2_1_5b", "--smoke", "--steps", "3", "--seq", "32",
        "--batch", "4", "--compress-grads", "--ckpt-dir", str(tmp_path / "ck2")])
    assert state is not None
    # compressed path carries the error-feedback buffer in the opt state
    assert "err" in state["opt"]


def test_train_cli_grad_accum(tmp_path):
    train_cli.main(["--arch", "mamba2_780m", "--smoke", "--steps", "2",
                    "--seq", "32", "--batch", "4", "--grad-accum", "2",
                    "--remat", "--ckpt-dir", str(tmp_path / "ck3")])


def test_serve_cli(capsys):
    out = serve_cli.main(["--arch", "qwen2_1_5b", "--smoke", "--requests", "3",
                          "--prompt-len", "8", "--max-new", "4", "--max-seq", "32"])
    assert len(out) == 3
    assert all(len(v) == 4 for v in out.values())
    text = capsys.readouterr().out
    assert "quantization time" in text


def test_serve_cli_fp(capsys):
    out = serve_cli.main(["--arch", "recurrentgemma_9b", "--smoke", "--fp",
                          "--requests", "2", "--prompt-len", "8", "--max-new",
                          "3", "--max-seq", "32"])
    assert len(out) == 2
