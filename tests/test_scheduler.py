"""Slot-based continuous batching: per-slot cache lengths, padded
prefill-into-slot, admission control, slot recycling, and the
engine-level exactness/metrics contracts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.infer.kvcache import max_batch_for_hbm, param_bytes, total_cache_bytes
from repro.infer.scheduler import SlotScheduler, bucket_length, plan_slots
from repro.infer.serve import Engine, ServeConfig
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lengths, seed=0):
    r = np.random.default_rng(seed)
    return [r.integers(0, cfg.vocab_size, length).tolist() for length in lengths]


def _single_reference(cfg, params, prompt, max_new):
    """Per-request reference decoding: legacy grouped engine, batch 1."""
    e = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_batch=1, scheduler="grouped"))
    rid = e.add_request(prompt)
    return e.run(max_new_tokens=max_new)[rid]


# ---------------------------------------------------------------------------
# model layer: padded prefill + scatter-into-slot + per-slot decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2_1_5b", "recurrentgemma_9b", "mamba2_780m"])
def test_padded_prefill_matches_unpadded(rng, arch):
    """Right-padded prefill with a length mask reproduces the per-request
    unpadded prefill: logits at the last valid position, and caches that
    decode identically — for full-attn, local-ring+rglru, and ssm archs."""
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    s_max, lens, padded_len = 32, [5, 9, 13], 16
    toks = [rng.integers(0, cfg.vocab_size, l) for l in lens]
    refs = [M.prefill(params, {"tokens": jnp.asarray(t[None], jnp.int32)},
                      cfg, s_max=s_max) for t in toks]
    pad = np.zeros((len(lens), padded_len), np.int32)
    for i, t in enumerate(toks):
        pad[i, :len(t)] = t
    lp, caches = M.prefill(params, {"tokens": jnp.asarray(pad)}, cfg,
                           s_max=s_max, lengths=jnp.asarray(lens, jnp.int32))
    for i, (rl, _) in enumerate(refs):
        np.testing.assert_allclose(np.asarray(lp[i]), np.asarray(rl[0]),
                                   rtol=1e-4, atol=1e-5)
    # scatter the padded-prefill caches into a live cache and decode per-slot
    live = M.init_cache(cfg, len(lens), s_max)

    def row(tree, i):
        """Slice row i of the padded batch cache as a batch-1 cache."""
        def f(path, leaf):
            # stage leaves: (L, B, ...) -> batch axis 1; tail leaves: axis 0
            names = [str(getattr(p, "key", "")) for p in path]
            axis = 1 if names and names[0] == "stages" else 0
            return jax.lax.slice_in_dim(leaf, i, i + 1, axis=axis)
        return jax.tree_util.tree_map_with_path(f, tree)
    for i in range(len(lens)):
        live = M.scatter_cache_into_slot(live, row(caches, i), i)
    nxt = jnp.argmax(lp, axis=-1)[:, None].astype(jnp.int32)
    ld, _ = M.decode_step(params, nxt, live, jnp.asarray(lens, jnp.int32), cfg)
    for i, (rl, c1) in enumerate(refs):
        n1 = jnp.argmax(rl, axis=-1)[:, None].astype(jnp.int32)
        ld1, _ = M.decode_step(params, n1, c1, jnp.int32(lens[i]), cfg)
        np.testing.assert_allclose(np.asarray(ld[i]), np.asarray(ld1[0]),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "recurrentgemma_9b"])
def test_vector_cache_len_matches_scalar(rng, arch):
    """decode_step with a constant (B,) cache_len vector is the scalar path."""
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s, s_max = 2, 12, 24
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)), jnp.int32)
    _, c1 = M.prefill(params, {"tokens": tokens[:, :s]}, cfg, s_max=s_max)
    _, c2 = M.prefill(params, {"tokens": tokens[:, :s]}, cfg, s_max=s_max)
    l_sc, _ = M.decode_step(params, tokens[:, s:], c1, jnp.int32(s), cfg)
    l_vec, _ = M.decode_step(params, tokens[:, s:], c2,
                             jnp.full((b,), s, jnp.int32), cfg)
    np.testing.assert_array_equal(np.asarray(l_sc), np.asarray(l_vec))


# ---------------------------------------------------------------------------
# engine: continuous batching exactness + recycling
# ---------------------------------------------------------------------------
def test_slots_mixed_lengths_token_identical(setup):
    """Mixed-length prompts (>=3 distinct lengths) on a 2-slot pool are
    token-identical to per-request reference decoding — the acceptance
    contract for padded prefill-into-slot + per-slot decode."""
    cfg, params = setup
    prompts = _prompts(cfg, [5, 9, 13, 9, 3, 7])
    eng = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_batch=8, max_slots=2))
    ids = [eng.add_request(p) for p in prompts]
    out = eng.run(max_new_tokens=6)
    assert set(out) == set(ids)
    for rid, p in zip(ids, prompts):
        assert out[rid] == _single_reference(cfg, params, p, 6)


def test_eos_frees_slot_for_queued_request(setup):
    """EOS mid-stream frees a slot that a queued request then reuses."""
    cfg, params = setup
    prompts = _prompts(cfg, [8, 10])
    first = _single_reference(cfg, params, prompts[0], 1)[0]
    eng = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_batch=8, max_slots=1, eos_id=first))
    a = eng.add_request(prompts[0])
    b = eng.add_request(prompts[1])
    out = eng.run(max_new_tokens=6)
    assert out[a] == [first]                 # stopped at EOS, slot freed
    ref_b = _single_reference(cfg, params, prompts[1], 6)
    stop = ref_b.index(first) + 1 if first in ref_b else len(ref_b)
    assert out[b] == ref_b[:stop]            # recycled slot decodes correctly
    st = eng.last_run_stats
    assert st["n_slots"] == 1 and st["requests"] == 2


@pytest.mark.parametrize("scheduler", ["slots", "grouped"])
def test_per_request_max_new_tokens(setup, scheduler):
    """Per-request budgets are honored by BOTH schedulers (the grouped path
    caps each request inside the drained group)."""
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_batch=2, scheduler=scheduler))
    a = eng.add_request(_prompts(cfg, [8])[0], max_new_tokens=3)
    b = eng.add_request(_prompts(cfg, [8], seed=1)[0])
    out = eng.run(max_new_tokens=7)
    assert len(out[a]) == 3 and len(out[b]) == 7
    assert out[a] == _single_reference(cfg, params, _prompts(cfg, [8])[0], 3)


def test_validation_error_leaves_queue_intact(setup):
    """A run-level budget overflow raises BEFORE any work and keeps the
    queue, so the caller can retry with a smaller budget."""
    cfg, params = setup
    for scheduler in ("slots", "grouped"):
        eng = Engine(cfg, params, serve_cfg=ServeConfig(
            max_seq=16, max_batch=2, scheduler=scheduler))
        eng.add_request([1, 2, 3])
        eng.add_request(list(range(14)))
        with pytest.raises(ValueError, match="max_seq"):
            eng.run(max_new_tokens=8)
        out = eng.run(max_new_tokens=2)          # retry serves both requests
        assert len(out) == 2 and all(len(v) == 2 for v in out.values())
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.run(max_new_tokens=0)


def test_grouped_legacy_stays_available_and_exact(setup):
    """scheduler="grouped" keeps the seed engine's group-drain semantics:
    equal-length batching is token-identical to per-request runs AND to the
    slots scheduler (greedy)."""
    cfg, params = setup
    prompts = _prompts(cfg, [8, 8, 12, 12])
    grouped = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_batch=4, scheduler="grouped"))
    slots = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_batch=4, scheduler="slots"))
    ids_g = [grouped.add_request(p) for p in prompts]
    ids_s = [slots.add_request(p) for p in prompts]
    out_g, out_s = grouped.run(max_new_tokens=5), slots.run(max_new_tokens=5)
    for g, s_, p in zip(ids_g, ids_s, prompts):
        ref = _single_reference(cfg, params, p, 5)
        assert out_g[g] == ref
        assert out_s[s_] == ref


# ---------------------------------------------------------------------------
# satellites: validation, no-retrace temperature, PRNG per prefill
# ---------------------------------------------------------------------------
def test_add_request_validates_capacity(setup):
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=32, max_batch=2))
    with pytest.raises(ValueError, match="max_seq"):
        eng.add_request(list(range(40)))                     # prompt too long
    with pytest.raises(ValueError, match="max_seq"):
        eng.add_request(list(range(20)), max_new_tokens=20)  # budget too big
    with pytest.raises(ValueError):
        eng.add_request([])                                  # empty prompt
    rid = eng.add_request(list(range(20)))                   # fits with 1 token
    with pytest.raises(ValueError, match=str(rid)):
        eng.run(max_new_tokens=16)          # run-level budget overflows at run
    # grouped path raises too (the seed engine had a bare assert)
    eng2 = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=32, max_batch=2, scheduler="grouped"))
    eng2.add_request(list(range(20)))
    with pytest.raises(ValueError, match="max_seq"):
        eng2.run(max_new_tokens=16)


def test_temperature_is_dynamic_no_retrace(setup):
    """Changing temperature (and eos) must not retrace the fused decode
    step: both are dynamic operands now (the seed passed temperature via
    static_argnames, recompiling per setting)."""
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=32, max_batch=2))
    p = _prompts(cfg, [6])[0]
    eng.add_request(p)
    eng.run(max_new_tokens=4)
    eng.sc = dataclasses.replace(eng.sc, temperature=0.8, eos_id=3)
    eng.add_request(p)
    eng.run(max_new_tokens=4)
    assert eng._decode._cache_size() == 1


def test_prng_split_per_prefill(setup):
    """The seed engine reused PRNGKey(seed) unsplit for the first sampled
    token of every group; identical prompts in different groups sampled
    identical outputs.  Now the key is split per prefill, so two runs of the
    same sampled request inside one engine-run differ across groups."""
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_batch=1, temperature=1.0, scheduler="grouped"))
    p = _prompts(cfg, [8])[0]
    a = eng.add_request(p)
    b = eng.add_request(p)   # same prompt, same length -> two batch-1 groups
    out = eng.run(max_new_tokens=8)
    assert out[a] != out[b]


# ---------------------------------------------------------------------------
# admission control + metrics
# ---------------------------------------------------------------------------
def test_hbm_budget_caps_slots(setup):
    cfg, params = setup
    pbytes = param_bytes(params)
    per_seq = total_cache_bytes(cfg, 1, 48)
    sc = ServeConfig(max_seq=48, max_batch=8,
                     hbm_budget_bytes=pbytes + 2.5 * per_seq)
    assert plan_slots(cfg, sc, params) == 2
    eng = Engine(cfg, params, serve_cfg=sc)
    for p in _prompts(cfg, [6, 6, 6]):
        eng.add_request(p)
    out = eng.run(max_new_tokens=3)
    assert len(out) == 3 and eng.last_run_stats["n_slots"] == 2
    # a budget that cannot fit even one sequence is rejected
    with pytest.raises(ValueError, match="hbm_budget"):
        plan_slots(cfg, ServeConfig(max_seq=48, hbm_budget_bytes=1.0), params)
    assert max_batch_for_hbm(cfg, 48, pbytes, pbytes) == 0


def test_request_metrics_and_occupancy(setup):
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_batch=8, max_slots=2))
    ids = [eng.add_request(p) for p in _prompts(cfg, [5, 9, 13, 7])]
    out = eng.run(max_new_tokens=4)
    st = eng.last_run_stats
    assert st["generated_tokens"] == sum(len(v) for v in out.values()) == 16
    assert 0.0 < st["occupancy"] <= 1.0
    assert st["decode_steps"] > 0 and st["decode_tokens_per_sec"] > 0
    for rid in ids:
        m = eng.last_request_metrics[rid]
        assert m["new_tokens"] == 4
        assert m["ttft_s"] > 0 and m["tokens_per_sec"] > 0


def test_one_transfer_per_step_with_recycling(setup, monkeypatch):
    """The one-device_get-per-loop-iteration contract survives continuous
    batching: admissions (prefill, scatter, first-token sampling) stay
    device-side even when slots are recycled mid-stream.

    ``decode_steps`` counts decode DISPATCHES only — drain iterations (the
    fetch that emits a wave's final pending tokens and dispatches nothing)
    transfer but don't decode.  This workload is two full waves (4 requests
    on 2 slots, 4 tokens each): 3 dispatches + 1 drain per wave."""
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_batch=8, max_slots=2))
    for p in _prompts(cfg, [5, 9, 13, 7]):
        eng.add_request(p)
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    eng.run(max_new_tokens=4)
    assert eng.last_run_stats["decode_steps"] == 6
    assert len(calls) == 8  # 6 decode dispatches + 2 drain fetches


def test_decode_steps_count_dispatches_only(setup):
    """Regression (accounting): ``decode_steps`` must equal the number of
    decode DISPATCHES.  The old loop bumped the counters at the top of every
    iteration, so the final drain (fetch + emit, no decode) overstated
    decode_steps by one per drain and understated occupancy."""
    cfg, params = setup
    for scheduler in ("slots", "grouped"):
        eng = Engine(cfg, params, serve_cfg=ServeConfig(
            max_seq=48, max_batch=2, max_slots=2, scheduler=scheduler))
        for p in _prompts(cfg, [8, 8]):
            eng.add_request(p)
        dispatches = []
        real = eng._decode
        eng._decode = lambda *a, **k: dispatches.append(1) or real(*a, **k)
        eng.run(max_new_tokens=4)
        st = eng.last_run_stats
        # 2 requests in lock-step on 2 slots: first token comes from prefill,
        # the remaining 3 from 3 decode dispatches; the 4th fetch drains
        assert st["decode_steps"] == len(dispatches) == 3, scheduler
        # both slots alive at every dispatch -> full occupancy (the old
        # accounting diluted this with the dispatch-free drain iteration)
        assert st["occupancy"] == pytest.approx(1.0), scheduler
        assert st["generated_tokens"] == 8


def test_zero_budget_rejected_on_both_paths(setup):
    """Regression (contract): an effective ``max_new_tokens=0`` used to slip
    through scheduler-level runs and still emit 1 token (the prefill-sampled
    token was appended before the budget check).  The contract is reject-
    at-validation, enforced by add_request, Engine.run AND both scheduler
    paths (requests handed to the scheduler directly, bypassing
    add_request's check)."""
    cfg, params = setup
    from repro.infer.scheduler import Request
    with pytest.raises(ValueError, match="max_new_tokens"):
        Engine(cfg, params, serve_cfg=ServeConfig(max_seq=48)) \
            .add_request([1, 2, 3], max_new_tokens=0)
    for scheduler in ("slots", "grouped"):
        eng = Engine(cfg, params, serve_cfg=ServeConfig(
            max_seq=48, max_batch=2, scheduler=scheduler))
        eng._queue.append(Request(rid=0, tokens=[1, 2, 3], max_new_tokens=0))
        with pytest.raises(ValueError, match=">= 1"):
            eng.run(max_new_tokens=4)
        # run-level zero is rejected up front too (queue left intact)
        eng2 = Engine(cfg, params, serve_cfg=ServeConfig(
            max_seq=48, max_batch=2, scheduler=scheduler))
        eng2.add_request([1, 2, 3])
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng2.run(max_new_tokens=0)
        assert len(eng2._queue) == 1


def test_bucket_length():
    assert bucket_length(5, 16, 64) == 16
    assert bucket_length(16, 16, 64) == 16
    assert bucket_length(17, 16, 64) == 32
    assert bucket_length(60, 16, 64) == 64     # capped at capacity
    assert bucket_length(3, 1, 64) == 3
