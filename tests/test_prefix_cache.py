"""Chunked prefill + shared-prefix (radix) caching (DESIGN.md §14).

Three layers, all mandatory:

* **allocator/trie property suite** — hypothesis-driven op sequences over
  :class:`repro.infer.kvcache.PageAllocator` and
  :class:`repro.infer.kvcache.PrefixCache`: no double-free, no leak, and
  the conservation law ``free_pages + |{ref > 0}| == num_pages`` holds at
  every step (deterministic sweeps cover the same invariants when
  hypothesis is absent);
* **token-identity matrix** — subprocess engine runs (test_dist_serving's
  isolation idiom) assert chunked / prefix-cached serving is TOKEN-
  IDENTICAL to monolithic uncached prefill across the attn, local+rglru
  and ssm arch classes, plain / speculative / QoS-tiered, cold and warm.
  Identity cases pin FP or weight-only (W4A16) policies: per-batch dynamic
  activation quantization (a_terms > 0) makes activation scales a function
  of the whole dispatched tensor, so chunked-vs-monolithic bit-identity is
  undefined there by construction (DESIGN.md §14);
* **bucket-pad regression** — a prompt whose bucket-padded tail overhangs
  its true length must not prefill pad rows into shared (increfed) prefix
  pages: a warm sharer of those pages still decodes identically.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.infer.kvcache import PageAllocator, PrefixCache

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ===========================================================================
# PageAllocator: refcounted sharing
# ===========================================================================
def _conserved(alloc):
    """The conservation law: every page is exactly one of free / referenced."""
    live = int(np.count_nonzero([alloc.refcount(p) >= 1
                                 for p in range(alloc.num_pages)]))
    assert alloc.free_pages + live == alloc.num_pages
    alloc.check()


def test_alloc_free_roundtrip():
    a = PageAllocator(8)
    pages = a.alloc(5)
    assert len(pages) == 5 and a.pages_in_use == 5
    assert all(a.refcount(p) == 1 for p in pages)
    _conserved(a)
    a.free(pages)
    assert a.pages_in_use == 0 and a.free_pages == 8
    _conserved(a)


def test_alloc_all_or_nothing():
    a = PageAllocator(4)
    assert a.alloc(5) is None          # over-ask: nothing allocated
    assert a.pages_in_use == 0
    got = a.alloc(4)
    assert a.alloc(1) is None and len(got) == 4
    _conserved(a)


def test_incref_shares_and_free_releases_once():
    a = PageAllocator(4)
    pages = a.alloc(2)
    a.incref(pages)                    # second sharer
    a.free(pages)                      # first sharer releases
    assert a.pages_in_use == 2         # still held
    _conserved(a)
    a.free(pages)                      # last reference
    assert a.pages_in_use == 0
    _conserved(a)


def test_double_free_and_foreign_ops_raise():
    a = PageAllocator(4)
    pages = a.alloc(1)
    a.free(pages)
    with pytest.raises(ValueError):
        a.free(pages)                  # double free
    with pytest.raises(ValueError):
        a.free([99])                   # foreign page
    with pytest.raises(ValueError):
        a.incref(pages)                # incref of a freed page
    a.free([a.sentinel])               # sentinel frees are ignored
    _conserved(a)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "incref", "free"]),
                          st.integers(0, 5)), max_size=60),
       st.integers(1, 12))
def test_allocator_property_no_leak_no_double_free(ops, num_pages):
    """Random alloc/incref/free interleavings against a shadow model:
    conservation holds at every step, operations past the shadow's
    outstanding references raise (never corrupt), and releasing every
    outstanding reference returns the pool to fully-free."""
    a = PageAllocator(num_pages)
    held = []                                      # one entry per reference
    for op, n in ops:
        if op == "alloc":
            got = a.alloc(n)
            if got is not None:
                held.extend(got)
        elif op == "incref" and held:
            p = held[n % len(held)]
            a.incref([p])
            held.append(p)
        elif op == "free" and held:
            p = held.pop(n % len(held))
            a.free([p])
        _conserved(a)
    for p in held:
        a.free([p])
    assert a.free_pages == a.num_pages
    _conserved(a)


# ===========================================================================
# PrefixCache: radix trie insert / match / evict
# ===========================================================================
def _toks(rng, n):
    return rng.integers(0, 50, n).tolist()


def test_trie_match_increfs_and_insert_adopts():
    a = PageAllocator(16)
    pc = PrefixCache(a, page_size=4)
    rng = np.random.default_rng(0)
    prompt = _toks(rng, 10)                        # 2 full pages + tail
    row = a.alloc(3)                               # the cold request's row
    assert pc.match(prompt) == ([], 0)             # cold miss
    assert pc.insert(prompt, row) == 2             # only FULL pages adopt
    pc.check(); _conserved(a)
    a.free(row)                                    # request retires
    assert a.pages_in_use == 2                     # trie keeps its own refs
    pages, n = pc.match(prompt)                    # warm sharer
    assert n == 8 and pages == row[:2]
    assert all(a.refcount(p) == 2 for p in pages)  # trie + caller
    a.free(pages)
    pc.release_all()
    assert a.pages_in_use == 0
    _conserved(a)


def test_trie_evict_lru_spares_referenced_pages():
    a = PageAllocator(16)
    pc = PrefixCache(a, page_size=2)
    rng = np.random.default_rng(1)
    pa, pb = _toks(rng, 4), _toks(rng, 4)
    ra, rb = a.alloc(2), a.alloc(2)
    pc.insert(pa, ra); a.free(ra)
    pc.insert(pb, rb); a.free(rb)
    held, _ = pc.match(pa)                         # caller still holds pa
    assert pc.evict(10) == 2                       # only pb's chain evicts
    assert a.refcount(held[-1]) >= 1
    pc.check(); _conserved(a)
    a.free(held)
    assert pc.evict(10) == 2                       # now pa's chain goes too
    assert a.pages_in_use == 0
    _conserved(a)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["admit", "evict", "retire"]),
                          st.integers(0, 7)), max_size=40),
       st.integers(1, 3))
def test_trie_property_conservation(ops, page_size):
    """Random admit (match + alloc suffix + insert) / retire / evict
    sequences: trie and allocator audits pass at every step, and draining
    everything returns the pool to fully-free — no page is ever freed while
    the trie or a live row still references it, none leaks."""
    a = PageAllocator(12)
    pc = PrefixCache(a, page_size)
    rng = np.random.default_rng(42)
    pool = [_toks(rng, page_size * k) for k in (1, 2, 3, 2, 1, 3, 2, 1)]
    rows = []                                      # live block-table rows
    for op, i in ops:
        if op == "admit":
            toks = pool[i % len(pool)]
            matched, n = pc.match(toks)
            need = (len(toks) - n) // page_size
            fresh = a.alloc(need)
            if fresh is None:
                pc.evict(need)
                fresh = a.alloc(need)
            if fresh is None:
                a.free(matched)                    # admission failed: undo
            else:
                row = matched + fresh
                pc.insert(toks, row)
                rows.append(row)
        elif op == "retire" and rows:
            a.free(rows.pop(i % len(rows)))
        elif op == "evict":
            pc.evict(i)
        pc.check(); _conserved(a)
    for row in rows:
        a.free(row)
    pc.release_all()
    assert pc.evict(1) == 0 and a.pages_in_use == 0
    _conserved(a)


# ===========================================================================
# token-identity matrix (subprocess isolation, test_dist_serving's idiom)
# ===========================================================================
def _run(*parts: str, timeout=560):
    py_src = "\n".join(textwrap.dedent(p) for p in parts)
    assert "OK" in py_src.rsplit("print", 1)[-1], "test body must print ...OK"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_NO_PALLAS"] = "1"
    out = subprocess.run([sys.executable, "-c", py_src],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout, f"script did not reach its OK print:\n{out.stdout}"
    return out.stdout


_COMMON = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_arch
    from repro.core.policy import ExpansionPolicy
    from repro.infer.serve import Engine, ServeConfig
    from repro.models import model as M

    W4A16 = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=2, a_terms=0)
    W4A16_T3 = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=3, a_terms=0)

    def build(arch):
        cfg = get_arch(arch, smoke=True)
        return cfg, M.init_params(jax.random.PRNGKey(0), cfg)

    def prompts(cfg, lens, seed=1, prefix=0):
        rng = np.random.default_rng(seed)
        common = rng.integers(0, cfg.vocab_size, prefix).tolist()
        return [common + rng.integers(0, cfg.vocab_size, l).tolist()
                for l in lens]

    def serve(cfg, params, sc, reqs, policy=None, qualities=None, max_new=8):
        eng = Engine(cfg, params, policy=policy, serve_cfg=sc)
        ids = []
        for i, p in enumerate(reqs):
            kw = {"quality": qualities[i % len(qualities)]} if qualities else {}
            ids.append(eng.add_request(p, **kw))
        out = eng.run(max_new_tokens=max_new)
        return [list(out[i]) for i in ids], eng.last_run_stats

    def assert_identical(a, b, tag):
        for i, (x, y) in enumerate(zip(a, b)):
            assert x == y, (tag, i, x, y)
"""


# {chunked vs monolithic} x {attn, local+rglru, ssm} x {fp, w4a16}, with
# slot recycling (n_req > slots) and mixed non-bucket-aligned lengths
@pytest.mark.parametrize("arch,quant", [
    ("qwen2_1_5b", "fp"), ("qwen2_1_5b", "w4a16"),
    ("recurrentgemma_9b", "w4a16"), ("mamba2_780m", "w4a16"),
])
def test_identity_chunked_dense(arch, quant):
    _run(_COMMON, f"""
    cfg, params = build({arch!r})
    pol = None if {quant!r} == "fp" else W4A16
    reqs = prompts(cfg, [5, 19, 9, 21, 13])
    base = dict(max_seq=64, max_slots=3)
    mono, _ = serve(cfg, params, ServeConfig(**base), reqs, policy=pol)
    chunk, _ = serve(cfg, params, ServeConfig(**base, prefill_chunk=8),
                     reqs, policy=pol)
    assert_identical(mono, chunk, "chunked-vs-monolithic")
    print("OK")
    """)


def test_identity_prefix_cold_and_warm():
    """Paged + prefix: the cold pass (trie empty), a warm same-run sharer,
    and a warm second run all match the uncached monolithic engine; warm
    passes actually reuse pages, and the run ends with zero pages in use."""
    _run(_COMMON, """
    cfg, params = build("qwen2_1_5b")
    reqs = prompts(cfg, [5, 13, 9, 21], prefix=16)
    base = dict(max_seq=64, max_slots=2, paged=True, page_size=8,
                num_pages=64)
    mono, _ = serve(cfg, params, ServeConfig(**base), reqs, policy=W4A16)
    eng = Engine(cfg, params, policy=W4A16, serve_cfg=ServeConfig(
        **base, prefill_chunk=8, prefix_cache=True))
    ids = [eng.add_request(p) for p in reqs]
    out = eng.run(max_new_tokens=8)
    st1 = eng.last_run_stats
    assert_identical(mono, [list(out[i]) for i in ids], "cold+warm run 1")
    assert st1["prefix"]["tokens_reused"] > 0, st1["prefix"]
    assert st1["paged"]["pages_in_use_end"] == 0, st1
    # second run on the SAME engine: the trie survives between runs, so
    # every request warm-hits the shared prefix now
    ids = [eng.add_request(p) for p in reqs]
    out = eng.run(max_new_tokens=8)
    st2 = eng.last_run_stats
    assert_identical(mono, [list(out[i]) for i in ids], "warm run 2")
    assert st2["prefix"]["tokens_reused"] >= st1["prefix"]["tokens_reused"]
    assert st2["paged"]["pages_in_use_end"] == 0, st2
    print("OK")
    """)


def test_identity_chunked_speculative():
    """Self-speculative decoding over chunked prefill: token-identical to
    the monolithic speculative engine (greedy spec is itself identical to
    non-spec, so this pins the whole chain)."""
    _run(_COMMON, """
    cfg, params = build("qwen2_1_5b")
    reqs = prompts(cfg, [5, 17, 9, 12])
    base = dict(max_seq=64, max_slots=2, spec_terms=1, spec_lookahead=2)
    mono, _ = serve(cfg, params, ServeConfig(**base), reqs, policy=W4A16_T3)
    chunk, _ = serve(cfg, params, ServeConfig(**base, prefill_chunk=8),
                     reqs, policy=W4A16_T3)
    assert_identical(mono, chunk, "spec")
    print("OK")
    """)


def test_identity_chunked_qos_tiers():
    """Mixed-quality (term-truncated) requests over chunked prefill match
    the monolithic tiered engine tier-for-tier.  Load-adaptive degradation
    is pinned OFF: it keys on queue depth per scheduler ROUND, and chunked
    fills take more rounds than a monolithic prefill, so the two engines
    would legitimately degrade over different token windows — identity is
    only defined for the static tier budgets."""
    _run(_COMMON, """
    from repro.infer.qos import DegradeConfig
    cfg, params = build("qwen2_1_5b")
    reqs = prompts(cfg, [5, 18, 9, 13])
    quals = ["full", "k2", "k1", "k2"]
    base = dict(max_seq=64, max_slots=2,
                tier_budgets=(("k2", 2), ("k1", 1)),
                degrade=DegradeConfig(enabled=False))
    mono, _ = serve(cfg, params, ServeConfig(**base), reqs,
                    policy=W4A16_T3, qualities=quals)
    chunk, _ = serve(cfg, params, ServeConfig(**base, prefill_chunk=8),
                     reqs, policy=W4A16_T3, qualities=quals)
    assert_identical(mono, chunk, "qos")
    print("OK")
    """)


def test_bucket_pad_never_writes_shared_pages():
    """Regression (chunk tail x shared pages): prompt lengths sit just past
    a page boundary, so the final chunk's bucket padding overhangs into the
    region a LATER sharer will extend.  If pad rows were committed past
    ``valid`` (or below the per-row ``write_from`` floor on matched pages),
    the warm request would read corrupted prefix KV and diverge from the
    monolithic engine."""
    _run(_COMMON, """
    cfg, params = build("qwen2_1_5b")
    # 16-token shared prefix = 2 full pages; suffixes of 1 and 3 tokens put
    # every true length barely past the shared boundary while the chunk
    # (and bucket) padding extends well beyond it
    reqs = prompts(cfg, [1, 3, 1, 3], prefix=16)
    base = dict(max_seq=64, max_slots=2, paged=True, page_size=8,
                num_pages=48, prefill_bucket=16)
    mono, _ = serve(cfg, params, ServeConfig(**base), reqs, policy=W4A16)
    cached, stats = serve(cfg, params, ServeConfig(
        **base, prefill_chunk=8, prefix_cache=True), reqs, policy=W4A16)
    assert_identical(mono, cached, "bucket-pad")
    assert stats["prefix"]["tokens_reused"] > 0, stats["prefix"]
    assert stats["paged"]["pages_in_use_end"] == 0, stats
    print("OK")
    """)


def test_fully_cached_prompt_recompute_row():
    """A prompt whose pages are ALL cached still needs its last position's
    logits: the scheduler recomputes exactly one row (start = len-1) from
    shared pages without writing them, and output stays identical."""
    _run(_COMMON, """
    cfg, params = build("qwen2_1_5b")
    # identical 24-token prompts: the second is fully covered by the trie
    reqs = prompts(cfg, [0, 0], prefix=24)
    base = dict(max_seq=64, max_slots=2, paged=True, page_size=8,
                num_pages=48)
    mono, _ = serve(cfg, params, ServeConfig(**base), reqs, policy=W4A16)
    cached, stats = serve(cfg, params, ServeConfig(
        **base, prefill_chunk=8, prefix_cache=True), reqs, policy=W4A16)
    assert_identical(mono, cached, "fully-cached")
    assert stats["prefix"]["tokens_reused"] > 0, stats["prefix"]
    assert stats["paged"]["pages_in_use_end"] == 0, stats
    print("OK")
    """)
