"""MoE dispatch: dropless == dense-gated reference; capacity semantics."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.models import moe as MOE
from repro.models.layers import FP


def dense_moe_reference(params, x, cfg):
    """Every token through its top-k experts, no capacity limit."""
    b, s, d = x.shape
    logits = x @ params["router"]["kernel"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    if cfg.experts_per_token > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    wi, wg, wo = params["wi"]["kernel"], params["wg"]["kernel"], params["wo"]["kernel"]
    # run all experts densely, then gate
    h = jnp.einsum("bsd,edf->ebsf", x, wi)
    hg = jax.nn.silu(jnp.einsum("bsd,edf->ebsf", x, wg))
    y_all = jnp.einsum("ebsf,efd->ebsd", h * hg, wo)
    out = jnp.zeros_like(x)
    for j in range(cfg.experts_per_token):
        sel = jax.nn.one_hot(gate_idx[..., j], cfg.num_experts)      # (b,s,E)
        y_sel = jnp.einsum("bse,ebsd->bsd", sel, y_all)
        out = out + gate_vals[..., j:j+1] * y_sel
    if "shared" in params:
        from repro.models import layers as L
        out = out + L.mlp_apply(FP, params["shared"], x, "silu")
    return out


def test_dropless_matches_dense(rng):
    for arch in ("grok_1_314b", "llama4_scout_17b_a16e"):
        cfg = get_arch(arch, smoke=True)  # capacity_factor=8 -> dropless
        params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
        x = jnp.array(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
        y = MOE.moe_apply(FP, params, x, cfg)
        y_ref = dense_moe_reference(params, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens(rng):
    """With tiny capacity, output norm shrinks (tokens dropped) but stays finite."""
    cfg = get_arch("grok_1_314b", smoke=True)
    cfg_tight = dataclasses.replace(cfg, capacity_factor=0.25)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.array(rng.normal(size=(2, 32, cfg.d_model)).astype(np.float32))
    y_full = MOE.moe_apply(FP, params, x, cfg)
    y_tight = MOE.moe_apply(FP, params, x, cfg_tight)
    assert bool(jnp.all(jnp.isfinite(y_tight)))
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))


def test_expanded_experts(rng):
    """Per-expert series expansion approximates the FP MoE block."""
    from repro.core.ptq import expand_params
    from repro.core.policy import W8A8
    from repro.models.layers import QuantContext
    cfg = get_arch("llama4_scout_17b_a16e", smoke=True)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.array(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
    y_fp = MOE.moe_apply(FP, params, x, cfg)
    pq = expand_params(params, W8A8)
    y_q = MOE.moe_apply(QuantContext(policy=W8A8), pq, x, cfg)
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.05, rel
