"""MoE dispatch: dropless == dense-gated reference; capacity semantics."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.models import moe as MOE
from repro.models.layers import FP


def dense_moe_reference(params, x, cfg):
    """Every token through its top-k experts, no capacity limit."""
    b, s, d = x.shape
    logits = x @ params["router"]["kernel"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    if cfg.experts_per_token > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    wi, wg, wo = params["wi"]["kernel"], params["wg"]["kernel"], params["wo"]["kernel"]
    # run all experts densely, then gate
    h = jnp.einsum("bsd,edf->ebsf", x, wi)
    hg = jax.nn.silu(jnp.einsum("bsd,edf->ebsf", x, wg))
    y_all = jnp.einsum("ebsf,efd->ebsd", h * hg, wo)
    out = jnp.zeros_like(x)
    for j in range(cfg.experts_per_token):
        sel = jax.nn.one_hot(gate_idx[..., j], cfg.num_experts)      # (b,s,E)
        y_sel = jnp.einsum("bse,ebsd->bsd", sel, y_all)
        out = out + gate_vals[..., j:j+1] * y_sel
    if "shared" in params:
        from repro.models import layers as L
        out = out + L.mlp_apply(FP, params["shared"], x, "silu")
    return out


def test_dropless_matches_dense(rng):
    for arch in ("grok_1_314b", "llama4_scout_17b_a16e"):
        cfg = get_arch(arch, smoke=True)  # capacity_factor=8 -> dropless
        params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
        x = jnp.array(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
        y = MOE.moe_apply(FP, params, x, cfg)
        y_ref = dense_moe_reference(params, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens(rng):
    """With tiny capacity, output norm shrinks (tokens dropped) but stays finite."""
    cfg = get_arch("grok_1_314b", smoke=True)
    cfg_tight = dataclasses.replace(cfg, capacity_factor=0.25)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.array(rng.normal(size=(2, 32, cfg.d_model)).astype(np.float32))
    y_full = MOE.moe_apply(FP, params, x, cfg)
    y_tight = MOE.moe_apply(FP, params, x, cfg_tight)
    assert bool(jnp.all(jnp.isfinite(y_tight)))
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))


def test_expanded_experts(rng):
    """Per-expert series expansion approximates the FP MoE block."""
    from repro.core.ptq import expand_params
    from repro.core.policy import W8A8
    from repro.models.layers import QuantContext
    cfg = get_arch("llama4_scout_17b_a16e", smoke=True)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.array(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
    y_fp = MOE.moe_apply(FP, params, x, cfg)
    pq = expand_params(params, W8A8)
    y_q = MOE.moe_apply(QuantContext(policy=W8A8), pq, x, cfg)
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.05, rel


def test_group_routing_pads_non_dividing_token_counts(rng):
    """tokens % group_size != 0 routes without caller-side padding: the last
    group is right-padded with zero-gate rows (exact no-op), so a dropless
    config still matches the dense reference on awkward shapes."""
    cfg = get_arch("grok_1_314b", smoke=True)  # capacity_factor=8 -> dropless
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.array(rng.normal(size=(3, 7, cfg.d_model)).astype(np.float32))
    y_ref = dense_moe_reference(params, x, cfg)
    for g in (5, 8, 16):   # 21 tokens: pad 4, 3 and 11 rows respectively
        y = MOE.moe_apply(FP, params, x, cfg, group_size=g)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)


def test_pad_rows_claim_no_capacity(rng):
    """Pad-row isolation under a TIGHT capacity: real tokens must see the
    same capacity slots whether or not the group carries pad rows — the pad
    rows' one-hots are zeroed BEFORE the capacity cumsum."""
    cfg = dataclasses.replace(get_arch("grok_1_314b", smoke=True),
                              capacity_factor=1.0)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.array(rng.normal(size=(1, 12, cfg.d_model)).astype(np.float32))
    # one group of 12 (divides) vs one group of 16 (4 pad rows at the end):
    # same group membership for the real tokens -> identical routing
    y_exact = MOE.moe_apply(FP, params, x, cfg, group_size=12)
    y_padded = MOE.moe_apply(FP, params, x, cfg, group_size=16)
    np.testing.assert_array_equal(np.asarray(y_exact), np.asarray(y_padded))


def test_token_routing_matches_dense_reference(rng):
    """routing="token" (the serving contract) is dropless by construction:
    it must match the dense-gated reference for any capacity_factor."""
    for arch in ("grok_1_314b", "llama4_scout_17b_a16e"):
        cfg = dataclasses.replace(get_arch(arch, smoke=True),
                                  capacity_factor=0.25)  # would drop in "group"
        params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
        x = jnp.array(rng.normal(size=(2, 9, cfg.d_model)).astype(np.float32))
        y = MOE.moe_apply(FP, params, x, cfg, routing="token")
        y_ref = dense_moe_reference(params, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)


def test_token_routing_row_independent(rng):
    """The serving determinism rule: under routing="token" a row's output is
    a function of that row alone — bit-identical whether it is served alone
    or batched with arbitrary other rows (slot order / recycling / masked
    neighbors cannot perturb a request's stream)."""
    cfg = get_arch("grok_1_314b", smoke=True)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    xs = jnp.array(rng.normal(size=(4, 1, cfg.d_model)).astype(np.float32))
    y_batch = MOE.moe_apply(FP, params, xs, cfg, routing="token")
    for i in range(4):
        y_solo = MOE.moe_apply(FP, params, xs[i:i + 1], cfg, routing="token")
        np.testing.assert_array_equal(np.asarray(y_batch[i]),
                                      np.asarray(y_solo[0]))
    # and permuting the batch permutes the outputs bit-exactly
    perm = jnp.array([2, 0, 3, 1])
    y_perm = MOE.moe_apply(FP, params, xs[perm], cfg, routing="token")
    np.testing.assert_array_equal(np.asarray(y_perm),
                                  np.asarray(y_batch[perm]))


def test_moe_stats_load_and_drops(rng):
    """return_stats: token routing counts k slots per token with zero drops;
    tight-capacity group routing reports the dropped mass."""
    cfg = get_arch("grok_1_314b", smoke=True)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.array(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    t, k = 16, cfg.experts_per_token
    _, st = MOE.moe_apply(FP, params, x, cfg, routing="token",
                          return_stats=True)
    assert int(st["assigned"]) == t * k
    assert int(st["dropped"]) == 0
    assert int(jnp.sum(st["load"])) == t * k
    cfg_tight = dataclasses.replace(cfg, capacity_factor=0.25)
    _, st2 = MOE.moe_apply(FP, params, x, cfg_tight, routing="group",
                           return_stats=True)
    assert int(st2["dropped"]) > 0
    assert int(jnp.sum(st2["load"])) + int(st2["dropped"]) == t * k
