"""Doc-tested README: every runnable ```python fence in README.md executes
against the real API, so the quickstart can no longer drift.

Convention: blocks tagged ```python run, cumulatively, in ONE subprocess
(shared namespace — later blocks may use names from earlier ones, exactly
as a reader pasting them in order would).  Blocks tagged ```python no-run
are fragments for illustration (still syntax-checked here).  The
subprocess gets 4 fake devices so the multi-device quickstart runs too,
and a temp cwd so artifact saves don't pollute the repo.

The docs-check CI job runs this module plus every examples/*.py.
"""
import os
import re
import subprocess
import sys
import tempfile
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
README = os.path.join(REPO, "README.md")

_FENCE = re.compile(r"^```python([^\n`]*)\n(.*?)^```\s*$",
                    re.MULTILINE | re.DOTALL)


def _blocks():
    with open(README) as f:
        text = f.read()
    out = []
    for m in _FENCE.finditer(text):
        info, body = m.group(1).strip(), m.group(2)
        out.append((info, textwrap.dedent(body)))
    return out


def test_readme_has_runnable_quickstart():
    runnable = [b for info, b in _blocks() if "no-run" not in info]
    assert len(runnable) >= 3, "README lost its runnable quickstart blocks"
    joined = "\n".join(runnable)
    for needle in ("QuantRecipe", "Runtime", "serve", "make_serve_mesh"):
        assert needle in joined, f"quickstart no longer shows {needle}"


def test_readme_python_blocks_compile():
    """Every python fence — including no-run fragments — must parse."""
    for i, (info, body) in enumerate(_blocks()):
        compile(body, f"README.md[python block {i}]", "exec")


def test_readme_snippets_run():
    """Execute the runnable blocks in order in one fresh interpreter."""
    runnable = [b for info, b in _blocks() if "no-run" not in info]
    script = "\n\n".join(runnable)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["REPRO_NO_PALLAS"] = "1"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    with tempfile.TemporaryDirectory() as tmp:
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=560,
                             env=env, cwd=tmp)
    assert out.returncode == 0, (
        f"README snippet failed:\nSTDOUT:\n{out.stdout}\n"
        f"STDERR:\n{out.stderr[-3000:]}")
