"""Loop-aware HLO cost model: trip-count multiplication, dot flops, bytes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import parse_hlo, total_costs


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_trip_multiplication():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=12)
        return out

    comp = _compile(f, jax.ShapeDtypeStruct((8, 64), jnp.float32),
                    jax.ShapeDtypeStruct((64, 64), jnp.float32))
    costs = total_costs(comp.as_text())
    assert costs["flops"] == 12 * 2 * 8 * 64 * 64
    # xla's own count sees the body once (cost_analysis returns a list of
    # per-computation dicts on some jax versions)
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < costs["flops"]


def test_nested_scan_trips_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    comp = _compile(f, jax.ShapeDtypeStruct((4, 16), jnp.float32),
                    jax.ShapeDtypeStruct((16, 16), jnp.float32))
    costs = total_costs(comp.as_text())
    assert costs["flops"] == 5 * 3 * 2 * 4 * 16 * 16


def test_int8_dot_classified():
    def f(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)

    comp = _compile(f, jax.ShapeDtypeStruct((16, 32), jnp.int8),
                    jax.ShapeDtypeStruct((32, 8), jnp.int8))
    costs = total_costs(comp.as_text())
    assert costs["flops"] == 2 * 16 * 32 * 8
    assert costs["int_dot_flops"] == costs["flops"]


def test_bytes_scale_with_scan():
    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 1.5, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    comp = _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    costs = total_costs(comp.as_text())
    # each iteration reads+writes ~2 x 256KB; 10 trips >= 4MB total
    assert costs["bytes"] > 10 * 2 * 256 * 256 * 4 * 0.8
