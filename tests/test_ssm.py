"""Mamba-2 SSD: chunked algorithm vs naive sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import ssm as SSM
from repro.models.layers import FP


def naive_ssd(x, dt, a, bv, cv):
    """h_t = exp(dt_t a) h_{t-1} + dt_t B_t (x) x_t;  y_t = C_t . h_t."""
    b, l, h, p = x.shape
    n = bv.shape[-1]
    s = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, l, h, p), np.float64)
    xn, dtn, an = np.asarray(x, np.float64), np.asarray(dt, np.float64), np.asarray(a, np.float64)
    bn, cn = np.asarray(bv, np.float64), np.asarray(cv, np.float64)
    for t in range(l):
        da = np.exp(dtn[:, t] * an)                       # (B,H)
        s = s * da[:, :, None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dtn[:, t], bn[:, t], xn[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", cn[:, t], s)
    return ys, s


@pytest.mark.parametrize("chunk", (4, 8, 16))
def test_ssd_chunked_matches_naive(rng, chunk):
    b, l, h, p, n = 2, 32, 3, 4, 8
    x = jnp.array(rng.normal(size=(b, l, h, p)).astype(np.float32))
    dt = jnp.array(rng.uniform(0.01, 0.5, size=(b, l, h)).astype(np.float32))
    a = jnp.array(-rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32))
    bv = jnp.array(rng.normal(size=(b, l, n)).astype(np.float32))
    cv = jnp.array(rng.normal(size=(b, l, n)).astype(np.float32))
    y, s_fin = SSM.ssd_chunked(x, dt, a, bv, cv, chunk=chunk)
    y_ref, s_ref = naive_ssd(x, dt, a, bv, cv)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), s_ref, rtol=2e-4, atol=2e-4)


def test_ssm_decode_matches_forward(rng):
    """Full mixer: per-token decode reproduces the full-sequence output."""
    cfg = get_arch("mamba2_780m", smoke=True)
    params = SSM.ssm_init(jax.random.PRNGKey(0), cfg)
    b, l = 2, 12
    x = jnp.array(rng.normal(size=(b, l, cfg.d_model)).astype(np.float32))
    y_full, final_cache = SSM.ssm_apply(FP, params, x, cfg)
    d = SSM.ssm_dims(cfg)
    cache = {"conv": jnp.zeros((b, cfg.ssm_conv - 1, d["conv_ch"])),
             "ssm": jnp.zeros((b, d["heads"], d["p"], d["n"]))}
    ys = []
    for t in range(l):
        y_t, cache = SSM.ssm_decode_step(FP, params, x[:, t:t+1], cache, cfg)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache["ssm"]), np.asarray(final_cache["ssm"]),
                               rtol=2e-3, atol=2e-3)
