"""Optional-hypothesis shim: the CI/container image may not ship hypothesis.

``from _hyp import given, settings, st`` gives the real library when
installed; otherwise property tests are skipped (never silently passed) and
the deterministic sweeps in the same modules still run.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
