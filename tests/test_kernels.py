"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import expansion as E
from repro.kernels import ops, ref

BITS = (2, 3, 4, 8)
SHAPES_Q = [(8, 16), (33, 65), (128, 128), (256, 300), (1, 7)]
SHAPES_MM = [(8, 16, 8), (32, 48, 24), (64, 128, 96), (129, 257, 65)]


@pytest.mark.parametrize("shape", SHAPES_Q)
@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("terms", (1, 3))
def test_residual_quantize_kernel_matches_ref(rng, shape, bits, terms):
    x = jnp.array(rng.normal(size=shape).astype(np.float32) * 3.0)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), bits)
    pk = ops.residual_quantize(x, s1, bits=bits, terms=terms, use_kernel=True)
    pr = ops.residual_quantize(x, s1, bits=bits, terms=terms, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))


@pytest.mark.parametrize("in_dtype", (jnp.float32, jnp.bfloat16))
def test_residual_quantize_dtypes(rng, in_dtype):
    x = jnp.array(rng.normal(size=(32, 32)).astype(np.float32)).astype(in_dtype)
    s1 = E.first_scale(jnp.max(jnp.abs(x.astype(jnp.float32))), 4)
    pk = ops.residual_quantize(x.astype(jnp.float32), s1, bits=4, terms=2, use_kernel=True)
    pr = ops.residual_quantize(x.astype(jnp.float32), s1, bits=4, terms=2, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    assert pk.dtype == jnp.int8


@pytest.mark.parametrize("m,k,n", SHAPES_MM)
@pytest.mark.parametrize("a_bits", (2, 4, 8))
@pytest.mark.parametrize("tw", (1, 2))
def test_series_matmul_kernel_matches_ref(rng, m, k, n, a_bits, tw):
    x = jnp.array(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.array(rng.normal(size=(k, n)).astype(np.float32))
    w_et = E.expand(w, 4, tw, per_channel=True, saturating=False)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), a_bits)
    kw = dict(a_bits=a_bits, a_terms=3)
    yk = ops.series_matmul(x, s1, w_et.planes, w_et.scales, use_kernel=True, **kw)
    yr = ops.series_matmul(x, s1, w_et.planes, w_et.scales, use_kernel=False, **kw)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5, atol=1e-5)


def test_series_matmul_per_tensor_scales(rng):
    x = jnp.array(rng.normal(size=(16, 32)).astype(np.float32))
    w = jnp.array(rng.normal(size=(32, 8)).astype(np.float32))
    w_et = E.expand(w, 4, 2, per_channel=False, saturating=False)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)
    yk = ops.series_matmul(x, s1, w_et.planes, w_et.scales, a_bits=4, a_terms=2, use_kernel=True)
    yr = ops.series_matmul(x, s1, w_et.planes, w_et.scales, a_bits=4, a_terms=2, use_kernel=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5, atol=1e-5)


def test_series_matmul_approximates_fp(rng):
    """The kernel's output converges to x@w as terms grow (Eq. 3)."""
    x = jnp.array(rng.normal(size=(32, 64)).astype(np.float32))
    w = jnp.array(rng.normal(size=(64, 32)).astype(np.float32))
    errs = []
    for tw, ta in ((1, 1), (2, 2), (3, 3)):
        w_et = E.expand(w, 4, tw, per_channel=True, saturating=False)
        s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)
        y = ops.series_matmul(x, s1, w_et.planes, w_et.scales, a_bits=4, a_terms=ta,
                              use_kernel=True)
        errs.append(float(jnp.linalg.norm(y - x @ w)))
    assert errs[0] > errs[1] > errs[2], errs


def test_block_size_invariance(rng):
    """Tiling must not change results (pure tiling, no cross-tile state)."""
    x = jnp.array(rng.normal(size=(100, 120)).astype(np.float32))
    w = jnp.array(rng.normal(size=(120, 60)).astype(np.float32))
    w_et = E.expand(w, 4, 2, per_channel=True, saturating=False)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)
    outs = []
    for bm, bn, bk in ((32, 32, 32), (64, 16, 64), (128, 128, 128)):
        outs.append(np.asarray(ops.series_matmul(
            x, s1, w_et.planes, w_et.scales, a_bits=4, a_terms=2, use_kernel=True,
            block_m=bm, block_n=bn, block_k=bk)))
    # f32 accumulation order differs across K tilings: ulp-level tolerance
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 40), k=st.integers(1, 60), n=st.integers(1, 40),
       bits=st.sampled_from((2, 4, 8)), seed=st.integers(0, 2**31 - 1))
def test_property_kernel_ref_equal(m, k, n, bits, seed):
    r = np.random.default_rng(seed)
    x = jnp.array(r.normal(size=(m, k)).astype(np.float32))
    w = jnp.array(r.normal(size=(k, n)).astype(np.float32))
    w_et = E.expand(w, bits, 2, per_channel=True, saturating=False)
    s1 = E.first_scale(jnp.max(jnp.abs(x)) + 1e-30, bits)
    yk = ops.series_matmul(x, s1, w_et.planes, w_et.scales, a_bits=bits, a_terms=2, use_kernel=True)
    yr = ops.series_matmul(x, s1, w_et.planes, w_et.scales, a_bits=bits, a_terms=2, use_kernel=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5, atol=1e-5)


def test_plane_limits_agree_across_modules():
    """The four `_plane_limits` copies (core reference, ref kernels, Pallas
    residual-quantize, Pallas series-matmul) must state identical bounds —
    the bits=8 audit: residual planes use the proof bound ±2^{X-1} in an
    int8 container, so lo reaches -128 at X=8 while hi clamps to +127
    (both unreachable there: the halved scale ratio keeps |q| <= 64)."""
    import importlib
    RQ = importlib.import_module("repro.kernels.residual_quantize")
    SM = importlib.import_module("repro.kernels.series_matmul")

    for bits in (2, 3, 4, 8):
        for k in (0, 1, 2):
            want = E._plane_limits(bits, k)
            assert ref._plane_limits(bits, k) == want, (bits, k)
            assert RQ._plane_limits(bits, k) == want, (bits, k)
            assert SM._plane_limits(bits, k) == want, (bits, k)
    assert E._plane_limits(8, 1) == (-128, 127)
    assert E._plane_limits(4, 1) == (-8, 8)
    assert E._plane_limits(8, 0) == (-127, 127)


@pytest.mark.parametrize("terms", (2, 4))
def test_bits8_residual_parity_and_halved_grid(rng, terms):
    """bits=8 parity audit (deterministic adversarial sweep): kernel ==
    pure-jnp ref == core sequential extraction, on data engineered to sit on
    half-tie rounding frontiers, and residual planes never leave ±64 (the
    halved X=8 ratio makes the ±127/-128 container clamp unreachable)."""
    bits = 8
    x = rng.normal(size=(64, 64)).astype(np.float32) * 5.0
    s1f = float(E.first_scale(jnp.max(jnp.abs(jnp.asarray(x))), bits))
    ratio = E.scale_ratio(bits)
    # adversarial rows: exact grid multiples and half-ties of every term scale
    x[0, :] = s1f * np.arange(-32, 32)
    x[1, :] = s1f * (np.arange(-32, 32) + 0.5)
    x[2, :] = (s1f / ratio) * (np.arange(-32, 32) + 0.5)
    x[3, :] = 127.0 * s1f            # the symmetric-grid extreme
    xj = jnp.asarray(x)
    s1 = E.first_scale(jnp.max(jnp.abs(xj)), bits)
    # compare all three extractors under jit, like the serving path runs
    # them: eager-vs-jit f32 fusion (FMA on `r - s*q`) can shift an exact
    # half-tie residual by one ulp, which is a program-shape effect, not an
    # extraction-semantics difference
    pk = ops.residual_quantize(xj, s1, bits=bits, terms=terms, use_kernel=True)
    pr = ops.residual_quantize(xj, s1, bits=bits, terms=terms, use_kernel=False)
    pseq, _ = jax.jit(lambda a, b: E.extract_planes_sequential(
        a, b, bits, terms, per_channel=False))(xj, s1)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pseq))
    resid = np.asarray(pk)[1:].astype(np.int32)
    assert resid.size == 0 or (np.abs(resid).max() <= 64), np.abs(resid).max()


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_bits8_residual_parity_property(seed):
    """bits=8 parity as a property over random scales/data: the Pallas
    kernel, the jnp ref, and the core sequential reference extract identical
    planes (the aligned `_plane_limits` never fire at X=8)."""
    r = np.random.default_rng(seed)
    x = jnp.asarray((r.normal(size=(32, 32)) *
                     10.0 ** r.uniform(-3, 3)).astype(np.float32))
    bits, terms = 8, 3
    s1 = E.first_scale(jnp.max(jnp.abs(x)), bits)
    pk = ops.residual_quantize(x, s1, bits=bits, terms=terms, use_kernel=True)
    pr = ops.residual_quantize(x, s1, bits=bits, terms=terms, use_kernel=False)
    pseq, _ = jax.jit(lambda a, b: E.extract_planes_sequential(
        a, b, bits, terms, per_channel=False))(x, s1)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pseq))
