"""Multi-device semantics (8 fake host devices via subprocess — the main
pytest process must keep 1 device, per the dry-run isolation contract):

* expansion (term) parallelism == local fused expanded matmul  (the paper's
  AllReduce/Abelian execution model, Theorem 2);
* GPipe pipeline forward == sequential stack;
* sharded train step == single-device train step (pjit semantics);
* sharding rules produce legal NamedShardings for a smoke model.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(py_src: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(py_src)],
                         capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_expansion_parallel_matches_local():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import expansion as E
        from repro.core.linear import expand_weight, expanded_apply
        from repro.core.policy import ExpansionPolicy
        from repro.dist.expansion_parallel import make_expand_mesh, term_parallel_apply
        rng = np.random.default_rng(0)
        pol = ExpansionPolicy(w_bits=4, a_bits=4, w_terms=3, a_terms=3,
                              a_symmetric=False, w_saturating=True)
        x = jnp.array(rng.normal(size=(16, 64)).astype(np.float32))
        w = jnp.array(rng.normal(size=(64, 32)).astype(np.float32))
        w_et = expand_weight(w, pol)
        y_local = expanded_apply(x, w_et, pol)
        mesh = make_expand_mesh(4)
        y_par = term_parallel_apply(x, w_et, pol, mesh)
        np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_local),
                                   rtol=1e-5, atol=1e-5)
        # and with term count not divisible by the axis (zero-plane padding)
        mesh8 = make_expand_mesh(8)
        y_par8 = term_parallel_apply(x, w_et, pol, mesh8)
        np.testing.assert_allclose(np.asarray(y_par8), np.asarray(y_local),
                                   rtol=1e-5, atol=1e-5)
        print("expansion-parallel OK")
    """)


def test_pipeline_matches_sequential():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import make_stage_mesh, pipeline_forward
        rng = np.random.default_rng(0)
        n_stages, n_micro, mb, d = 4, 8, 4, 16
        Ws = jnp.array(rng.normal(size=(n_stages, d, d)).astype(np.float32) / d**0.5)
        x = jnp.array(rng.normal(size=(n_micro, mb, d)).astype(np.float32))
        stage_fn = lambda w, h: jnp.tanh(h @ w)
        mesh = make_stage_mesh(n_stages)
        y = pipeline_forward(stage_fn, Ws, x, mesh)
        # sequential reference
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ Ws[s])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)
        print("pipeline OK")
    """)


def test_sharded_train_step_matches_single_device():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_arch
        from repro.dist.sharding import ShardingRules
        from repro.models import model as M
        from repro.train.data import make_batch
        from repro.train.train_step import TrainConfig, make_train_step
        cfg = get_arch("qwen2_1_5b", smoke=True)
        tc = TrainConfig(lr=1e-3, remat=False, grad_accum=2)
        opt, step = make_train_step(cfg, tc)
        params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        opt_state = opt.init(params)
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 32, 8, 0).items()}
        p1, _, m1 = jax.jit(step)(params, opt_state, batch)

        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((2, 4), ("data", "model"))
        rules = ShardingRules(mesh, ("data",))
        p_specs = rules.param_specs(params)
        o_specs = rules.opt_state_specs("adamw", params, p_specs)
        b_specs = rules.batch_specs(batch)
        with mesh:
            p2, _, m2 = jax.jit(step, in_shardings=(p_specs, o_specs, b_specs))(
                params, opt_state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-5)
        print("sharded == single OK")
    """)


def test_sharded_serve_step_runs():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_arch
        from repro.core.ptq import expand_params
        from repro.core.policy import W4A4
        from repro.dist.sharding import ShardingRules
        from repro.infer.serve import make_serve_step
        from repro.models import model as M
        from repro.models.layers import QuantContext
        import os
        os.environ["REPRO_NO_PALLAS"] = "1"
        cfg = get_arch("qwen2_1_5b", smoke=True)
        params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        q = expand_params(params, W4A4)
        qc = QuantContext(policy=W4A4)
        serve_step = make_serve_step(cfg, qc)
        caches = M.init_cache(cfg, batch=8, s_max=32, dtype=jnp.float32)
        tokens = jnp.zeros((8, 1), jnp.int32)
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((2, 4), ("data", "model"))
        rules = ShardingRules(mesh, ("data",))
        in_sh = (rules.param_specs(q), rules.batch_specs({"t": tokens})["t"],
                 rules.cache_specs(caches), rules.replicated())
        with mesh:
            logits, caches2 = jax.jit(serve_step, in_shardings=in_sh)(
                q, tokens, caches, jnp.int32(4))
        assert logits.shape == (8, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        print("sharded serve OK")
    """)


def test_model_level_term_parallel_forward():
    """Theorem 2 executed across devices for a full MLP stack: per-layer
    psum (AbelianAdd) + duplicated nonlinearity == local expanded forward."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.linear import expand_weight, expanded_apply
        from repro.core.policy import ExpansionPolicy
        from repro.dist.expansion_parallel import (make_expand_mesh,
                                                   term_parallel_mlp_forward)
        rng = np.random.default_rng(0)
        pol = ExpansionPolicy(w_bits=4, a_bits=4, w_terms=2, a_terms=3)
        dims = [(32, 48), (48, 24), (24, 8)]
        ws = [jnp.array(rng.normal(size=d).astype(np.float32)) for d in dims]
        ets = [expand_weight(w, pol) for w in ws]
        x = jnp.array(rng.normal(size=(8, 32)).astype(np.float32))
        # local reference: layer-by-layer expanded apply + gelu between
        h = x
        for i, et in enumerate(ets):
            h = expanded_apply(h, et, pol)
            if i < len(ets) - 1:
                h = jax.nn.gelu(h)
        mesh = make_expand_mesh(4)
        y = term_parallel_mlp_forward(x, ets, pol, mesh)
        np.testing.assert_allclose(np.asarray(y), np.asarray(h), rtol=2e-4, atol=2e-4)
        print("model-level term-parallel OK")
    """)
