"""Paged KV cache (DESIGN.md §13): allocator invariants, page-granular
admission accounting (incl. the int8-KV dtype-bytes regression), paged
flash-attention kernel vs the gather-based reference, engine-level token
identity against the dense slots engine (recycling, EOS, spec decode, QoS
tiers), and the no-dense-scores jaxpr contract on the paged dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_check as JC
from repro.configs.base import get_arch
from repro.core.policy import ExpansionPolicy
from repro.infer import kvcache
from repro.infer.serve import Engine, ServeConfig
from repro.models import attention as ATT
from repro.models import model as M
from repro.models.layers import FP, QuantContext

W4A16_T3 = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=3, a_terms=0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lengths, seed=0):
    r = np.random.default_rng(seed)
    return [r.integers(0, cfg.vocab_size, l).tolist() for l in lengths]


def _sc(**kw):
    base = dict(max_seq=48, max_slots=3, scheduler="slots")
    base.update(kw)
    return ServeConfig(**base)


# ---------------------------------------------------------------------------
# allocator: free-list + refcount invariants under randomized streams
# ---------------------------------------------------------------------------
def test_page_allocator_randomized_stream():
    r = np.random.default_rng(7)
    alloc = kvcache.PageAllocator(24)
    held = []                              # list of page lists
    for _ in range(300):
        op = r.integers(0, 3)
        if op == 0:                        # alloc a random footprint
            n = int(r.integers(0, 9))
            pages = alloc.alloc(n)
            if pages is None:
                assert n > alloc.free_pages    # only failure mode
            else:
                assert len(pages) == n and len(set(pages)) == n
                held.append(pages)
        elif op == 1 and held:             # free a held footprint
            alloc.free(held.pop(int(r.integers(0, len(held)))))
        elif op == 2 and held:             # share + unshare (refcounts)
            pages = held[int(r.integers(0, len(held)))]
            alloc.incref(pages)
            alloc.free(pages)
        alloc.check()                      # invariant after EVERY op
        assert alloc.pages_in_use == sum(len(p) for p in held)
    for pages in held:
        alloc.free(pages)
    alloc.check()
    assert alloc.pages_in_use == 0 and alloc.free_pages == 24


def test_page_allocator_misuse_raises():
    alloc = kvcache.PageAllocator(4)
    pages = alloc.alloc(2)
    alloc.free(pages)
    with pytest.raises(ValueError):        # double free
        alloc.free(pages)
    with pytest.raises(ValueError):        # foreign page
        alloc.free([99])
    with pytest.raises(ValueError):        # incref of unallocated
        alloc.incref([0])
    # sentinel ids are ignored wholesale (block-table rows free padding too)
    alloc.free([alloc.sentinel, alloc.sentinel])
    assert alloc.alloc(5) is None          # all-or-nothing beyond capacity
    assert alloc.free_pages == 4


# ---------------------------------------------------------------------------
# admission accounting: int8-KV dtype bytes + page-granular planning
# ---------------------------------------------------------------------------
def test_int8_kv_admission_uses_int8_bytes(setup):
    """Regression: HBM admission must charge int8-KV caches their int8+scale
    byte cost, not bf16 — under a fixed budget an int8-KV engine admits
    MORE slots, never the same or fewer."""
    cfg, _ = setup
    per_bf16 = kvcache.total_cache_bytes(cfg, 1, 256)
    per_int8 = kvcache.total_cache_bytes(cfg, 1, 256, int8_kv=True)
    assert per_int8 < per_bf16
    budget = 8 * per_bf16                  # fits exactly 8 bf16 slots
    cap_bf16 = kvcache.max_batch_for_hbm(cfg, 256, budget, 0.0)
    cap_int8 = kvcache.max_batch_for_hbm(cfg, 256, budget, 0.0, int8_kv=True)
    assert cap_bf16 == 8
    assert cap_int8 > cap_bf16


def test_plan_slots_paged_is_page_granular(setup):
    """Under the same budget the paged bound (fixed state + ONE page per
    slot) admits at least as many slots as the dense bound (every slot
    charged max_seq up front) — strictly more whenever pages are the
    dominant cost."""
    from repro.infer.scheduler import plan_slots
    cfg, params = setup
    per = kvcache.total_cache_bytes(cfg, 1, 256)
    sc_d = _sc(max_seq=256, max_slots=64, hbm_budget_bytes=4 * per)
    sc_p = _sc(max_seq=256, max_slots=64, hbm_budget_bytes=4 * per,
               paged=True, page_size=16)
    n_dense = plan_slots(cfg, sc_d, {})
    n_paged = plan_slots(cfg, sc_p, {})
    assert n_dense == 4
    assert n_paged > n_dense


def test_plan_pages_and_pages_for(setup):
    cfg, _ = setup
    assert kvcache.pages_for(0, 8) == 0
    assert kvcache.pages_for(1, 8) == 1
    assert kvcache.pages_for(8, 8) == 1
    assert kvcache.pages_for(9, 8) == 2
    # no budget: dense-equivalent worst case
    assert kvcache.plan_pages(cfg, 48, 8, 3) == 3 * 6
    # with budget: floored at one sequence's pages, never unusable
    tiny = kvcache.plan_pages(cfg, 48, 8, 3, hbm_bytes=1.0)
    assert tiny == 6
    # attention-free arch: nothing pages
    cfg_ssm = get_arch("mamba2_780m", smoke=True)
    assert kvcache.plan_pages(cfg_ssm, 48, 8, 3, hbm_bytes=1e12) == 0


# ---------------------------------------------------------------------------
# kernel vs reference: paged flash partial (fp exact-level, int8 tolerance)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("softcap", [0.0, 5.0])
def test_paged_flash_kernel_matches_ref(softcap):
    r = np.random.default_rng(3)
    b, t, g, rep, d, page, mp = 3, 1, 2, 2, 16, 8, 5
    num_pages = b * mp
    h = g * rep
    q = jnp.asarray(r.normal(size=(b, t, h, d)).astype(np.float32))
    k_pool = jnp.asarray(r.normal(size=(num_pages + 1, page, g, d))
                         .astype(np.float32))
    v_pool = jnp.asarray(r.normal(size=(num_pages + 1, page, g, d))
                         .astype(np.float32))
    bt = jnp.asarray(r.permutation(num_pages).reshape(b, mp).astype(np.int32))
    clen = jnp.asarray([7, 23, 40], jnp.int32)
    k_new = jnp.asarray(r.normal(size=(b, t, g, d)).astype(np.float32))
    v_new = jnp.asarray(r.normal(size=(b, t, g, d)).astype(np.float32))
    ref = ATT.paged_decode_attention(q, k_pool, v_pool, bt, clen, k_new,
                                     v_new, softcap=softcap, use_kernel=False)
    ker = ATT.paged_decode_attention(q, k_pool, v_pool, bt, clen, k_new,
                                     v_new, softcap=softcap, use_kernel=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_flash_int8_kernel_close_to_ref():
    """int8 kernel re-quantizes softmax weights per page (the ref quantizes
    whole rows), so agreement is tolerance-level, not bitwise; the gather
    reference remains the engine's token-identity oracle."""
    r = np.random.default_rng(4)
    b, t, g, rep, d, page, mp = 2, 3, 2, 2, 16, 8, 4
    num_pages = b * mp
    h = g * rep
    q = jnp.asarray(r.normal(size=(b, t, h, d)).astype(np.float32))
    kf = r.normal(size=(num_pages + 1, page, g, d)).astype(np.float32)
    vf = r.normal(size=(num_pages + 1, page, g, d)).astype(np.float32)
    kq, ks = ATT.quantize_kv(jnp.asarray(kf))
    vq, vs = ATT.quantize_kv(jnp.asarray(vf))
    bt = jnp.asarray(r.permutation(num_pages).reshape(b, mp).astype(np.int32))
    clen = jnp.asarray([11, 27], jnp.int32)
    k_new = jnp.asarray(r.normal(size=(b, t, g, d)).astype(np.float32))
    v_new = jnp.asarray(r.normal(size=(b, t, g, d)).astype(np.float32))
    ref = ATT.paged_chunk_decode_attention_int8(
        q, kq, ks, vq, vs, bt, clen, k_new, v_new, use_kernel=False)
    ker = ATT.paged_chunk_decode_attention_int8(
        q, kq, ks, vq, vs, bt, clen, k_new, v_new, use_kernel=True)
    ref, ker = np.asarray(ref), np.asarray(ker)
    rel = np.abs(ker - ref).max() / max(np.abs(ref).max(), 1e-9)
    assert rel < 0.05, f"int8 paged kernel rel err {rel:.4f}"


# ---------------------------------------------------------------------------
# engine: paged == dense, token for token
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2_1_5b", "recurrentgemma_9b"])
def test_paged_engine_token_identical(arch):
    """The acceptance contract: greedy paged output is token-identical to
    the dense slots engine — mixed lengths, more requests than slots, slot
    AND page recycling — for full-attention and local(ring)+rglru archs."""
    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, [7, 12, 3, 9, 15, 5])
    dense = Engine(cfg, params, serve_cfg=_sc())
    ids_d = [dense.add_request(p) for p in prompts]
    ref = dense.run(max_new_tokens=6)
    paged = Engine(cfg, params, serve_cfg=_sc(paged=True, page_size=8))
    ids_p = [paged.add_request(p) for p in prompts]
    out = paged.run(max_new_tokens=6)
    for a, b in zip(ids_d, ids_p):
        assert out[b] == ref[a], (arch, ref[a], out[b])
    st = paged.last_run_stats["paged"]
    assert st["pages_in_use_end"] == 0     # every page returned
    if arch == "qwen2_1_5b":               # full attention: pages are real
        assert 0 < st["pages_hwm"] <= st["num_pages"]
        # short sequences charge their length, not max_seq
        assert st["kv_bytes_hwm"] < st["kv_bytes_dense"]


def test_paged_engine_eos_recycles_pages(setup):
    """EOS mid-stream frees the slot AND its pages; a queued request
    recycles both, and the stream stays identical to the dense engine."""
    cfg, params = setup
    prompts = _prompts(cfg, [8, 10, 6])
    probe = Engine(cfg, params, serve_cfg=_sc(max_slots=1))
    rid = probe.add_request(prompts[0])
    eos = probe.run(max_new_tokens=6)[rid][3]
    dense = Engine(cfg, params, serve_cfg=_sc(max_slots=1, eos_id=eos))
    ids_d = [dense.add_request(p) for p in prompts]
    ref = dense.run(max_new_tokens=6)
    paged = Engine(cfg, params,
                   serve_cfg=_sc(max_slots=1, eos_id=eos, paged=True,
                                 page_size=8))
    ids_p = [paged.add_request(p) for p in prompts]
    out = paged.run(max_new_tokens=6)
    for a, b in zip(ids_d, ids_p):
        assert out[b] == ref[a]
    assert paged.last_run_stats["paged"]["pages_in_use_end"] == 0


def test_paged_spec_decode_token_identical(setup):
    """Speculative decoding on the paged engine reproduces the
    non-speculative dense stream (the spec contract composes with paging)."""
    cfg, params = setup
    prompts = _prompts(cfg, [5, 9, 13, 7])
    kw = dict(max_seq=48, max_slots=2)
    base = Engine(cfg, params, policy=W4A16_T3,
                  serve_cfg=ServeConfig(**kw))
    ids_b = [base.add_request(p) for p in prompts]
    ref = base.run(max_new_tokens=6)
    spec = Engine(cfg, params, policy=W4A16_T3,
                  serve_cfg=ServeConfig(spec_terms=1, spec_lookahead=3,
                                        paged=True, page_size=8, **kw))
    ids_s = [spec.add_request(p) for p in prompts]
    out = spec.run(max_new_tokens=6)
    for a, b in zip(ids_b, ids_s):
        assert out[b] == ref[a]
    st = spec.last_run_stats
    assert st["paged"]["pages_in_use_end"] == 0
    assert 0.0 <= st["acceptance_rate"] <= 1.0


def test_paged_qos_tiers_match_dense(setup):
    """Per-request QoS tiers ride the paged masked dispatch: tier streams
    are identical to the dense tiered engine, and per-tier effective terms
    hold on the paged layout."""
    cfg, params = setup
    prompts = _prompts(cfg, [5, 9, 13, 7])
    qs = ["full", "k2", "k1", "full"]
    tiers = (("k2", 2), ("k1", 1))
    dense = Engine(cfg, params, policy=W4A16_T3,
                   serve_cfg=_sc(tier_budgets=tiers))
    ids_d = [dense.add_request(p, quality=q) for p, q in zip(prompts, qs)]
    ref = dense.run(max_new_tokens=5)
    paged = Engine(cfg, params, policy=W4A16_T3,
                   serve_cfg=_sc(tier_budgets=tiers, paged=True, page_size=8))
    ids_p = [paged.add_request(p, quality=q) for p, q in zip(prompts, qs)]
    out = paged.run(max_new_tokens=5)
    for a, b in zip(ids_d, ids_p):
        assert out[b] == ref[a]
    st = paged.last_run_stats["tiers"]
    assert st["k1"]["mean_effective_terms"] == 1.0
    assert st["k2"]["mean_effective_terms"] == 2.0


def test_paged_engine_validations(setup):
    cfg, params = setup
    with pytest.raises(ValueError):        # grouped scheduler cannot page
        Engine(cfg, params, serve_cfg=ServeConfig(scheduler="grouped",
                                                  paged=True))
    with pytest.raises(ValueError):
        Engine(cfg, params, serve_cfg=_sc(paged=True, page_size=0))


# ---------------------------------------------------------------------------
# jaxpr contract: no dense (B, max_seq) float intermediates in the paged
# kernel dispatch — and the tripwire provably sees the dense bug class
# ---------------------------------------------------------------------------
def test_no_dense_scores_contract(setup, monkeypatch):
    from repro.infer import serve as S
    cfg, params = setup
    # the kernel gate reads REPRO_NO_PALLAS at trace time; tracing never
    # executes the kernel, so the check runs on any backend
    monkeypatch.delenv("REPRO_NO_PALLAS", raising=False)
    b, s_max, page = 3, 40, 8
    mp = -(-s_max // page)
    tok = jnp.ones((b, 1), jnp.int32)
    clen = jnp.full((b,), 8, jnp.int32)
    key = jax.random.PRNGKey(1)
    alive = jnp.ones((b,), bool)
    eos = jnp.asarray(-1, jnp.int32)
    temp = jnp.asarray(0.0, jnp.float32)
    mask = jnp.ones((b,), bool)
    sizes = (s_max, mp * page)

    # calibration: the dense dispatch MUST trip (scores + cache rows)
    caches = M.init_cache(cfg, b, s_max)
    dense = S.make_decode_sample_step(cfg, FP, masked=True)
    bad = JC.check_no_dense_scores(
        dense, params, tok, caches, clen, key, alive, eos, temp, mask,
        batch=b, seq_sizes=sizes, strict=False)
    assert bad, "tripwire cannot see the dense bug class"

    # the paged KERNEL dispatch must be clean (trace-only: interpret-mode
    # Pallas traces fine on CPU regardless of REPRO_NO_PALLAS)
    pc = M.init_paged_cache(cfg, b, s_max, page_size=page, num_pages=b * mp)
    bt = jnp.arange(b * mp, dtype=jnp.int32).reshape(b, mp)
    qck = QuantContext(policy=None, use_kernel=True)
    paged = S.make_paged_decode_step(cfg, qck, page, masked=True)
    JC.check_no_dense_scores(
        paged, params, tok, pc, clen, bt, key, alive, eos, temp, mask,
        batch=b, seq_sizes=sizes, strict=True)

    # the gather-based REF path is the documented exception (it IS the
    # dense-equivalent oracle) — it trips, which proves the kernel path's
    # pass is not vacuous
    paged_ref = S.make_paged_decode_step(cfg, FP, page, masked=True)
    ref_hits = JC.check_no_dense_scores(
        paged_ref, params, tok, pc, clen, bt, key, alive, eos, temp, mask,
        batch=b, seq_sizes=sizes, strict=False)
    assert ref_hits


def test_paged_open_loop_arrivals(setup):
    """Open-loop arrivals: staggered requests produce the same tokens as
    the all-at-once batch (arrival timing gates admission, never content)."""
    cfg, params = setup
    prompts = _prompts(cfg, [7, 12, 3])
    ref = Engine(cfg, params, serve_cfg=_sc(max_slots=2, paged=True,
                                            page_size=8))
    ids_r = [ref.add_request(p) for p in prompts]
    out_r = ref.run(max_new_tokens=4)
    arr = Engine(cfg, params, serve_cfg=_sc(max_slots=2, paged=True,
                                            page_size=8))
    ids_a = [arr.add_request(p, arrival=0.02 * i)
             for i, p in enumerate(prompts)]
    out_a = arr.run(max_new_tokens=4)
    for a, b in zip(ids_r, ids_a):
        assert out_r[a] == out_a[b]
    m = arr.last_request_metrics
    assert all(m[i]["ttft_s"] > 0 for i in ids_a)
