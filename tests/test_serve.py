"""Serving engine: batching, greedy equivalence FP vs expanded, quant time."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.policy import W4A4, W8A8
from repro.infer.kvcache import cache_bytes_per_token, total_cache_bytes
from repro.infer.serve import Engine, ServeConfig
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, n, length, seed=0):
    r = np.random.default_rng(seed)
    return [r.integers(0, cfg.vocab_size, length).tolist() for _ in range(n)]


def test_engine_generates_batched(setup):
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=48, max_batch=4))
    ids = [eng.add_request(p) for p in _prompts(cfg, 6, 8)]
    out = eng.run(max_new_tokens=5)
    assert set(out) == set(ids)
    assert all(len(v) == 5 for v in out.values())


def test_batched_equals_single(setup):
    """Batching must not change greedy generations (exactness contract)."""
    cfg, params = setup
    prompts = _prompts(cfg, 4, 8)
    eng = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=48, max_batch=4))
    ids = [eng.add_request(p) for p in prompts]
    out_b = eng.run(max_new_tokens=6)
    singles = {}
    for p in prompts:
        e1 = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=48, max_batch=1))
        rid = e1.add_request(p)
        singles[tuple(p)] = e1.run(max_new_tokens=6)[rid]
    for rid, p in zip(ids, prompts):
        assert out_b[rid] == singles[tuple(p)]


def test_mixed_lengths_grouped(setup):
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=64, max_batch=8))
    ids8 = [eng.add_request(p) for p in _prompts(cfg, 3, 8)]
    ids16 = [eng.add_request(p) for p in _prompts(cfg, 2, 16, seed=1)]
    out = eng.run(max_new_tokens=4)
    assert set(out) == set(ids8 + ids16)


def test_expanded_engine_quant_time_and_agreement(setup):
    """W8A8 expansion: fast quantization + high greedy agreement with FP."""
    cfg, params = setup
    prompts = _prompts(cfg, 4, 8)
    fp = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=48, max_batch=4))
    q = Engine(cfg, params, policy=W8A8, serve_cfg=ServeConfig(max_seq=48, max_batch=4))
    assert q.quant_seconds < 60.0
    ids_f = [fp.add_request(p) for p in prompts]
    ids_q = [q.add_request(p) for p in prompts]
    out_f, out_q = fp.run(max_new_tokens=6), q.run(max_new_tokens=6)
    agree = np.mean([np.mean(np.array(out_f[a]) == np.array(out_q[b]))
                     for a, b in zip(ids_f, ids_q)])
    # untrained smoke weights -> near-uniform logits, so argmax is fragile;
    # logits-level closeness is asserted in test_ptq.test_e2e_model_output_close
    assert agree > 0.25, agree


def test_eos_stops_early(setup):
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=48, max_batch=2))
    rid = eng.add_request(_prompts(cfg, 1, 8)[0])
    # force eos to whatever greedy emits first -> length 1
    probe = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=48, max_batch=2))
    pid = probe.add_request(_prompts(cfg, 1, 8)[0])
    first = probe.run(max_new_tokens=1)[pid][0]
    eng.sc = ServeConfig(max_seq=48, max_batch=2, eos_id=first)
    out = eng.run(max_new_tokens=8)
    assert out[rid] == [first]


def test_one_host_transfer_per_decode_step(setup, monkeypatch):
    """The fused decode step keeps sampling + EOS tracking on device: the
    engine performs exactly one device_get per decode step (the seed pulled
    int(tok[i, 0]) twice per request per step)."""
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=48, max_batch=4))
    for p in _prompts(cfg, 4, 8):
        eng.add_request(p)
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    out = eng.run(max_new_tokens=5)
    assert all(len(v) == 5 for v in out.values())
    # one group of 4 requests, 5 decode steps -> 5 transfers (not 2*4*5)
    assert len(calls) == 5, len(calls)


def test_cache_accounting():
    cfg = get_arch("nemotron_4_340b")
    c = cache_bytes_per_token(cfg)
    # 96 layers x 2 x 8 kv x 192 dh x 2B
    assert c["growing_per_token"] == 96 * 2 * 8 * 192 * 2
    total = total_cache_bytes(cfg, batch=128, s_max=32768)
    assert total == pytest.approx(128 * 32768 * c["growing_per_token"], rel=1e-6)
    # ssm: O(1) cache
    m = cache_bytes_per_token(get_arch("mamba2_780m"))
    assert m["growing_per_token"] == 0 and m["fixed"] > 0


def test_hbm_cap_honors_smax_below_window():
    """Regression: ``cache_bytes_per_token`` charged local-attention rings
    the full ``cfg.window`` regardless of decode capacity, while the
    allocator caps the ring at ``min(window, s_max)``
    (models.blocks.init_block_cache) — so ``max_batch_for_hbm``/``plan_slots``
    under-admitted whenever ``max_seq < window``."""
    from repro.infer.kvcache import max_batch_for_hbm

    cfg = get_arch("recurrentgemma_9b", smoke=True)   # window 16, local+rglru
    s_max = 8                                         # below the window
    per_seq = total_cache_bytes(cfg, 1, s_max)
    c_unbounded = cache_bytes_per_token(cfg)          # roofline estimate
    per_seq_window = (c_unbounded["fixed"]
                      + c_unbounded["growing_per_token"] * s_max)
    assert per_seq < per_seq_window                   # ring capped at s_max
    # a budget that truly fits 4 sequences admits 4 ...
    hbm = 4 * per_seq
    assert max_batch_for_hbm(cfg, s_max, hbm, 0.0) == 4
    # ... where the pre-fix full-window charge would have under-admitted
    assert int(hbm // per_seq_window) < 4
    # at s_max >= window the two agree (no behavior change above the cap)
    assert total_cache_bytes(cfg, 1, cfg.window) == pytest.approx(
        c_unbounded["fixed"] + c_unbounded["growing_per_token"] * cfg.window)
