"""RG-LRU: associative scan vs sequential loop; decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.models import rglru as RG
from repro.models.layers import FP


def test_rglru_decode_matches_forward(rng):
    cfg = get_arch("recurrentgemma_9b", smoke=True)
    params = RG.rglru_init(jax.random.PRNGKey(0), cfg)
    b, l = 2, 10
    x = jnp.array(rng.normal(size=(b, l, cfg.d_model)).astype(np.float32))
    y_full, final = RG.rglru_apply(FP, params, x, cfg)
    cache = {"conv": jnp.zeros((b, 3, cfg.rnn_width)), "h": jnp.zeros((b, cfg.rnn_width))}
    ys = []
    for t in range(l):
        y_t, cache = RG.rglru_decode_step(FP, params, x[:, t:t+1], cache, cfg)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cache["h"]), np.asarray(final["h"]),
                               rtol=2e-4, atol=2e-4)


def test_recurrence_is_stable(rng):
    """|a_t| <= 1 by construction: long sequences cannot blow up."""
    cfg = get_arch("recurrentgemma_9b", smoke=True)
    params = RG.rglru_init(jax.random.PRNGKey(1), cfg)
    x = jnp.array(rng.normal(size=(1, 256, cfg.d_model)).astype(np.float32))
    y, _ = RG.rglru_apply(FP, params, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.max(jnp.abs(y))) < 1e3
