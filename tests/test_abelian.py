"""Abelian group axioms (§3.3) + basis-model decomposition (Theorem 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import abelian as A
from repro.core import expansion as E
from repro.core.policy import W4A4
from repro.core.ptq import expand_params


def _model(rng, seed_shift=0):
    r = np.random.default_rng(0 + seed_shift)
    return {"l1": {"kernel": jnp.array(r.normal(size=(8, 16)).astype(np.float32))},
            "l2": {"kernel": jnp.array(r.normal(size=(16, 4)).astype(np.float32)),
                   "bias": jnp.array(r.normal(size=(4,)).astype(np.float32))}}


def _eq(a, b, atol=1e-6):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol), a, b)


def test_group_axioms(rng):
    m1, m2, m3 = _model(rng, 1), _model(rng, 2), _model(rng, 3)
    # commutativity
    _eq(A.abelian_add(m1, m2), A.abelian_add(m2, m1))
    # associativity
    _eq(A.abelian_add(A.abelian_add(m1, m2), m3),
        A.abelian_add(m1, A.abelian_add(m2, m3)))
    # identity
    zero = A.abelian_zero_like(m1)
    _eq(A.abelian_add(m1, zero), m1)
    # inverse
    _eq(A.abelian_add(m1, A.abelian_neg(m1)), zero)


def test_abelian_mul_action(rng):
    m = _model(rng)
    layers = [m["l1"], m["l2"]]
    out = A.abelian_mul([2.0, -0.5], layers)
    np.testing.assert_allclose(np.asarray(out[0]["kernel"]),
                               2.0 * np.asarray(m["l1"]["kernel"]))
    np.testing.assert_allclose(np.asarray(out[1]["kernel"]),
                               -0.5 * np.asarray(m["l2"]["kernel"]))
    # distributivity of the scalar action over AbelianAdd
    m2 = _model(rng, 5)
    lhs = A.abelian_mul([2.0], [A.abelian_add(m["l1"], m2["l1"])])[0]
    rhs = A.abelian_add(A.abelian_mul([2.0], [m["l1"]])[0],
                        A.abelian_mul([2.0], [m2["l1"]])[0])
    _eq(lhs, rhs)


def test_eq5_weight_additivity_linear_model(rng):
    """Eq. 5: Model(W1, x) (+) Model(W2, x) == Model(W1+W2, x) for linear model."""
    r = np.random.default_rng(3)
    w1 = jnp.array(r.normal(size=(8, 8)).astype(np.float32))
    w2 = jnp.array(r.normal(size=(8, 8)).astype(np.float32))
    x = jnp.array(r.normal(size=(4, 8)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(x @ w1 + x @ w2),
                               np.asarray(x @ (w1 + w2)), rtol=1e-5)


def test_basis_models_sum_to_dequant(rng):
    params = _model(rng)
    q = expand_params(params, W4A4)
    bs = A.basis_models(q)
    assert len(bs) == A.num_basis_terms(q)
    total = A.abelian_sum(bs)
    _eq(total, A.dequantize(q), atol=1e-5)
    # order independence (Abelian): reversed sum identical
    total_r = A.abelian_sum(list(reversed(bs)))
    _eq(total, total_r, atol=1e-6)


def test_basis_models_carry_fp_leaves_once(rng):
    params = _model(rng)
    q = expand_params(params, W4A4)
    bs = A.basis_models(q)
    # the non-expanded bias must appear exactly once (in the affine term)
    biases = [np.asarray(b["l2"]["bias"]) for b in bs]
    nonzero = [b for b in biases if np.abs(b).sum() > 0]
    assert len(nonzero) == 1
    np.testing.assert_allclose(nonzero[0], np.asarray(params["l2"]["bias"]))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 5))
def test_property_sum_permutation_invariant(seed, n):
    r = np.random.default_rng(seed)
    models = [{"w": jnp.array(r.normal(size=(6, 6)).astype(np.float32))} for _ in range(n)]
    perm = r.permutation(n)
    a = A.abelian_sum(models)
    b = A.abelian_sum([models[i] for i in perm])
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]), atol=1e-5)
