"""Single-pass series-GEMM pipeline: fused kernels == ref == FP within the
Theorem-1 bound, plus kernel-structure regressions (jaxpr inspection):

* the stacked-plane GEMM issues <= ta MXU dot dispatches per block
  (seed: ta*tw);
* no read of the HBM output ref inside the kernel (accumulation lives in
  VMEM scratch; the output block is written exactly once);
* quantization (round) ops run only under the j==0 guard — each activation
  tile is quantized exactly once per (m, k) grid cell and reused across all
  weight-column blocks.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import convergence as C
from repro.core import expansion as E
from repro.kernels import ops, ref
from repro.kernels.series_matmul import series_matmul_pallas


def _setup(rng, m, k, n, w_bits, tw, per_channel, pack_safe=False):
    x = jnp.array(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.array(rng.normal(size=(k, n)).astype(np.float32))
    w_et = E.expand(w, w_bits, tw, per_channel=per_channel, saturating=False,
                    pack_safe=pack_safe)
    return x, w, w_et


# ---------------------------------------------------------------------------
# numerics: kernel == ref == FP within the Theorem-1 bound
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (33, 65, 17), (129, 257, 65),
                                   (100, 120, 60), (1, 7, 5)])
@pytest.mark.parametrize("ta,tw", [(1, 1), (2, 2), (3, 3), (3, 1), (1, 3)])
def test_kernel_ref_fp_triangle(rng, m, k, n, ta, tw):
    """Odd (non-block-multiple) shapes, ta, tw in {1..3}: the fused kernel
    matches the oracle, and both are within the Theorem-1 residual bound of
    the FP matmul."""
    a_bits = w_bits = 4
    x, w, w_et = _setup(rng, m, k, n, w_bits, tw, per_channel=False)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), a_bits)
    kw = dict(a_bits=a_bits, a_terms=ta)
    yk = ops.series_matmul(x, s1, w_et.planes, w_et.scales, use_kernel=True, **kw)
    yr = ops.series_matmul(x, s1, w_et.planes, w_et.scales, use_kernel=False, **kw)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5, atol=1e-5)

    # Theorem-1 error budget for the GEMM:
    # |y - x@w| <= |Q(x~)| @ |W-err| + |x-err| @ |w|  (triangle inequality),
    # bounded via the per-element residual bounds scale_n/2 on each factor.
    a_res = float(C.residual_bound(float(s1), a_bits, ta))
    w_s1 = float(jnp.max(w_et.scales[0]))
    w_res = float(C.residual_bound(w_s1, w_bits, tw))
    bound = (k * a_res * float(jnp.max(jnp.abs(w)))
             + k * w_res * float(jnp.max(jnp.abs(x)))
             + k * a_res * w_res)
    err = float(jnp.max(jnp.abs(yk - x @ w)))
    assert err <= bound * (1 + 1e-3) + 1e-5, (err, bound)


@pytest.mark.parametrize("per_channel", [False, True])
@pytest.mark.parametrize("a_bits", [2, 4, 8])
def test_per_tensor_vs_per_channel_scales(rng, per_channel, a_bits):
    x, w, w_et = _setup(rng, 40, 72, 24, 4, 2, per_channel=per_channel)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), a_bits)
    kw = dict(a_bits=a_bits, a_terms=2)
    yk = ops.series_matmul(x, s1, w_et.planes, w_et.scales, use_kernel=True, **kw)
    yr = ops.series_matmul(x, s1, w_et.planes, w_et.scales, use_kernel=False, **kw)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5, atol=1e-5)


def test_single_k_block_bit_exact(rng):
    """When K fits one block the per-plane scale folding preserves the
    oracle's f32 association — agreement is bit-exact, not just close."""
    x, w, w_et = _setup(rng, 32, 48, 24, 4, 2, per_channel=True)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)
    kw = dict(a_bits=4, a_terms=3)
    yk = ops.series_matmul(x, s1, w_et.planes, w_et.scales, use_kernel=True,
                           block_m=32, block_n=24, block_k=48, **kw)
    yr = ops.series_matmul(x, s1, w_et.planes, w_et.scales, use_kernel=False, **kw)
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(yr))


def test_packed_dequant_single_block_bit_exact(rng):
    from repro.kernels.pack import pack_int4
    x = jnp.array(rng.normal(size=(16, 32)).astype(np.float32))
    w = jnp.array(rng.normal(size=(32, 16)).astype(np.float32))
    et = E.expand(w, 4, 2, per_channel=True, pack_safe=True)
    packed = pack_int4(et.planes)
    yk = ops.packed_dequant_matmul(x, packed, et.scales, use_kernel=True,
                                   block_m=16, block_n=16, block_k=32)
    yr = ops.packed_dequant_matmul(x, packed, et.scales, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(yr))


def test_quantize_once_reuse_across_n_blocks(rng):
    """Force several N blocks per (m, k) cell: the cached-plane path must
    agree with the oracle (catches stale/incorrect VMEM plane reuse)."""
    x, w, w_et = _setup(rng, 16, 64, 128, 4, 2, per_channel=True)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)
    kw = dict(a_bits=4, a_terms=3)
    yk = ops.series_matmul(x, s1, w_et.planes, w_et.scales, use_kernel=True,
                           block_m=16, block_n=32, block_k=32, **kw)  # 4 N-blocks
    yr = ops.series_matmul(x, s1, w_et.planes, w_et.scales, use_kernel=False, **kw)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# kernel structure (jaxpr inspection)
# ---------------------------------------------------------------------------
needs_kernels = pytest.mark.skipif(
    not ops.kernels_enabled(),
    reason="REPRO_NO_PALLAS=1: no Pallas kernel is dispatched to inspect")


@needs_kernels
@pytest.mark.parametrize("ta,tw", [(1, 1), (2, 2), (3, 2), (3, 3)])
def test_stacked_plane_gemm_dispatch_count(rng, ta, tw):
    """The acceptance metric: <= ta MXU dot dispatches per block (was ta*tw)."""
    x, w, w_et = _setup(rng, 32, 64, 32, 4, tw, per_channel=True)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)
    stats = ops.kernel_structure(
        ops.series_matmul, x, s1, w_et.planes, w_et.scales,
        a_bits=4, a_terms=ta, use_kernel=True)
    assert len(stats) == 1, stats
    assert stats[0]["dot_dispatches"] <= ta, stats


@needs_kernels
def test_no_output_rmw_and_guarded_quantize(rng):
    """Scratch accumulation: the kernel never reads the HBM output ref; the
    residual-quantize chain runs only inside the j==0 guard."""
    x, w, w_et = _setup(rng, 32, 64, 32, 4, 2, per_channel=True)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)
    stats = ops.kernel_structure(
        ops.series_matmul, x, s1, w_et.planes, w_et.scales,
        a_bits=4, a_terms=3, use_kernel=True)[0]
    assert stats["out_ref_reads"] == 0, stats          # no o_ref[...] += RMW
    assert stats["quantize_rounds"] == 3, stats        # one round per plane
    assert stats["unguarded_rounds"] == 0, stats       # all under pl.when

    stats_d = ops.kernel_structure(
        ops.packed_dequant_matmul, x,
        jnp.zeros((2, 64, 16), jnp.int8), jnp.ones((2, 32), jnp.float32),
        use_kernel=True)[0]
    assert stats_d["out_ref_reads"] == 0, stats_d
    assert stats_d["dot_dispatches"] == 1, stats_d     # plane-summed GEMM


def test_dispatch_count_raw_kernel_scales_with_ta_only(rng):
    """Directly on the pallas_call (no jit wrapper): dispatches == ta for
    every tw — the tw weight planes ride one batched dot."""
    for ta, tw in ((1, 3), (2, 1), (3, 2)):
        x = jnp.array(rng.normal(size=(16, 32)).astype(np.float32))
        wp = jnp.zeros((tw, 32, 16), jnp.int8)
        ws = jnp.ones((tw, 16), jnp.float32)
        f = functools.partial(series_matmul_pallas, a_bits=4, a_terms=ta,
                              block_m=16, block_n=16, block_k=32, interpret=True)
        n = ops.gemm_dispatch_count(f, x, jnp.float32(0.1), wp, ws)
        assert n == ta, (ta, tw, n)


# ---------------------------------------------------------------------------
# autotune / dispatch layer
# ---------------------------------------------------------------------------
def test_autotune_cache_and_shapes():
    cfg1 = ops.select_block_config("series", 1024, 4096, 4096, 3, 2)
    cfg2 = ops.select_block_config("series", 1024, 4096, 4096, 3, 2)
    assert cfg1 is cfg2                                # lru-cached decision
    assert cfg1.dimension_semantics == ("parallel", "arbitrary", "arbitrary")
    bm, bn, bk = cfg1.blocks
    assert bm % 8 == 0 and bn % 8 == 0 and bk % 8 == 0
    # tiny shapes degrade to padded-dim blocks, never zero
    tiny = ops.select_block_config("series", 1, 7, 5, 2, 1)
    assert all(b >= 1 for b in tiny.blocks)
    # dequant N blocks stay even (packed halves)
    dq = ops.select_block_config("dequant", 64, 256, 200, 0, 2)
    assert dq.block_n % 2 == 0


def test_autotune_respects_vmem_budget():
    cfg = ops.select_block_config("series", 8192, 16384, 16384, 3, 3)
    used = ops._vmem_bytes("series", *cfg.blocks, 16384, 3, 3)
    assert used <= ops.VMEM_BUDGET_BYTES, (cfg, used)


def test_explicit_blocks_override_autotune(rng):
    """Explicit block args bypass the autotuner but still clamp to dims."""
    x, w, w_et = _setup(rng, 100, 120, 60, 4, 2, per_channel=True)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)
    outs = []
    for bm, bn, bk in ((32, 32, 32), (64, 16, 64), (None, None, None)):
        outs.append(np.asarray(ops.series_matmul(
            x, s1, w_et.planes, w_et.scales, a_bits=4, a_terms=2,
            use_kernel=True, block_m=bm, block_n=bn, block_k=bk)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-5)
