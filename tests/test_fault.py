"""Fault tolerance: crash/restart bitwise-identity, stragglers, supervisor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.dist import checkpoint as CKPT
from repro.dist.fault import StragglerDetector, TrainSupervisor
from repro.models import model as M
from repro.train.data import make_batch
from repro.train.train_step import TrainConfig, make_train_step


def _run_life(cfg, ckpt_dir, stop_after, total, *, seed=0):
    """One 'process lifetime': restore-or-init, train until min(stop, total)."""
    tc = TrainConfig(lr=1e-3, remat=False)
    opt, step_fn = make_train_step(cfg, tc)

    def init_state():
        params = M.init_params(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
        return {"params": params, "opt": opt.init(params)}

    sup = TrainSupervisor(ckpt_dir, init_state, ckpt_every=2)
    state, start = sup.restore_or_init()
    step_fn = jax.jit(step_fn)
    end = min(total, stop_after)
    for step in range(start, end):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 32, 4, step, seed=seed).items()}
        params, opt_state, _ = step_fn(state["params"], state["opt"], batch)
        state = {"params": params, "opt": opt_state}
        sup.after_step(step, state)
    sup.finalize(end - 1, state)
    return state


def test_crash_restart_bitwise_identical(tmp_path):
    """Train 10 steps straight vs 6 steps + crash + restart to 10: identical."""
    cfg = get_arch("qwen2_1_5b", smoke=True)
    s_straight = _run_life(cfg, str(tmp_path / "a"), stop_after=10, total=10)
    _run_life(cfg, str(tmp_path / "b"), stop_after=6, total=10)    # first life
    s_restart = _run_life(cfg, str(tmp_path / "b"), stop_after=10, total=10)  # second life
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        s_straight["params"], s_restart["params"])


def test_elastic_restore_across_meshes(tmp_path):
    """State saved unsharded restores under different shardings (elastic)."""
    cfg = get_arch("qwen2_1_5b", smoke=True)
    state = _run_life(cfg, str(tmp_path / "c"), stop_after=3, total=3)
    template = jax.eval_shape(lambda: state)
    sh = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), template)
    restored, step = CKPT.restore(str(tmp_path / "c"), template, shardings=sh)
    assert step == 2
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        state["params"], restored["params"])


def test_straggler_detector():
    det = StragglerDetector(factor=2.0, warmup=2)
    for i, dt in enumerate([1.0, 1.0, 1.0, 1.0]):
        assert not det.observe(i, dt)
    assert det.observe(4, 5.0)          # 5x the EMA
    assert det.slow_steps == [(4, 5.0)]
    # the straggler did not poison the EMA
    assert abs(det.ema - 1.0) < 1e-6
    assert not det.observe(5, 1.1)


def test_supervisor_restore_or_init_fresh(tmp_path):
    init = lambda: {"w": jnp.arange(4.0)}
    sup = TrainSupervisor(str(tmp_path / "fresh"), init)
    state, start = sup.restore_or_init()
    assert start == 0
    np.testing.assert_array_equal(np.asarray(state["w"]), np.arange(4.0))
