"""Checkpointing: atomic commit, keep-k GC, async, corrupted-ignore, restore."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import checkpoint as CKPT


def _state(seed=0):
    r = np.random.default_rng(seed)
    return {"params": {"w": jnp.array(r.normal(size=(8, 8)).astype(np.float32)),
                       "b": jnp.array(r.normal(size=(8,)).astype(np.float32))},
            "step": jnp.int32(seed)}


def _eq(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)), a, b)


def test_roundtrip(tmp_path):
    st = _state(3)
    CKPT.save(str(tmp_path), 3, st)
    restored, step = CKPT.restore(str(tmp_path), jax.eval_shape(lambda: st))
    assert step == 3
    _eq(st, restored)


def test_latest_and_keep_k(tmp_path):
    for s in range(6):
        CKPT.save(str(tmp_path), s, _state(s), keep=3)
    steps = CKPT.committed_steps(str(tmp_path))
    assert steps == [3, 4, 5]
    assert CKPT.latest_step(str(tmp_path)) == 5


def test_uncommitted_ignored(tmp_path):
    CKPT.save(str(tmp_path), 1, _state(1))
    # fake a crashed (uncommitted) later checkpoint: dir without .DONE
    os.makedirs(tmp_path / "step_000000002")
    assert CKPT.latest_step(str(tmp_path)) == 1
    restored, step = CKPT.restore(str(tmp_path), jax.eval_shape(lambda: _state(1)))
    assert step == 1
    # gc removes the orphan
    CKPT.gc_old(str(tmp_path), keep=3)
    assert not os.path.exists(tmp_path / "step_000000002")


def test_shape_mismatch_rejected(tmp_path):
    CKPT.save(str(tmp_path), 0, _state())
    bad = {"params": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((8,))}, "step": jnp.int32(0)}
    with pytest.raises(ValueError, match="shape mismatch"):
        CKPT.restore(str(tmp_path), jax.eval_shape(lambda: bad))


def test_missing_leaf_rejected(tmp_path):
    CKPT.save(str(tmp_path), 0, _state())
    extra = {"params": {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,)),
                        "new": jnp.zeros((2,))}, "step": jnp.int32(0)}
    with pytest.raises(KeyError):
        CKPT.restore(str(tmp_path), jax.eval_shape(lambda: extra))


def test_async_checkpointer(tmp_path):
    ck = CKPT.AsyncCheckpointer(str(tmp_path), keep=2)
    st = _state(7)
    ck.save(7, st)
    ck.wait()
    restored, step = CKPT.restore(str(tmp_path), jax.eval_shape(lambda: st))
    assert step == 7
    _eq(st, restored)


def test_async_overlapping_saves(tmp_path):
    ck = CKPT.AsyncCheckpointer(str(tmp_path), keep=10)
    for s in range(4):
        ck.save(s, _state(s))   # each save waits for the previous
    ck.wait()
    assert CKPT.committed_steps(str(tmp_path)) == [0, 1, 2, 3]


def test_gc_removes_crashed_tmp_dir(tmp_path):
    """A save killed mid-write leaves step_*.tmp; gc (and thus the next
    save) must clean it up instead of crashing on the name parse."""
    CKPT.save(str(tmp_path), 1, _state(1))
    os.makedirs(tmp_path / "step_000000002.tmp")
    CKPT.save(str(tmp_path), 3, _state(3), keep=3)   # triggers gc_old
    assert not os.path.exists(tmp_path / "step_000000002.tmp")
    assert CKPT.committed_steps(str(tmp_path)) == [1, 3]


def test_gc_preserves_committed_old_copy(tmp_path):
    """A re-commit crash leaves the previous committed copy at step_*.old
    (atomic_commit_dir's recovery guarantee); gc must not destroy it, while
    a markerless .old (torn move) is cleaned like any crashed leftover."""
    CKPT.save(str(tmp_path), 1, _state(1))
    old = tmp_path / "step_000000002.old"
    os.makedirs(old)
    with open(old / ".DONE", "w") as f:
        f.write("ok\n")
    os.makedirs(tmp_path / "step_000000004.old")     # no marker: garbage
    CKPT.save(str(tmp_path), 3, _state(3), keep=3)   # triggers gc_old
    assert os.path.exists(old)                       # recovery copy survives
    assert not os.path.exists(tmp_path / "step_000000004.old")
    assert CKPT.committed_steps(str(tmp_path)) == [1, 3]


def test_recommit_replaces_in_place(tmp_path):
    """Re-saving an existing step commits the new copy and leaves no
    .tmp/.old staging behind."""
    CKPT.save(str(tmp_path), 5, _state(1))
    CKPT.save(str(tmp_path), 5, _state(2))
    restored, step = CKPT.restore(str(tmp_path), jax.eval_shape(lambda: _state(2)))
    assert step == 5 and int(restored["step"]) == 2
    leftovers = [n for n in os.listdir(tmp_path)
                 if n.endswith(".tmp") or n.endswith(".old")]
    assert leftovers == []


def test_async_checkpointer_surfaces_write_errors(tmp_path):
    """A failed background write must raise on wait(), not vanish."""
    ck = CKPT.AsyncCheckpointer(str(tmp_path / "f"))
    ck.save(0, {"x": jnp.zeros(())})
    ck.wait()                                        # healthy write is fine
    # a plain file where the checkpoint dir should be -> makedirs fails
    (tmp_path / "g").write_text("")
    broken = CKPT.AsyncCheckpointer(str(tmp_path / "g"))
    broken.save(1, {"x": jnp.ones(())})
    with pytest.raises(OSError):
        broken.wait()


def test_restore_with_shardings_device_put(tmp_path):
    """The elastic path: restore with explicit (here trivial) shardings."""
    st = _state(1)
    CKPT.save(str(tmp_path), 1, st)
    shardings = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), st)
    restored, _ = CKPT.restore(str(tmp_path), jax.eval_shape(lambda: st),
                               shardings=shardings)
    _eq(st, restored)
    for leaf in jax.tree_util.tree_leaves(restored):
        assert leaf.sharding == jax.sharding.SingleDeviceSharding(jax.devices()[0])
