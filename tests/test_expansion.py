"""Theorem 1 (tensor low-bit series expansion): bounds, schedules, properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import expansion as E
from repro.core import convergence as C

BITS = (2, 3, 4, 8)


def _rand(rng, shape, scale=1.0):
    return jnp.array(rng.normal(size=shape).astype(np.float32) * scale)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("terms", (1, 2, 3, 4))
@pytest.mark.parametrize("symmetric", (True, False))
@pytest.mark.parametrize("saturating", (True, False))
def test_residual_bound(rng, bits, terms, symmetric, saturating):
    m = _rand(rng, (48, 64))
    et = E.expand(m, bits, terms, symmetric=symmetric, saturating=saturating,
                  per_channel=True)
    res = float(jnp.max(jnp.abs(E.residual(m, et))))
    bound = float(E.theoretical_residual_bound(et))
    noise = C.f32_noise_floor(float(jnp.max(jnp.abs(m))))
    assert res <= bound * 1.01 + noise, (res, bound)


@pytest.mark.parametrize("bits", BITS)
def test_exponential_convergence(rng, bits):
    """Each extra term shrinks the residual by the scale ratio (Theorem 1)."""
    m = _rand(rng, (64, 64))
    et = E.expand(m, bits, 4, saturating=False)
    prev = None
    ratio = E.scale_ratio(bits)
    for t in range(1, 5):
        r = float(jnp.max(jnp.abs(E.residual(m, et, t))))
        if prev is not None and r > 1e-6:  # above f32 noise floor
            assert r <= prev / ratio * 1.05, (t, r, prev)
        prev = r


def test_scale_schedule_dyadic(rng):
    """scale_i = ratio * scale_{i+1} exactly (the paper's parallelism enabler)."""
    m = _rand(rng, (32, 32))
    for bits in BITS:
        et = E.expand(m, bits, 3)
        s = np.asarray(et.scales)
        ratio = E.scale_ratio(bits)
        np.testing.assert_allclose(s[0], ratio * s[1], rtol=1e-6)
        np.testing.assert_allclose(s[1], ratio * s[2], rtol=1e-6)


def test_closed_form_matches_sequential(rng):
    """Paper §4 parallel extraction == sequential (up to f32 tie flips)."""
    m = _rand(rng, (64, 96))
    s1 = E.first_scale(E.clip_bound(m, 4, False, False), 4)
    et = E.expand(m, 4, 3, symmetric=True, saturating=False)
    for k in range(3):
        cf = np.asarray(E.extract_plane_closed_form(m, s1, 4, k, False)).astype(int)
        sq = np.asarray(et.planes[k]).astype(int)
        d = np.abs(cf - sq)
        assert d.max() <= 1
        assert (d > 0).mean() < 0.01  # only isolated f32 rounding ties


def test_planes_are_int_range(rng):
    for bits in BITS:
        et = E.expand(_rand(rng, (32, 48)), bits, 3, saturating=True)
        p = np.asarray(et.planes).astype(int)
        hi0 = 2 ** (bits - 1) - 1
        hi = min(2 ** (bits - 1), 127)
        assert np.abs(p[0]).max() <= hi0
        assert np.abs(p[1:]).max() <= hi


def test_negation_symmetry(rng):
    """expand(-M) == -expand(M) for symmetric non-saturating quantizers."""
    m = _rand(rng, (16, 16))
    a = E.expand(m, 4, 3, symmetric=True, saturating=False)
    b = E.expand(-m, 4, 3, symmetric=True, saturating=False)
    np.testing.assert_array_equal(np.asarray(a.planes), -np.asarray(b.planes))


def test_asymmetric_absorbs_offset(rng):
    """A constant offset lands in bias*M_nsy, not in the planes."""
    m = _rand(rng, (32, 32))
    a = E.expand(m, 4, 2, symmetric=False, saturating=False)
    b = E.expand(m + 7.5, 4, 2, symmetric=False, saturating=False)
    np.testing.assert_array_equal(np.asarray(a.planes), np.asarray(b.planes))
    np.testing.assert_allclose(float(b.bias - a.bias), 7.5, rtol=1e-5)


def test_saturation_correction_exact(rng):
    """M_sa + clipped series reconstructs heavy-tailed tensors to bound."""
    m = _rand(rng, (64, 64))
    m = m.at[0, 0].set(50.0).at[1, 1].set(-40.0)  # outliers
    et = E.expand(m, 4, 3, saturating=True, keep_sat=True)
    res = float(jnp.max(jnp.abs(E.residual(m, et))))
    assert res <= float(E.theoretical_residual_bound(et)) * 1.01 + 1e-5
    assert et.sat is not None and float(jnp.max(jnp.abs(et.sat))) > 1.0
    # dropping sat loses exactly the clipped mass
    et2 = E.drop_sat(et)
    res2 = float(jnp.max(jnp.abs(E.residual(m, et2))))
    assert res2 >= 1.0


def test_per_channel_isolation(rng):
    """Scaling one channel must not change other channels' planes."""
    m = _rand(rng, (32, 8))
    m2 = m.at[:, 3].multiply(100.0)
    a = E.expand(m, 4, 2, per_channel=True)
    b = E.expand(m2, 4, 2, per_channel=True)
    other = [c for c in range(8) if c != 3]
    np.testing.assert_array_equal(np.asarray(a.planes)[..., other],
                                  np.asarray(b.planes)[..., other])


def test_batched_expansion_matches_loop(rng):
    m = _rand(rng, (4, 16, 24))
    et = E.expand_batched(m, 4, 2, per_channel=True, saturating=True)
    assert et.batch_dims == 1 and et.num_terms == 2
    for e in range(4):
        et_e = E.expand(m[e], 4, 2, per_channel=True, saturating=True)
        np.testing.assert_array_equal(np.asarray(et.planes[e]), np.asarray(et_e.planes))
    rec = E.reconstruct(et)
    assert rec.shape == m.shape


def test_truncate(rng):
    m = _rand(rng, (16, 16))
    et = E.expand(m, 4, 4)
    t2 = E.truncate(et, 2)
    assert t2.num_terms == 2
    np.testing.assert_array_equal(np.asarray(t2.planes), np.asarray(et.planes[:2]))


def test_auto_num_terms():
    assert E.auto_num_terms(1.0, 4, threshold=1e-4) == 5   # 1/(2*16^4) < 1e-4
    assert E.auto_num_terms(0.1, 8, threshold=1e-4) == 3   # ratio 128 for X=8
    assert E.auto_num_terms(1e-6, 4, threshold=1e-4) == 1


@settings(max_examples=40, deadline=None)
@given(
    bits=st.sampled_from(BITS),
    terms=st.integers(1, 4),
    rows=st.integers(1, 24),
    cols=st.integers(1, 24),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
    symmetric=st.booleans(),
    saturating=st.booleans(),
)
def test_property_bound_holds(bits, terms, rows, cols, scale, seed, symmetric, saturating):
    """Hypothesis: the Theorem-1 bound holds for arbitrary shapes/scales."""
    r = np.random.default_rng(seed)
    m = jnp.array((r.normal(size=(rows, cols)) * scale).astype(np.float32))
    et = E.expand(m, bits, terms, symmetric=symmetric, saturating=saturating)
    res = float(jnp.max(jnp.abs(E.residual(m, et))))
    bound = float(E.theoretical_residual_bound(et))
    noise = C.f32_noise_floor(float(jnp.max(jnp.abs(m))) + 1e-30)
    assert res <= bound * 1.02 + noise + 1e-12


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from((2, 4)),
       terms=st.integers(1, 3))
def test_property_reconstruct_idempotent(seed, bits, terms):
    """Expanding a reconstruction reproduces identical planes (fixed point)."""
    r = np.random.default_rng(seed)
    m = jnp.array(r.normal(size=(8, 8)).astype(np.float32))
    et = E.expand(m, bits, terms, saturating=False)
    rec = E.reconstruct(et)
    et2 = E.expand(rec, bits, terms, saturating=False)
    rec2 = E.reconstruct(et2)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(rec2),
                               atol=float(E.theoretical_residual_bound(et)) * 0.1 + 1e-6)


def test_batched_quantizers_fully_independent(rng):
    """Per-expert quantizer independence: EVERY field of the batched
    expansion (planes, scales, bias, sat) is bit-identical to a Python loop
    of per-slice ``expand`` — each slice gets its own clip/scale schedule,
    so stacking experts never couples their quantizers."""
    m = _rand(rng, (3, 16, 24), scale=2.0)
    kw = dict(per_channel=True, saturating=True, symmetric=False,
              keep_sat=True)
    et = E.expand_batched(m, 4, 3, **kw)
    assert et.batch_dims == 1
    for e in range(3):
        ref = E.expand(m[e], 4, 3, **kw)
        np.testing.assert_array_equal(np.asarray(et.planes[e]),
                                      np.asarray(ref.planes))
        np.testing.assert_array_equal(np.asarray(et.scales[e]),
                                      np.asarray(ref.scales))
        np.testing.assert_array_equal(np.asarray(et.bias[e]),
                                      np.asarray(ref.bias))
        np.testing.assert_array_equal(np.asarray(et.sat[e]),
                                      np.asarray(ref.sat))
        np.testing.assert_array_equal(np.asarray(E.reconstruct(et)[e]),
                                      np.asarray(E.reconstruct(ref)))


@pytest.mark.parametrize("e", (3, 5))
def test_batched_pack_odd_expert_count(rng, e):
    """INT4-packing a stacked expansion with an ODD expert count and an odd
    last axis: the nibble pad applies per-row on the last axis only (the
    expert axis is never halved), and unpack restores every expert
    bit-exactly."""
    m = _rand(rng, (e, 8, 7))               # odd columns -> one pad nibble
    et = E.expand_batched(m, 4, 2, per_channel=True, pack_safe=True)
    p = E.pack(et)
    assert p.packed and p.pack_pad == 1
    assert p.planes.shape[0] == e           # expert axis untouched
    assert p.planes.shape[-1] == 4          # ceil(7/2) bytes
    u = E.unpack(p)
    np.testing.assert_array_equal(np.asarray(u.planes), np.asarray(et.planes))
    np.testing.assert_array_equal(np.asarray(E.reconstruct(p)),
                                  np.asarray(E.reconstruct(et)))


def test_batched_truncate_per_expert(rng):
    """truncate(k) on a batched expansion slices the TERM axis (axis
    batch_dims), not the expert axis, and equals the per-slice truncate of
    each expert's own expansion — QoS term budgets work per-expert."""
    m = _rand(rng, (4, 12, 10))
    et = E.expand_batched(m, 4, 3, per_channel=True)
    for k in (1, 2):
        t = E.truncate(et, k)
        assert t.num_terms == k and t.batch_dims == 1
        assert t.planes.shape == (4, k, 12, 10)
        for e in range(4):
            ref = E.truncate(E.expand(m[e], 4, 3, per_channel=True), k)
            np.testing.assert_array_equal(np.asarray(t.planes[e]),
                                          np.asarray(ref.planes))
            np.testing.assert_array_equal(np.asarray(t.scales[e]),
                                          np.asarray(ref.scales))
            np.testing.assert_array_equal(np.asarray(E.reconstruct(t)[e]),
                                          np.asarray(E.reconstruct(ref)))
